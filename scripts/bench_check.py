#!/usr/bin/env python
"""Benchmark regression guard for the CI bench-smoke lane.

Compares a freshly produced ``BENCH_controller_overhead.json`` against
the committed ``BENCH_baseline.json`` row-by-row (matched on ``name``)
and fails the job when any comparable row slowed down by more than the
allowed factor.

CI runners are not the machine the baseline was recorded on, so raw
us_per_call is never compared directly: a machine-speed factor — the
median of (current / baseline) across comparable rows, clamped to
[0.25, 4] — rescales the baseline first.  A single regressed row can't
hide behind the factor (the median is robust), and a uniformly slower
runner doesn't trip the guard.

Coverage is part of the contract: a baseline row the fresh run failed
to produce is a FAIL (a benchmark that silently stops emitting a row
would otherwise never regress). The committed baseline spans several
benchmark JSONs, so each CI invocation passes ``--scope PREFIX``
(repeatable) naming the row families it is responsible for; baseline
rows outside every scope are someone else's job and are skipped. With
no ``--scope``, every baseline row is required (single-JSON layouts).
Rows only in the CURRENT run stay informational (benchmarks grow).

Also skipped: rows whose ``derived`` mentions "interpret" — Pallas
interpret mode on CPU is an emulation path whose latency is noise, not
a product number.

Non-numeric ``us_per_call`` is an ERROR, not a skip: the benchmark
contract (and this guard) depends on numeric rows.

  python scripts/bench_check.py BENCH_controller_overhead.json \\
      --baseline BENCH_baseline.json [--factor 2.0] \\
      [--scope controller_ --scope fleet_ ...]
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    rows = {}
    for row in payload["rows"]:
        us = row.get("us_per_call")
        if isinstance(us, bool) or not isinstance(us, (int, float)):
            raise SystemExit(
                f"{path}: row {row.get('name')!r} has non-numeric "
                f"us_per_call {us!r} — benchmark rows must be numbers"
            )
        rows[row["name"]] = row
    return rows


def check(cur_path: str, base_path: str, factor: float,
          scopes=None) -> int:
    cur = load_rows(cur_path)
    base = load_rows(base_path)
    shared = sorted(set(cur) & set(base))

    def in_scope(name: str) -> bool:
        return scopes is None or any(name.startswith(s) for s in scopes)

    for name in sorted(set(cur) - set(base)):
        print(f"skip {name}: only in current (benchmarks grow)")
    missing = []
    for name in sorted(set(base) - set(cur)):
        if in_scope(name):
            print(f"MISSING {name}: in baseline but not produced by "
                  f"this run")
            missing.append(name)
        else:
            print(f"skip {name}: baseline row outside this run's scope")

    comparable = []
    for name in shared:
        derived = f"{cur[name].get('derived', '')} {base[name].get('derived', '')}"
        if "interpret" in derived:
            print(f"skip {name}: interpret-mode row (emulated, not a "
                  f"product number)")
            continue
        comparable.append(name)
    if not comparable:
        if missing:
            print(f"FAIL: {len(missing)} baseline row(s) missing from "
                  f"the fresh run: {', '.join(missing)}")
            return 1
        print("no comparable rows; nothing to check")
        return 0

    ratios = [cur[n]["us_per_call"] / base[n]["us_per_call"]
              for n in comparable if base[n]["us_per_call"] > 0]
    speed = min(4.0, max(0.25, statistics.median(ratios))) if ratios else 1.0
    print(f"machine-speed factor (median cur/base, clamped): {speed:.3f}")

    failures = []
    for name in comparable:
        b = base[name]["us_per_call"] * speed
        c = cur[name]["us_per_call"]
        verdict = "OK" if c <= factor * b or b == 0 else "REGRESSED"
        print(f"{verdict:9s} {name}: {c:.2f} us vs {b:.2f} us adjusted "
              f"baseline (limit {factor:.1f}x)")
        if verdict == "REGRESSED":
            failures.append(name)

    if failures or missing:
        if failures:
            print(f"FAIL: {len(failures)} row(s) regressed beyond "
                  f"{factor:.1f}x: {', '.join(failures)}")
        if missing:
            print(f"FAIL: {len(missing)} baseline row(s) missing from "
                  f"the fresh run: {', '.join(missing)}")
        return 1
    print(f"PASS: {len(comparable)} row(s) within {factor:.1f}x of the "
          f"speed-adjusted baseline; all in-scope baseline rows present")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="freshly produced benchmark JSON")
    ap.add_argument("--baseline", default="BENCH_baseline.json",
                    help="committed baseline JSON to compare against")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max allowed slowdown after speed adjustment")
    ap.add_argument("--scope", action="append", default=None,
                    metavar="PREFIX",
                    help="row-name prefix this run is responsible for "
                         "(repeatable): matching baseline rows MUST be "
                         "present in the current JSON. Default: every "
                         "baseline row is required")
    args = ap.parse_args(argv)
    return check(args.current, args.baseline, args.factor,
                 scopes=args.scope)


if __name__ == "__main__":
    sys.exit(main())
