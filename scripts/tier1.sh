#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md).
#
#   scripts/tier1.sh          full tier-1 gate: pytest -x -q
#   scripts/tier1.sh fast     fast lane: skip tests marked `slow`
#
# Extra args are forwarded to pytest, e.g. scripts/tier1.sh fast -k fleet
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

lane="${1:-full}"
if [ "$lane" = "fast" ]; then
  shift
  exec python -m pytest -x -q -m "not slow" "$@"
fi
[ "$lane" = "full" ] && shift || true
exec python -m pytest -x -q "$@"
