#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md).
#
#   scripts/tier1.sh            full tier-1 gate: pytest -x -q
#   scripts/tier1.sh fast       fast lane: skip tests marked `slow`
#   scripts/tier1.sh lint       repro-lint invariant checker (no jax needed)
#   scripts/tier1.sh sanitize   controller/episode smoke tests under
#                               jax_debug_nans + tracer-leak checking +
#                               rank_promotion="raise"
#
# Extra args are forwarded to pytest (or to repro_lint for `lint`),
# e.g. scripts/tier1.sh fast -k fleet / scripts/tier1.sh lint --json
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

lane="${1:-full}"
if [ "$lane" = "fast" ]; then
  shift
  exec python -m pytest -x -q -m "not slow" "$@"
fi
if [ "$lane" = "lint" ]; then
  shift
  exec python scripts/repro_lint.py "$@"
fi
if [ "$lane" = "sanitize" ]; then
  shift
  # runtime sanitizers on the numerics-heavy smoke suites: NaNs raise at
  # the op that produced them, leaked tracers raise at escape, implicit
  # rank promotion raises at the broadcast
  export JAX_DEBUG_NANS=True
  export JAX_CHECK_TRACER_LEAKS=True
  export JAX_NUMPY_RANK_PROMOTION=raise
  exec python -m pytest -x -q -m "not slow" \
    tests/test_energy_backend.py tests/test_episode_scan.py "$@"
fi
[ "$lane" = "full" ] && shift || true
exec python -m pytest -x -q "$@"
