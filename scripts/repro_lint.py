#!/usr/bin/env python3
"""repro-lint CLI: check the repo's architecture invariants statically.

Usage::

    python scripts/repro_lint.py                 # lint src/repro
    python scripts/repro_lint.py --json          # machine-readable
    python scripts/repro_lint.py path/to/file.py # lint specific paths
    python scripts/repro_lint.py --show-suppressed

Exit status: 0 when every finding is suppressed (with a justification),
1 when any active finding remains, 2 on usage errors. Stdlib-only — no
jax needed, safe for pre-commit and the CI lint job.
"""
import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import (  # noqa: E402
    exit_code,
    render_human,
    render_json,
    run_lint,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="AST invariant checker (RPL001..RPL005)",
    )
    ap.add_argument(
        "paths", nargs="*", type=Path,
        help="files or directories to lint (default: src/repro)",
    )
    ap.add_argument(
        "--root", type=Path, default=REPO_ROOT,
        help="repo root used for relative paths in reports",
    )
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit JSON instead of human-readable lines")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="include suppressed findings in human output")
    args = ap.parse_args(argv)

    paths = args.paths or [args.root / "src" / "repro"]
    for p in paths:
        if not p.exists():
            print(f"repro_lint: no such path: {p}", file=sys.stderr)
            return 2

    findings = run_lint(args.root, paths)
    if args.as_json:
        print(render_json(findings))
    else:
        print(render_human(findings, show_suppressed=args.show_suppressed))
    return exit_code(findings)


if __name__ == "__main__":
    sys.exit(main())
