from repro.parallel.distributed import (
    DistributedFleetController,
    FleetComm,
    FleetEpoch,
    connect_fleet,
    init_jax_distributed,
    parse_address,
    restore_fleet_controller,
)
from repro.parallel.fleet import (
    fleet_mesh,
    host_stripe,
    make_sharded_fleet_step,
    stripe_bounds,
    stripe_map,
)
from repro.parallel.sharding import DEFAULT_RULES, Sharder, spec_for_axes

__all__ = [
    "DEFAULT_RULES",
    "DistributedFleetController",
    "FleetComm",
    "FleetEpoch",
    "Sharder",
    "connect_fleet",
    "fleet_mesh",
    "host_stripe",
    "init_jax_distributed",
    "make_sharded_fleet_step",
    "parse_address",
    "restore_fleet_controller",
    "spec_for_axes",
    "stripe_bounds",
    "stripe_map",
]
