from repro.parallel.distributed import (
    DistributedFleetController,
    FleetComm,
    connect_fleet,
    init_jax_distributed,
    parse_address,
)
from repro.parallel.fleet import (
    fleet_mesh,
    host_stripe,
    make_sharded_fleet_step,
    stripe_bounds,
)
from repro.parallel.sharding import DEFAULT_RULES, Sharder, spec_for_axes

__all__ = [
    "DEFAULT_RULES",
    "DistributedFleetController",
    "FleetComm",
    "Sharder",
    "connect_fleet",
    "fleet_mesh",
    "host_stripe",
    "init_jax_distributed",
    "make_sharded_fleet_step",
    "parse_address",
    "spec_for_axes",
    "stripe_bounds",
]
