from repro.parallel.fleet import fleet_mesh, make_sharded_fleet_step
from repro.parallel.sharding import DEFAULT_RULES, Sharder, spec_for_axes

__all__ = [
    "DEFAULT_RULES",
    "Sharder",
    "fleet_mesh",
    "make_sharded_fleet_step",
    "spec_for_axes",
]
