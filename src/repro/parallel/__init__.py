from repro.parallel.sharding import DEFAULT_RULES, Sharder, spec_for_axes

__all__ = ["DEFAULT_RULES", "Sharder", "spec_for_axes"]
