"""Multi-process fleet control plane: H controller processes, one fleet.

The paper's deployment target is per-host energy control (GEOPM-style:
every node reads its own counters and actuates its own frequency), but
until now the repo's control plane assumed one Python process owned the
world — ``make_sharded_fleet_step`` shards controller state across a
single-host mesh, and every :class:`~repro.energy.backend.EnergyBackend`
lives next to the policy. This module promotes that to H controller
processes, each owning

- a LOCAL backend stripe (``backend.local_slice(lo, hi)``: SimBackend
  noise streams are keyed by global node id, trace shards slice the
  recorded columns), and
- the matching N/H stripe of fused-kernel controller state (per-node
  hyperparameter lanes sliced by ``core.fleet.slice_policy_lanes``).

Per decision interval there are ZERO collectives: telemetry, actuation
and the fused Pallas fleet step all stay host-local (the controller step
is embarrassingly row-parallel — the same property ``shard_map`` exploits
within one process). Hosts coordinate only through

- a stdlib-socket coordinator (:func:`connect_fleet`, built on
  ``multiprocessing.connection`` so it runs anywhere — CPU CI included)
  used for the startup barrier and for PERIODIC fleet-level aggregates
  (energy saved, slowdown, switch counts) via
  :func:`~repro.energy.controller.reduce_summaries`; or
- ``jax.distributed`` initialization (:func:`init_jax_distributed`) on
  real multi-host TPU/GPU deployments, where ``fleet_mesh()`` then spans
  every process and each host may additionally shard its own stripe over
  its local chips.

Bit-parity with the single-process sharded step is the correctness
oracle: a 2-process run must reproduce the exact arm/state trajectories
of one process owning the whole fleet (tests/test_distributed.py).
"""
from __future__ import annotations

import time
from multiprocessing.connection import Client, Listener
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.fleet import slice_policy_lanes
from repro.core.policies import Policy
from repro.energy.backend import EnergyBackend
from repro.energy.controller import EnergyController, reduce_summaries
from repro.parallel.fleet import host_stripe

# Rendezvous auth (multiprocessing.connection HMAC handshake). The
# payloads are pickles, so WHOEVER HOLDS THE KEY CAN EXECUTE CODE on the
# coordinator: any deployment whose coordinator port is reachable beyond
# loopback MUST supply its own secret (fleet_serve reads FLEET_AUTHKEY,
# and --spawn generates a fresh random key per run). This constant is
# only the convenience default for same-machine demos and tests.
DEFAULT_AUTHKEY = b"repro-fleet-v1"


def parse_address(spec: str) -> Tuple[str, int]:
    """'host:port' -> (host, port) for the coordinator socket."""
    host, _, port = spec.rpartition(":")
    return (host or "127.0.0.1", int(port))


def init_jax_distributed(coordinator: str, num_hosts: int, host_id: int):
    """Initialize ``jax.distributed`` for a real multi-host deployment
    (after this, ``jax.devices()`` — and therefore ``fleet_mesh()`` —
    spans every controller process). The CPU-CI control plane never
    needs this: the socket coordinator below carries the few fleet-level
    aggregates, and everything else is host-local."""
    import jax

    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_hosts,
        process_id=host_id,
    )


# ---------------------------------------------------------------------------
# the socket coordinator: startup barrier + periodic aggregate gathers
# ---------------------------------------------------------------------------


class FleetComm:
    """H-process rendezvous with one verb: ``allgather(payload, tag)``
    returns every host's payload ordered by host_id, on every host. Tags
    guard against rounds drifting out of step (every gather in the
    control plane happens at the same logical point on all hosts)."""

    num_hosts: int
    host_id: int

    def allgather(self, payload: Any, tag: str) -> List[Any]:
        raise NotImplementedError

    def barrier(self, tag: str = "barrier") -> None:
        self.allgather(None, tag)

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class NullComm(FleetComm):
    """The H=1 degenerate case: one process already owns the fleet."""

    num_hosts, host_id = 1, 0

    def allgather(self, payload: Any, tag: str) -> List[Any]:
        return [payload]


class CoordinatorComm(FleetComm):
    """Host 0: serves the rendezvous socket and participates in every
    gather in-process. Accepts exactly H-1 peers at startup (each peer
    identifies itself with its host_id), then each allgather round
    collects one tagged payload per peer and broadcasts the full list."""

    def __init__(self, address: Tuple[str, int], num_hosts: int,
                 authkey: bytes = DEFAULT_AUTHKEY, timeout_s: float = 120.0):
        self.num_hosts, self.host_id = int(num_hosts), 0
        self._listener = Listener(address, authkey=authkey)
        self.address = self._listener.address
        self._conns: Dict[int, Any] = {}
        # a peer that dies before connecting must fail the rendezvous
        # fast, not hang host 0 (and CI) until the job timeout. A
        # timeout on the listening socket is the only reliable way to
        # bound the blocking accept (closing the listener from another
        # thread does NOT wake accept on Linux); accepted connections
        # come back blocking, so gather rounds are unaffected. (A peer
        # that connects but never sends its host_id can still block the
        # handshake recv — the connect itself is the flaky part.)
        sock = getattr(getattr(self._listener, "_listener", None),
                       "_socket", None)
        if sock is not None:
            sock.settimeout(timeout_s)
        while len(self._conns) < num_hosts - 1:
            try:
                conn = self._listener.accept()
            except OSError:
                self._listener.close()
                raise TimeoutError(
                    f"fleet rendezvous: {len(self._conns) + 1}/"
                    f"{num_hosts} hosts checked in after {timeout_s}s"
                ) from None
            peer = int(conn.recv())
            if peer in self._conns or not 0 < peer < num_hosts:
                conn.close()
                raise RuntimeError(f"bad or duplicate host_id {peer}")
            self._conns[peer] = conn

    def allgather(self, payload: Any, tag: str) -> List[Any]:
        gathered = {0: payload}
        for peer, conn in self._conns.items():
            got_peer, got_tag, data = conn.recv()
            if got_peer != peer or got_tag != tag:
                raise RuntimeError(
                    f"fleet comm out of step: expected {(peer, tag)}, "
                    f"got {(got_peer, got_tag)}"
                )
            gathered[peer] = data
        out = [gathered[h] for h in range(self.num_hosts)]
        for conn in self._conns.values():
            conn.send(out)
        return out

    def close(self) -> None:
        for conn in self._conns.values():
            conn.close()
        self._listener.close()


class ClientComm(FleetComm):
    """Hosts 1..H-1: connect (with retry while host 0 comes up), then
    mirror the coordinator's gather rounds."""

    def __init__(self, address: Tuple[str, int], num_hosts: int, host_id: int,
                 authkey: bytes = DEFAULT_AUTHKEY, timeout_s: float = 60.0):
        self.num_hosts, self.host_id = int(num_hosts), int(host_id)
        deadline = time.monotonic() + timeout_s
        while True:
            try:
                self._conn = Client(address, authkey=authkey)
                break
            except (ConnectionError, OSError):
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"host {host_id}: coordinator {address} not up "
                        f"after {timeout_s}s"
                    )
                time.sleep(0.1)
        self._conn.send(self.host_id)

    def allgather(self, payload: Any, tag: str) -> List[Any]:
        self._conn.send((self.host_id, tag, payload))
        return self._conn.recv()

    def close(self) -> None:
        self._conn.close()


def connect_fleet(num_hosts: int, host_id: int,
                  address: Optional[Tuple[str, int]] = None,
                  authkey: bytes = DEFAULT_AUTHKEY) -> FleetComm:
    """The one entry point: host 0 serves, the rest connect, H=1 is a
    no-op comm. Blocks until the whole fleet has checked in."""
    if num_hosts == 1:
        return NullComm()
    if address is None:
        raise ValueError("multi-host fleets need a coordinator address")
    if host_id == 0:
        return CoordinatorComm(address, num_hosts, authkey=authkey)
    return ClientComm(address, num_hosts, host_id, authkey=authkey)


# ---------------------------------------------------------------------------
# the distributed controller: one stripe per process, zero per-interval
# collectives
# ---------------------------------------------------------------------------


class DistributedFleetController:
    """One controller process's share of the fleet: a local
    :class:`EnergyController` over the host's backend stripe and policy
    lanes, plus the comm used ONLY for periodic fleet-level aggregates.

    Build with :meth:`from_global` (each process constructs the same
    full-fleet description, then slices its own stripe — parity by
    construction) or pass an already-local backend with its ``stripe``.
    ``step``/``run`` never touch the network; ``fleet_summary`` and the
    optional ``report_every`` ticks gather H small summary dicts."""

    def __init__(self, policy: Policy, local_backend: EnergyBackend,
                 comm: Optional[FleetComm] = None,
                 stripe: Optional[Tuple[int, int]] = None,
                 n_total: Optional[int] = None, seed: int = 0,
                 use_kernel: Optional[bool] = None, interpret: bool = False,
                 record_history: bool = False, mesh=None,
                 log_arms: bool = False):
        self.comm = comm or NullComm()
        self.stripe = stripe or (0, local_backend.n_nodes)
        self.n_total = int(n_total or local_backend.n_nodes)
        self.n_local = int(local_backend.n_nodes)
        self.controller = EnergyController(
            policy, local_backend, seed=seed, use_kernel=use_kernel,
            interpret=interpret, record_history=record_history, mesh=mesh,
        )
        self.log_arms = log_arms
        self.arm_log: List[np.ndarray] = []
        self.reports: List[Dict[str, Any]] = []

    @classmethod
    def from_global(cls, policy: Policy, backend: EnergyBackend,
                    comm: FleetComm, **kw) -> "DistributedFleetController":
        """Slice this host's stripe out of the full-fleet backend and
        policy lanes. Every host calls this with the SAME (policy,
        backend) description; H=1 degenerates to the whole fleet."""
        n = int(backend.n_nodes)
        lo, hi = host_stripe(n, comm.num_hosts, comm.host_id)
        local = backend if comm.num_hosts == 1 else backend.local_slice(lo, hi)
        return cls(slice_policy_lanes(policy, lo, hi, n), local, comm,
                   stripe=(lo, hi), n_total=n, **kw)

    @property
    def use_kernel(self) -> bool:
        return self.controller.use_kernel

    def step(self, work_fn: Optional[Callable[[], Any]] = None) -> Dict[str, Any]:
        """One host-local decision interval — no collectives."""
        rec = self.controller.step(work_fn)
        if self.log_arms:
            self.arm_log.append(
                np.asarray(self.controller.last_arms).reshape(self.n_local)
            )
        return rec

    def run(self, n_intervals: int,
            work_fn: Optional[Callable[[], Any]] = None,
            report_every: int = 0,
            on_report: Optional[Callable[[int, Dict[str, Any]], None]] = None,
            episode_scan: bool = False,
            ) -> Dict[str, Any]:
        """Drive the stripe for ``n_intervals``; every ``report_every``
        intervals (0 = never) gather the fleet aggregate and append it
        to ``self.reports`` (``on_report(interval, fleet_summary)`` fires
        on every host). Returns the final fleet summary.

        ``episode_scan=True`` advances the stripe in fused episode-scan
        chunks (``EnergyController.run_scanned`` — one dispatch per
        chunk of ``report_every`` intervals, or the whole run when
        reporting is off) instead of per-interval steps. Striping is
        unaffected: the scan is host-local (noise is keyed by global
        node id, the drift schedule by global interval index), and the
        reporting/arm-log cadence is preserved. ``work_fn`` cannot run
        inside a fused episode."""
        if episode_scan:
            if work_fn is not None:
                raise ValueError(
                    "episode_scan fuses whole intervals on-device; "
                    "per-interval work_fn needs the streaming path"
                )
            done = 0
            while done < n_intervals:
                chunk = min(report_every or n_intervals, n_intervals - done)
                self.controller.run_scanned(chunk)
                if self.log_arms:
                    self.arm_log.extend(
                        np.asarray(self.controller.last_episode_arms)
                        .reshape(chunk, self.n_local)
                    )
                done += chunk
                if report_every and done % report_every == 0:
                    fleet = self.fleet_summary(tag=f"report-{done}")
                    self.reports.append(fleet)
                    if on_report is not None:
                        on_report(done, fleet)
            return self.fleet_summary(tag="final")
        for i in range(n_intervals):
            self.step(work_fn)
            if report_every and (i + 1) % report_every == 0:
                fleet = self.fleet_summary(tag=f"report-{i + 1}")
                self.reports.append(fleet)
                if on_report is not None:
                    on_report(i + 1, fleet)
        return self.fleet_summary(tag="final")

    def local_summary(self) -> Dict[str, Any]:
        return self.controller.summary()

    def fleet_summary(self, tag: str = "summary") -> Dict[str, Any]:
        """Gather H per-host summaries, reduce to the fleet aggregate
        (identical result on every host)."""
        return reduce_summaries(
            self.comm.allgather(self.local_summary(), tag=tag)
        )

    def gather_arms(self, tag: str = "arms") -> np.ndarray:
        """The full fleet's (T, N) arm trajectory, assembled from every
        host's stripe log (requires ``log_arms=True``) — the parity
        oracle against a single-process run."""
        if not self.log_arms:
            raise RuntimeError("construct with log_arms=True to gather arms")
        local = (np.stack(self.arm_log) if self.arm_log
                 else np.zeros((0, self.n_local), np.int32))
        return np.concatenate(self.comm.allgather(local, tag=tag), axis=1)
