"""Multi-process fleet control plane: H controller processes, one fleet.

The paper's deployment target is per-host energy control (GEOPM-style:
every node reads its own counters and actuates its own frequency), but
until now the repo's control plane assumed one Python process owned the
world — ``make_sharded_fleet_step`` shards controller state across a
single-host mesh, and every :class:`~repro.energy.backend.EnergyBackend`
lives next to the policy. This module promotes that to H controller
processes, each owning

- a LOCAL backend stripe (``backend.local_slice(lo, hi)``: SimBackend
  noise streams are keyed by global node id, trace shards slice the
  recorded columns), and
- the matching N/H stripe of fused-kernel controller state (per-node
  hyperparameter lanes sliced by ``core.fleet.slice_policy_lanes``).

Per decision interval there are ZERO collectives: telemetry, actuation
and the fused Pallas fleet step all stay host-local (the controller step
is embarrassingly row-parallel — the same property ``shard_map`` exploits
within one process). Hosts coordinate only through

- a stdlib-socket coordinator (:func:`connect_fleet`, built on
  ``multiprocessing.connection`` so it runs anywhere — CPU CI included)
  used for the startup barrier and for PERIODIC fleet-level aggregates
  (energy saved, slowdown, switch counts) via
  :func:`~repro.energy.controller.reduce_summaries`; or
- ``jax.distributed`` initialization (:func:`init_jax_distributed`) on
  real multi-host TPU/GPU deployments, where ``fleet_mesh()`` then spans
  every process and each host may additionally shard its own stripe over
  its local chips.

**Fault tolerance.** Node churn is the steady state at datacenter scale,
so the coordinator runs lease-based membership instead of lockstep
all-or-nothing rounds:

- *Liveness is wire liveness.* A SIGKILLed/crashed peer's TCP socket
  closes; the coordinator marks it dead (and bumps the membership
  ``epoch``) the moment a recv/send on that socket errors. A live host
  that merely misses a fold round's lease window contributes a ``None``
  (stale) slot but keeps its membership — optional consecutive-miss
  eviction is available via ``max_missed_folds``.
- *Two gather verbs.* ``allgather(payload, tag)`` is STRICT: it waits
  (bounded by ``round_timeout_s``, picking up mid-round joins) for every
  live member and raises ``TimeoutError`` naming the missing hosts —
  used for the start barrier and the final state/arm gathers, where
  bit-exactness demands every stripe. ``fold(payload, tag)`` is
  STALE-TOLERANT: the coordinator collects whatever live members deliver
  within ``lease_s`` (dead or late hosts yield ``None`` slots), and
  clients never block — they send and drain whatever round results have
  arrived, so a behind/rejoining host can't stall the fleet's periodic
  aggregates and the fleet can't stall it.
- *Epoch-stamped stripe maps.* Every round result is broadcast in an
  envelope carrying a :class:`FleetEpoch` — the membership epoch, the
  live host ids, and (when the coordinator knows ``n_total``) the
  ``stripe_map`` those members WOULD own after an elastic re-stripe.
  The map is advisory: surviving hosts never re-stripe mid-run (that
  would break bit-exactness); it is applied at checkpoint boundaries by
  :func:`restore_fleet_controller`, which stitches the new stripe out of
  per-stripe checkpoints (train.checkpoint.restore_stripe).
- *Rejoin.* A restarted host dials the same coordinator address
  (bounded retry with exponential backoff), is admitted mid-run with a
  ``rejoined=True`` join ACK (so it skips the start barrier), restores
  the latest checkpoint for its stripe, and replays forward — the
  observation-determined determinism (noise keyed by global node id,
  drift phases by global interval index) makes the replay bit-identical
  to the run it crashed out of.

The coordinator process itself is a single point of failure (see
ROADMAP design notes); every OTHER host may die and return freely.

Bit-parity with the single-process sharded step is the correctness
oracle: a 2-process run must reproduce the exact arm/state trajectories
of one process owning the whole fleet (tests/test_distributed.py), and
an 8-process run with a SIGKILL + resurrect mid-run must still match it
arm for arm (tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import socket
import threading
import time
from multiprocessing.connection import Client, Listener
from multiprocessing.connection import wait as conn_wait
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core.fleet import slice_policy_lanes
from repro.core.policies import Policy
from repro.energy.backend import EnergyBackend
from repro.energy.controller import EnergyController, reduce_summaries
from repro.parallel.fleet import host_stripe, stripe_map


def _ckpt():
    # deferred: repro.train pulls in train_step -> models.api, and
    # models.transformer imports repro.parallel for the Sharder — an
    # eager import here closes that cycle and breaks `import
    # repro.models.api` (the dryrun launcher's first import). Only the
    # checkpoint-path methods below need it, long after import time.
    from repro.train import checkpoint
    return checkpoint

# Rendezvous auth (multiprocessing.connection HMAC handshake). The
# payloads are pickles, so WHOEVER HOLDS THE KEY CAN EXECUTE CODE on the
# coordinator: any deployment whose coordinator port is reachable beyond
# loopback MUST supply its own secret (fleet_serve reads FLEET_AUTHKEY,
# and --spawn generates a fresh random key per run). This constant is
# only the convenience default for same-machine demos and tests.
DEFAULT_AUTHKEY = b"repro-fleet-v1"


def parse_address(spec: str) -> Tuple[str, int]:
    """'host:port' -> (host, port) for the coordinator socket."""
    host, _, port = spec.rpartition(":")
    return (host or "127.0.0.1", int(port))


def init_jax_distributed(coordinator: str, num_hosts: int, host_id: int):
    """Initialize ``jax.distributed`` for a real multi-host deployment
    (after this, ``jax.devices()`` — and therefore ``fleet_mesh()`` —
    spans every controller process). The CPU-CI control plane never
    needs this: the socket coordinator below carries the few fleet-level
    aggregates, and everything else is host-local."""
    import jax

    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_hosts,
        process_id=host_id,
    )


# ---------------------------------------------------------------------------
# the socket coordinator: lease membership, strict + stale-tolerant rounds
# ---------------------------------------------------------------------------


class FleetEpoch(NamedTuple):
    """One epoch of fleet membership, broadcast with every round result.

    ``epoch`` bumps on every death/join; ``members`` are the live host
    ids (sorted, coordinator included); ``stripes`` maps each live
    member to the (lo, hi) node stripe it WOULD own after an elastic
    re-stripe (``parallel.fleet.stripe_map``), or None when the
    coordinator was never told the fleet width. Advisory: applied only
    at checkpoint boundaries, never mid-run."""

    epoch: int
    members: Tuple[int, ...]
    stripes: Optional[Dict[int, Tuple[int, int]]]


class FleetComm:
    """H-process rendezvous with two verbs. ``allgather(payload, tag)``
    is the STRICT round: one slot per host id, ``None`` where a host is
    dead, blocking until every live member contributes (tags guard
    against rounds drifting out of step). ``fold(payload, tag)`` is the
    STALE-TOLERANT round for periodic aggregates: dead AND late hosts
    yield ``None`` slots, and non-coordinator hosts never block (they
    may return ``None`` before any round result has arrived)."""

    num_hosts: int
    host_id: int
    # True when this comm was admitted to an already-running fleet (a
    # restarted host): the caller must skip the start barrier and
    # restore its stripe's checkpoint instead
    rejoined: bool = False
    _n_total: Optional[int] = None

    def allgather(self, payload: Any, tag: str) -> List[Any]:
        raise NotImplementedError

    def fold(self, payload: Any, tag: str) -> Optional[List[Any]]:
        return self.allgather(payload, tag)

    def barrier(self, tag: str = "barrier") -> None:
        self.allgather(None, tag)

    def set_fleet_size(self, n_total: int) -> None:
        """Tell the comm the fleet width so membership broadcasts can
        carry elastic stripe maps (no-op where that's not its job)."""
        self._n_total = int(n_total)

    def fleet_epoch(self) -> Optional[FleetEpoch]:
        """The latest known membership epoch (None before any round)."""
        return None

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class NullComm(FleetComm):
    """The H=1 degenerate case: one process already owns the fleet."""

    num_hosts, host_id = 1, 0

    def allgather(self, payload: Any, tag: str) -> List[Any]:
        return [payload]

    def fleet_epoch(self) -> Optional[FleetEpoch]:
        stripes = {0: (0, self._n_total)} if self._n_total else None
        return FleetEpoch(0, (0,), stripes)


class CoordinatorComm(FleetComm):
    """Host 0: serves the rendezvous socket and participates in every
    round in-process. Accepts H-1 peers at startup, then keeps a
    lifetime accept thread so crashed hosts can dial back in mid-run
    (admission bumps the membership epoch; a reconnect under an id that
    is still live supersedes the stale socket — latest lease wins).

    Strict rounds collect one tagged payload per live member (skimming
    stale leftovers a resurrected host re-sent), refresh membership
    every poll tick so mid-round joins are waited for, and raise
    ``TimeoutError`` naming the hosts still missing at
    ``round_timeout_s``. Fold rounds wait at most ``lease_s``, drain
    each member's queue to its freshest payload, and leave ``None`` in
    the slots of dead or late hosts. Wire errors (EOF/RST — the SIGKILL
    signature) remove membership immediately in either mode; with
    ``max_missed_folds=k``, a connected-but-silent host is also evicted
    after k consecutive missed fold leases."""

    def __init__(self, address: Tuple[str, int], num_hosts: int,
                 authkey: bytes = DEFAULT_AUTHKEY, timeout_s: float = 120.0,
                 round_timeout_s: float = 120.0, lease_s: float = 5.0,
                 max_missed_folds: Optional[int] = None,
                 n_total: Optional[int] = None):
        self.num_hosts, self.host_id = int(num_hosts), 0
        self.round_timeout_s = float(round_timeout_s)
        self.lease_s = float(lease_s)
        self.max_missed_folds = max_missed_folds
        self._n_total = n_total
        # backlog sized to the fleet: H-1 peers dial at once during
        # rendezvous, and the default backlog of 1 bounces the rest
        # into ~1s of connect backoff each
        self._listener = Listener(address, backlog=num_hosts + 1,
                                  authkey=authkey)
        self.address = self._listener.address
        self._lock = threading.Lock()
        self._conns: Dict[int, Any] = {}
        self._epoch = 0
        self._dead: Dict[int, str] = {}
        self._misses: Dict[int, int] = {}
        self._stash: Dict[int, Dict[str, Any]] = {}
        self._closing = False
        # a peer that dies before connecting must fail the rendezvous
        # fast, not hang host 0 (and CI) until the job timeout. A
        # timeout on the listening socket is the only reliable way to
        # bound the blocking accept (closing the listener from another
        # thread does NOT wake accept on Linux); accepted connections
        # come back blocking, so gather rounds are unaffected.
        sock = getattr(getattr(self._listener, "_listener", None),
                       "_socket", None)
        if sock is not None:
            sock.settimeout(timeout_s)
        deadline = time.monotonic() + timeout_s
        while len(self._conns) < num_hosts - 1:
            try:
                conn = self._listener.accept()
            except OSError:
                self._listener.close()
                raise TimeoutError(
                    f"fleet rendezvous: {len(self._conns) + 1}/"
                    f"{num_hosts} hosts checked in after {timeout_s}s"
                ) from None
            self._admit(conn, rejoined=False,
                        handshake_s=max(0.1, deadline - time.monotonic()))
        # post-rendezvous: keep accepting for the fleet's lifetime so
        # dead hosts can resurrect; a short socket timeout lets the
        # thread observe close()
        if sock is not None:
            sock.settimeout(1.0)
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          daemon=True)
        self._acceptor.start()

    # -- membership ----------------------------------------------------
    def _admit(self, conn, rejoined: bool, handshake_s: float = 10.0):
        """Handshake (peer sends its host_id) and register the peer; an
        id that is still registered supersedes its stale socket. Bad
        handshakes close the connection without killing the fleet."""
        try:
            if not conn.poll(handshake_s):
                conn.close()
                return
            peer = int(conn.recv())
        except (EOFError, OSError, ValueError, TypeError):
            conn.close()
            return
        if not 0 < peer < self.num_hosts:
            conn.close()
            return
        with self._lock:
            if peer in self._conns:
                self._mark_dead_locked(peer, "superseded by reconnect")
            self._conns[peer] = conn
            self._epoch += 1
            self._dead.pop(peer, None)
            self._misses.pop(peer, None)
            self._stash.pop(peer, None)
            try:
                conn.send(("__join__", self._fleet_epoch_locked(), rejoined))
            except (OSError, ValueError):
                self._mark_dead_locked(peer, "join ack failed")

    def _accept_loop(self):
        while not self._closing:
            try:
                conn = self._listener.accept()
            except Exception:
                # socket timeout tick, close()'s waker dial, or a peer
                # failing the HMAC handshake — none may kill the
                # fleet's rejoin path
                if self._closing:
                    return
                continue
            self._admit(conn, rejoined=True)

    def _mark_dead_locked(self, host: int, reason: str):
        conn = self._conns.pop(host, None)
        if conn is None:
            return
        try:
            conn.close()
        except OSError:
            pass
        self._epoch += 1
        self._dead[host] = reason
        self._misses.pop(host, None)
        self._stash.pop(host, None)

    def _mark_dead(self, host: int, reason: str):
        with self._lock:
            self._mark_dead_locked(host, reason)

    def _fleet_epoch_locked(self) -> FleetEpoch:
        members = tuple(sorted([0, *self._conns]))
        stripes = (stripe_map(self._n_total, members)
                   if self._n_total else None)
        return FleetEpoch(self._epoch, members, stripes)

    def fleet_epoch(self) -> FleetEpoch:
        with self._lock:
            return self._fleet_epoch_locked()

    def dead_hosts(self) -> Dict[int, str]:
        """host_id -> reason for every host that has left the fleet
        (cleared again if it rejoins)."""
        with self._lock:
            return dict(self._dead)

    # -- rounds --------------------------------------------------------
    def _round(self, tag: str, payload: Any, strict: bool) -> List[Any]:
        results: Dict[int, Any] = {0: payload}
        deadline = time.monotonic() + (self.round_timeout_s if strict
                                       else self.lease_s)
        while True:
            with self._lock:
                live = dict(self._conns)
                if strict:
                    for h, stash in self._stash.items():
                        if h not in results and tag in stash:
                            results[h] = stash.pop(tag)
            pending = {h: c for h, c in live.items() if h not in results}
            if not pending:
                break
            left = deadline - time.monotonic()
            if left <= 0:
                break
            # short poll ticks so mid-round joins/deaths are picked up
            for conn in conn_wait(list(pending.values()),
                                  timeout=min(left, 0.25)):
                h = next(hh for hh, cc in pending.items() if cc is conn)
                try:
                    got = self._drain(h, conn, tag, strict)
                except (EOFError, ConnectionResetError, OSError):
                    self._mark_dead(h, "connection lost")
                    continue
                if got is not None:
                    results[h] = got[0]
        with self._lock:
            missing = [h for h in self._conns if h not in results]
        if strict and missing:
            raise TimeoutError(
                f"strict gather {tag!r}: hosts {sorted(missing)} still "
                f"missing after {self.round_timeout_s}s (live members "
                f"{sorted([0, *live])}, dead {self.dead_hosts()})"
            )
        with self._lock:
            for h in live:
                if h in results:
                    self._misses.pop(h, None)
                elif h in self._conns:
                    self._misses[h] = self._misses.get(h, 0) + 1
                    if (self.max_missed_folds is not None
                            and self._misses[h] >= self.max_missed_folds):
                        self._mark_dead_locked(
                            h, f"lease expired ({self.max_missed_folds} "
                               "consecutive missed folds)")
        out = [results.get(h) for h in range(self.num_hosts)]
        self._broadcast(("__round__", tag, self.fleet_epoch(), out))
        return out

    def _drain(self, host: int, conn, tag: str, strict: bool):
        """Consume ``host``'s queued messages. Strict: stash off-tag
        strict payloads for their own round, skim (drop) stale folds,
        return the matching payload if present. Fold: return the
        freshest fold payload, stashing any strict payloads untouched
        (a host far ahead must not have its barrier send eaten)."""
        got = None
        while True:
            peer_id, msg_tag, data, msg_strict = conn.recv()
            if msg_strict:
                if strict and msg_tag == tag:
                    return (data,)
                # under the lock: the acceptor thread's _admit does
                # `self._stash.pop(peer)` on a rejoin, and an unlocked
                # setdefault here can resurrect the orphaned inner dict
                # and silently lose this strict payload
                with self._lock:
                    self._stash.setdefault(host, {})[msg_tag] = data
            elif not strict:
                got = (data,)  # freshest fold wins
            # strict rounds skim (drop) stale fold leftovers
            if not conn.poll(0):
                return got

    def _broadcast(self, envelope) -> None:
        with self._lock:
            for h, conn in list(self._conns.items()):
                try:
                    conn.send(envelope)
                except (OSError, ValueError):
                    self._mark_dead_locked(h, "broadcast failed")

    def allgather(self, payload: Any, tag: str) -> List[Any]:
        return self._round(tag, payload, strict=True)

    def fold(self, payload: Any, tag: str) -> List[Any]:
        return self._round(tag, payload, strict=False)

    def close(self) -> None:
        self._closing = True
        # closing a listening socket does NOT interrupt a blocked accept
        # on Linux; a throwaway dial does (it fails the HMAC handshake
        # and the accept loop sees _closing on the way around)
        if isinstance(self.address, tuple):
            try:
                socket.create_connection(self.address, timeout=0.2).close()
            except OSError:
                pass
        self._listener.close()
        acceptor = getattr(self, "_acceptor", None)
        if acceptor is not None:
            acceptor.join(timeout=2.0)
        with self._lock:
            for conn in self._conns.values():
                try:
                    conn.close()
                except OSError:
                    pass
            self._conns.clear()


class ClientComm(FleetComm):
    """Hosts 1..H-1: dial the coordinator (bounded retry with
    exponential backoff while host 0 comes up — or comes BACK up), then
    mirror its rounds. The join ACK says whether this comm was admitted
    to an already-running fleet (``rejoined``), and every round envelope
    refreshes the cached :meth:`fleet_epoch`."""

    def __init__(self, address: Tuple[str, int], num_hosts: int, host_id: int,
                 authkey: bytes = DEFAULT_AUTHKEY, timeout_s: float = 60.0,
                 round_timeout_s: float = 150.0):
        self.num_hosts, self.host_id = int(num_hosts), int(host_id)
        self.round_timeout_s = float(round_timeout_s)
        deadline = time.monotonic() + timeout_s
        delay, attempts = 0.05, 0
        while True:
            try:
                self._conn = Client(address, authkey=authkey)
                break
            except (ConnectionError, EOFError, OSError):
                attempts += 1
                if time.monotonic() + delay > deadline:
                    raise TimeoutError(
                        f"host {host_id}: coordinator {address} not "
                        f"accepting after {attempts} attempts over "
                        f"{timeout_s}s — is host 0 up, and do both ends "
                        "share FLEET_AUTHKEY?"
                    ) from None
                time.sleep(delay)
                delay = min(delay * 2, 1.0)
        self._conn.send(self.host_id)
        if not self._conn.poll(max(1.0, deadline - time.monotonic())):
            raise TimeoutError(
                f"host {host_id}: coordinator accepted the connection "
                "but sent no join ACK (handshake stalled)"
            )
        kind, epoch, rejoined = self._conn.recv()
        assert kind == "__join__", kind
        self._epoch: FleetEpoch = epoch
        self.rejoined = bool(rejoined)
        self._last_round: Optional[List[Any]] = None

    def _read(self, msg) -> Optional[Tuple[str, List[Any]]]:
        kind = msg[0]
        if kind == "__round__":
            _, tag, epoch, out = msg
            self._epoch = epoch
            self._last_round = out
            return tag, out
        if kind == "__join__":
            self._epoch = msg[1]
        return None

    def allgather(self, payload: Any, tag: str) -> List[Any]:
        """Strict: send, then block for THIS tag's round envelope
        (skimming fold results broadcast in between)."""
        self._send(tag, payload, strict=True)
        deadline = time.monotonic() + self.round_timeout_s
        while True:
            if not self._conn.poll(max(0.0, deadline - time.monotonic())):
                raise TimeoutError(
                    f"host {self.host_id}: no {tag!r} round result after "
                    f"{self.round_timeout_s}s — coordinator gone?"
                )
            got = self._read(self._recv())
            if got is not None and got[0] == tag:
                return got[1]

    def fold(self, payload: Any, tag: str) -> Optional[List[Any]]:
        """Stale-tolerant: send, drain whatever envelopes have arrived,
        return the latest known round result (None before the first one
        lands). Never blocks — a behind host can't stall the fleet and
        the fleet can't stall it."""
        self._send(tag, payload, strict=False)
        while self._conn.poll(0):
            self._read(self._recv())
        return self._last_round

    def _send(self, tag: str, payload: Any, strict: bool) -> None:
        try:
            self._conn.send((self.host_id, tag, payload, strict))
        except (OSError, ValueError):
            raise RuntimeError(
                f"host {self.host_id}: coordinator connection lost "
                "(evicted or superseded?) — restart this host to rejoin"
            ) from None

    def _recv(self):
        try:
            return self._conn.recv()
        except (EOFError, ConnectionResetError, OSError):
            raise RuntimeError(
                f"host {self.host_id}: coordinator connection lost "
                "(evicted or superseded?) — restart this host to rejoin"
            ) from None

    def fleet_epoch(self) -> FleetEpoch:
        return self._epoch

    def close(self) -> None:
        self._conn.close()


def connect_fleet(num_hosts: int, host_id: int,
                  address: Optional[Tuple[str, int]] = None,
                  authkey: bytes = DEFAULT_AUTHKEY, **kw) -> FleetComm:
    """The one entry point: host 0 serves, the rest connect (with
    bounded retry-with-backoff while the listener comes up), H=1 is a
    no-op comm. Blocks until the whole fleet has checked in — or, for a
    client dialing an already-running fleet, until it is admitted as a
    rejoining member (``comm.rejoined``)."""
    if num_hosts == 1:
        return NullComm()
    if address is None:
        raise ValueError("multi-host fleets need a coordinator address")
    if host_id == 0:
        return CoordinatorComm(address, num_hosts, authkey=authkey, **kw)
    return ClientComm(address, num_hosts, host_id, authkey=authkey, **kw)


# ---------------------------------------------------------------------------
# the distributed controller: one stripe per process, zero per-interval
# collectives, periodic stripe checkpoints
# ---------------------------------------------------------------------------


class DistributedFleetController:
    """One controller process's share of the fleet: a local
    :class:`EnergyController` over the host's backend stripe and policy
    lanes, plus the comm used ONLY for periodic fleet-level aggregates.

    Build with :meth:`from_global` (each process constructs the same
    full-fleet description, then slices its own stripe — parity by
    construction) or pass an already-local backend with its ``stripe``.
    ``step``/``run`` never touch the network; ``fleet_summary`` and the
    optional ``report_every`` ticks gather H small summary dicts
    (stale-tolerant folds, so a dead host degrades the aggregate to the
    live stripes instead of blocking the fleet).

    ``checkpoint_dir`` + ``checkpoint_every`` enable periodic stripe
    checkpoints (train.checkpoint async_save under
    ``<dir>/stripe_<lo>_<hi>/``) of the fused-kernel controller state,
    the backend cursor/env rows and the arm log, all keyed by the
    GLOBAL interval index — so a crash-restarted host
    (:meth:`try_restore`) resumes bit-exact, and an elastically
    re-striped one (:func:`restore_fleet_controller`) stitches its new
    stripe from whatever stripes were saved."""

    def __init__(self, policy: Policy, local_backend: EnergyBackend,
                 comm: Optional[FleetComm] = None,
                 stripe: Optional[Tuple[int, int]] = None,
                 n_total: Optional[int] = None, seed: int = 0,
                 use_kernel: Optional[bool] = None, interpret: bool = False,
                 record_history: bool = False, mesh=None,
                 log_arms: bool = False, checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0, keep_last: int = 3):
        self.comm = comm or NullComm()
        self.stripe = stripe or (0, local_backend.n_nodes)
        self.n_total = int(n_total or local_backend.n_nodes)
        self.n_local = int(local_backend.n_nodes)
        self.comm.set_fleet_size(self.n_total)
        self.controller = EnergyController(
            policy, local_backend, seed=seed, use_kernel=use_kernel,
            interpret=interpret, record_history=record_history, mesh=mesh,
        )
        self.log_arms = log_arms
        self.arm_log: List[np.ndarray] = []
        self.reports: List[Dict[str, Any]] = []
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = int(checkpoint_every)
        self.keep_last = int(keep_last)
        # GLOBAL interval index (survives crash-restart restores), the
        # key for checkpoint/report cadences so a resumed host realigns
        # with the fleet's tick boundaries
        self.interval = 0

    @classmethod
    def from_global(cls, policy: Policy, backend: EnergyBackend,
                    comm: FleetComm, **kw) -> "DistributedFleetController":
        """Slice this host's stripe out of the full-fleet backend and
        policy lanes. Every host calls this with the SAME (policy,
        backend) description; H=1 degenerates to the whole fleet."""
        n = int(backend.n_nodes)
        lo, hi = host_stripe(n, comm.num_hosts, comm.host_id)
        local = backend if comm.num_hosts == 1 else backend.local_slice(lo, hi)
        return cls(slice_policy_lanes(policy, lo, hi, n), local, comm,
                   stripe=(lo, hi), n_total=n, **kw)

    @property
    def use_kernel(self) -> bool:
        return self.controller.use_kernel

    def step(self, work_fn: Optional[Callable[[], Any]] = None) -> Dict[str, Any]:
        """One host-local decision interval — no collectives."""
        rec = self.controller.step(work_fn)
        self.interval += 1
        if self.log_arms:
            self.arm_log.append(
                np.asarray(self.controller.last_arms).reshape(self.n_local)
            )
        return rec

    def run(self, n_intervals: int,
            work_fn: Optional[Callable[[], Any]] = None,
            report_every: int = 0,
            on_report: Optional[Callable[[int, Dict[str, Any]], None]] = None,
            episode_scan: bool = False,
            ) -> Dict[str, Any]:
        """Drive the stripe for ``n_intervals``; every ``report_every``
        intervals (0 = never) fold the fleet aggregate and append it to
        ``self.reports`` (``on_report(interval, fleet_summary)`` fires
        on every host that has a round result — the coordinator always
        does; clients may lag a tick, that's the stale-fold contract).
        Cadences key off the GLOBAL interval index, so a resumed host
        realigns with the fleet's boundaries. Returns the final fleet
        summary (a STRICT gather: every live stripe contributes).

        ``episode_scan=True`` advances the stripe in fused episode-scan
        chunks (``EnergyController.run_scanned`` — one dispatch per
        chunk up to the next report/checkpoint boundary) instead of
        per-interval steps. Striping is unaffected: the scan is
        host-local (noise is keyed by global node id, the drift
        schedule by global interval index), and the reporting/arm-log/
        checkpoint cadences are preserved. ``work_fn`` cannot run
        inside a fused episode."""
        ckpt_every = self.checkpoint_every if self.checkpoint_dir else 0

        def tick():
            if ckpt_every and self.interval % ckpt_every == 0:
                self.save_checkpoint()
            if report_every and self.interval % report_every == 0:
                fleet = self.fleet_summary(tag=f"report-{self.interval}",
                                           strict=False)
                if fleet is not None:
                    self.reports.append(fleet)
                    if on_report is not None:
                        on_report(self.interval, fleet)

        if episode_scan:
            if work_fn is not None:
                raise ValueError(
                    "episode_scan fuses whole intervals on-device; "
                    "per-interval work_fn needs the streaming path"
                )
            done = 0
            while done < n_intervals:
                chunk = n_intervals - done
                for every in (report_every, ckpt_every):
                    if every:
                        chunk = min(chunk, every - self.interval % every)
                self.controller.run_scanned(chunk)
                if self.log_arms:
                    self.arm_log.extend(
                        np.asarray(self.controller.last_episode_arms)
                        .reshape(chunk, self.n_local)
                    )
                done += chunk
                self.interval += chunk
                tick()
        else:
            for _ in range(n_intervals):
                self.step(work_fn)
                tick()
        if self.checkpoint_dir:
            # the end state is always resumable, whatever the cadence
            self.save_checkpoint(block=True)
        return self.fleet_summary(tag="final")

    def local_summary(self) -> Dict[str, Any]:
        return self.controller.summary()

    def fleet_summary(self, tag: str = "summary",
                      strict: bool = True) -> Optional[Dict[str, Any]]:
        """The fleet aggregate. Strict gathers every live stripe (and
        raise if one goes silent); stale-tolerant folds reduce whatever
        stripes the lease window delivered — identical to strict while
        the whole fleet is alive and on pace — and may return ``None``
        on a client before its first round result arrives."""
        local = self.local_summary()
        if strict:
            gathered = self.comm.allgather(local, tag=tag)
        else:
            gathered = self.comm.fold(local, tag=tag)
            if gathered is None:
                return None
        live = [s for s in gathered if s is not None]
        return reduce_summaries(live if live else [local])

    def gather_arms(self, tag: str = "arms") -> np.ndarray:
        """The full fleet's (T, N) arm trajectory, assembled from every
        host's stripe log (requires ``log_arms=True``) — the parity
        oracle against a single-process run. Raises if any live stripe
        is missing (use the per-host ``arm_log`` + stripes for partial
        fleets)."""
        if not self.log_arms:
            raise RuntimeError("construct with log_arms=True to gather arms")
        local = (np.stack(self.arm_log) if self.arm_log
                 else np.zeros((0, self.n_local), np.int32))
        gathered = self.comm.allgather(local, tag=tag)
        if any(g is None for g in gathered):
            raise RuntimeError(
                f"gather_arms: hosts "
                f"{[h for h, g in enumerate(gathered) if g is None]} "
                "are dead; their stripes' logs live in their checkpoints"
            )
        return np.concatenate(gathered, axis=1)

    # -- checkpoint surface --------------------------------------------
    @property
    def checkpoint_path(self) -> Optional[str]:
        """This stripe's checkpoint directory under ``checkpoint_dir``."""
        if self.checkpoint_dir is None:
            return None
        return _ckpt().stripe_dir(self.checkpoint_dir, *self.stripe)

    def state_dict(self) -> Dict[str, Any]:
        """Everything a resumed process needs, split per the stripe
        contract: controller policy state + pre-selected arms + counter
        snapshots, backend env rows/cursor and the (n_local, T) arm log
        under ``"striped"``; RNG key chains and the global interval
        under ``"host"`` (identical across hosts at a common interval,
        which is what lets restore_stripe stitch elastic restripes)."""
        c = self.controller.state_dict()
        b = self.controller.backend.state_dict()
        log = (np.stack(self.arm_log, axis=1).astype(np.int32)
               if self.arm_log else np.zeros((self.n_local, 0), np.int32))
        return {
            "striped": {"controller": c["striped"], "backend": b["striped"],
                        "arm_log": log},
            "host": {"controller": c["host"], "backend": b["host"],
                     "interval": np.int64(self.interval)},
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        s, h = state["striped"], state["host"]
        self.controller.load_state_dict(
            {"striped": s["controller"], "host": h["controller"]})
        self.controller.backend.load_state_dict(
            {"striped": s["backend"], "host": h["backend"]})
        log = np.asarray(s["arm_log"])
        self.arm_log = [log[:, t] for t in range(log.shape[1])]
        self.interval = int(h["interval"])

    def save_checkpoint(self, block: bool = False) -> None:
        """Checkpoint this stripe at the current global interval
        (async by default — serialization rides a background thread
        with one-in-flight backpressure; ``block=True`` for the final
        save). No-op without a ``checkpoint_dir``."""
        path = self.checkpoint_path
        if path is None:
            return
        extra = {"stripe": list(self.stripe), "n_total": self.n_total,
                 "interval": self.interval}
        if block:
            _ckpt().wait_for_saves(path)
            _ckpt().save(path, self.interval, self.state_dict(), extra,
                      self.keep_last)
        else:
            _ckpt().async_save(path, self.interval, self.state_dict(), extra,
                            self.keep_last)

    def try_restore(self, step: Optional[int] = None) -> bool:
        """Resume from the latest (or given) checkpoint covering this
        stripe, stitching across saved stripes if the layout changed.
        Returns False when there is nothing to restore (fresh start)."""
        if self.checkpoint_dir is None:
            return False
        try:
            _, state, _ = _ckpt().restore_stripe(
                self.checkpoint_dir, *self.stripe, like=self.state_dict(),
                step=step)
        except FileNotFoundError:
            return False
        self.load_state_dict(state)
        return True


def restore_fleet_controller(
    policy: Policy,
    backend_factory: Callable[[int, int], EnergyBackend],
    lo: int, hi: int, n_total: int,
    checkpoint_dir: str,
    comm: Optional[FleetComm] = None,
    step: Optional[int] = None,
    **kw,
) -> DistributedFleetController:
    """Elastic rebuild: construct the [lo, hi) stripe of an N-node fleet
    (``backend_factory(lo, hi)`` builds the local backend — e.g.
    fleet_serve.build_local_backend) and restore it from the per-stripe
    checkpoints under ``checkpoint_dir``, whatever stripe layout saved
    them. This is how a membership change is APPLIED: take the new
    stripe bounds from the coordinator's epoch-stamped stripe map
    (``comm.fleet_epoch().stripes``), rebuild, continue — the restored
    state is the common-step stitch of the old stripes, so the rebuilt
    fleet replays exactly like one that ran at the new size all along.
    Raises FileNotFoundError if no saved stripes cover [lo, hi)."""
    local = backend_factory(lo, hi)
    ctl = DistributedFleetController(
        slice_policy_lanes(policy, lo, hi, n_total), local, comm,
        stripe=(lo, hi), n_total=n_total, checkpoint_dir=checkpoint_dir,
        **kw)
    if not ctl.try_restore(step=step):
        raise FileNotFoundError(
            f"no stripe checkpoints covering [{lo}, {hi}) under "
            f"{checkpoint_dir}"
        )
    return ctl
