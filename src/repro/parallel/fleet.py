"""Sharded fleet step: (N, K) controller state over the mesh's data axis.

One chip's VMEM comfortably holds tens of thousands of controllers (the
fused kernel streams BLOCK_N stripes), but Aurora-scale fleets (63,720
controllers) with per-controller hyperparameter lanes — or fleets grown
past that — eventually exceed a single device. The controller step is
embarrassingly row-parallel: every node's update-then-select touches
only its own (K,) slice, so the whole step ``shard_map``s over the
mesh's data axis with ZERO collectives — each device runs the fused
Pallas kernel (kernels/fleet_ucb.fleet_step) on its own N/D stripe, and
state never leaves the device between intervals.

Bit-parity with the single-device kernel is asserted in
tests/test_sharding.py (in-process on the host mesh, and on a forced
8-device mesh in a subprocess).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.kernels.fleet_ucb import _pad, fleet_step


def fleet_mesh(devices: Optional[Sequence] = None, axis: str = "data") -> Mesh:
    """A 1-D controller mesh over the given (default: all) devices.

    Under ``jax.distributed`` initialization ``jax.devices()`` spans
    every controller process, so this is also the process-spanning mesh
    for multi-host fused steps; a host that only wants to shard its own
    stripe across local chips passes ``jax.local_devices()``."""
    devs = np.asarray(jax.devices() if devices is None else list(devices))
    return Mesh(devs.reshape(-1), (axis,))


def stripe_bounds(n: int, num_hosts: int):
    """Contiguous per-host stripes [(lo, hi), ...] covering an N-node
    fleet: host h owns ceil-balanced rows, ragged remainders going to
    the leading hosts (each stripe's fused step then reuses the
    kernel's own BLOCK_N padding — see kernels.fleet_ucb._pad — so no
    host-level padding convention is needed on top)."""
    if not 1 <= num_hosts <= n:
        raise ValueError(f"need 1 <= num_hosts <= n, got H={num_hosts}, N={n}")
    base, rem = divmod(n, num_hosts)
    bounds, lo = [], 0
    for h in range(num_hosts):
        hi = lo + base + (1 if h < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def host_stripe(n: int, num_hosts: int, host_id: int):
    """This host's (lo, hi) stripe of the fleet's node axis."""
    if not 0 <= host_id < num_hosts:
        raise ValueError(f"host_id {host_id} out of range for H={num_hosts}")
    return stripe_bounds(n, num_hosts)[host_id]


def stripe_map(n: int, members):
    """host_id -> (lo, hi) over an arbitrary LIVE member set: the elastic
    re-striping of an N-node fleet after membership changed (the
    coordinator broadcasts this, epoch-stamped, on every death/join —
    see parallel.distributed.FleetEpoch). Stripes go to members in
    ascending host_id order with the same ceil-balanced bounds a fresh
    H=len(members) fleet would use, so a rebalanced fleet is
    indistinguishable from one launched at the new size."""
    ids = sorted(set(int(m) for m in members))
    if not ids:
        raise ValueError("stripe_map needs at least one live member")
    return dict(zip(ids, stripe_bounds(n, len(ids))))


def make_sharded_fleet_step(
    mesh: Mesh, axis: str = "data", block_n: int = 1024,
    interpret: bool = False, k_unc: int = 1,
) -> Callable:
    """Build the jitted sharded fleet step for ``mesh``.

    Returns ``step(mu, n, phat, pn, prev, t, arm, reward, progress,
    active, alpha, lam, qos, def_arm, gamma, optimistic, prior_mu,
    lam_unc) -> (mu, n, phat, pn, prev, t, next_arm)`` with every array
    sharded on its leading N axis over ``axis``. Scalar hyperparameters
    broadcast to (N,) lanes first (``prior_mu`` to its (N, K) lane), and
    ragged fleets are padded to a shard multiple with inactive (frozen)
    controllers — same convention as the kernel's stripe padding — then
    sliced back. ``k_unc`` is the factored-ladder static (1 = scalar);
    row parallelism is factorization-blind, so the sharding story is
    unchanged — the static just rides into each shard's kernel.
    """
    n_shards = int(mesh.shape[axis])
    kernel = functools.partial(fleet_step, k_unc=k_unc, block_n=block_n,
                               interpret=interpret)
    row, mat = P(axis), P(axis, None)
    sharded = shard_map(
        kernel, mesh=mesh,
        in_specs=(mat, mat, mat, mat, row, row, row, row, row, row, row,
                  row, row, row, row, row, mat, row),
        out_specs=(mat, mat, mat, mat, row, row, row),
        check_rep=False,  # pallas_call has no replication rule
    )

    @jax.jit
    def step(mu, n, phat, pn, prev, t, arm, reward, progress, active,
             alpha, lam, qos, def_arm, gamma=1.0, optimistic=1.0,
             prior_mu=0.0, lam_unc=-1.0):
        nn, k = mu.shape
        lane = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (nn,))
        ilane = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.int32), (nn,))
        args = [
            mu, n, phat, pn, ilane(prev), lane(t), ilane(arm),
            lane(reward), lane(progress), lane(active),
            lane(alpha), lane(lam), lane(qos), ilane(def_arm),
            lane(gamma), lane(optimistic),
            jnp.broadcast_to(jnp.asarray(prior_mu, jnp.float32), (nn, k)),
            lane(lam_unc),
        ]
        pad = (-nn) % n_shards
        if pad:
            fills = (0, 1, 0, 1, 0, 2.0, 0, 0, 0, 0, 0, 0, -1.0, 0,
                     1.0, 1.0, 0, -1.0)
            args = [_pad(a, pad, f) for a, f in zip(args, fills)]
        out = sharded(*args)
        return tuple(o[:nn] for o in out) if pad else out

    return step
