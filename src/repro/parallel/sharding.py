"""Logical-axis sharding rules (MaxText-style) and the Sharder helper.

Models name tensor dims with *logical* axes; the rules table maps logical
axes onto mesh axes that exist in the current mesh. One mesh axis is never
assigned twice within a single spec (first logical axis wins), which lets
e.g. ``seq -> model`` (sequence parallelism) coexist with ``heads ->
model`` (tensor parallelism) across different tensors.

Parallelism scheme encoded by DEFAULT_RULES:
  - batch        -> ("pod", "data")   pure DP across pods, DP within pod
  - embed_fsdp   -> ("data",)         ZeRO-3/FSDP weight sharding in-pod
  - tp / heads / vocab / experts -> ("model",)  tensor/expert parallelism
  - seq          -> ("model",)        sequence-parallel residual stream
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

LogicalAxis = Optional[str]

# "2d": FSDP over "data" x TP over "model" (+ pure DP over "pod").
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),
    "heads": ("model",),
    "kv_heads": (),
    "head_dim": ("model",),
    "tp": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "embed": (),
    "embed_fsdp": ("data",),
    "experts": ("model",),
    "ssm_heads": ("model",),
    "layers": (),
    "state": (),
    "capacity": (),
    "frames": (),
    "expert_group": ("data",),
}

# "fsdp": no tensor parallelism — batch and weight-shard both span the
# whole pod (data x model). Right profile for <5B models at train time:
# zero per-layer activation collectives; only weight all-gathers +
# gradient reduce-scatters. (Multi-pod runs fall back to "2d"; a 256
# batch cannot shard 512 ways.)
FSDP_RULES: Dict[str, Tuple[str, ...]] = {
    **{k: () for k in DEFAULT_RULES},
    "batch": ("data", "model"),
    "embed_fsdp": ("data", "model"),
}

# "serve": weight-stationary decoding for models that fit TP-sharded on
# one model row (<=~16B bf16): weights replicated across "data", TP over
# "model"; batch over "data". Zero per-token weight gathers — per-layer
# collectives shrink to O(batch x d_model) activation reductions.
SERVE_RULES: Dict[str, Tuple[str, ...]] = {
    **DEFAULT_RULES,
    "embed_fsdp": (),
    "seq": (),
}

# "serve2d": 400B-class decoding — weights 2D-sharded (D -> data,
# heads/ffn -> model; nothing re-gathered per token), activations
# replicated (partial-sum reductions are O(batch x d_model)), KV cache
# sharded batch -> data, head_dim -> model.
SERVE2D_RULES: Dict[str, Tuple[str, ...]] = {
    **DEFAULT_RULES,
    "batch": (),
    "cache_batch": ("data",),
    "seq": (),
}
DEFAULT_RULES["cache_batch"] = ("pod", "data")
FSDP_RULES["cache_batch"] = ("data", "model")
SERVE_RULES["cache_batch"] = ("pod", "data")

PROFILES = {
    "2d": DEFAULT_RULES,
    "fsdp": FSDP_RULES,
    "serve": SERVE_RULES,
    "serve2d": SERVE2D_RULES,
}


def rules_for(profile: str) -> Dict[str, Tuple[str, ...]]:
    return dict(PROFILES[profile])


def spec_for_axes(
    axes: Sequence[LogicalAxis],
    rules: Dict[str, Tuple[str, ...]],
    mesh_axis_names: Sequence[str],
) -> P:
    used: set = set()
    out = []
    for ax in axes:
        if ax is None:
            out.append(None)
            continue
        if ax not in rules:
            raise KeyError(f"unknown logical axis {ax!r}")
        mesh_axes = tuple(
            m for m in rules[ax] if m in mesh_axis_names and m not in used
        )
        used.update(mesh_axes)
        if len(mesh_axes) == 0:
            out.append(None)
        elif len(mesh_axes) == 1:
            out.append(mesh_axes[0])
        else:
            out.append(mesh_axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


class Sharder:
    """Applies logical-axis sharding constraints; no-op without a mesh.

    Models receive a Sharder so the same code runs (a) un-meshed on CPU in
    smoke tests, (b) under the production mesh in the dry-run/launcher.
    """

    def __init__(
        self,
        mesh: Optional[Mesh] = None,
        rules: Optional[Dict[str, Tuple[str, ...]]] = None,
        seq_parallel: bool = True,
        profile: str = "2d",
    ):
        self.mesh = mesh
        base = rules_for(profile) if rules is None else rules
        self.rules = dict(base)
        if not seq_parallel or profile == "fsdp":
            self.rules["seq"] = ()

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    def spec(self, *axes: LogicalAxis) -> P:
        return spec_for_axes(axes, self.rules, self.axis_names)

    def _axis_size(self, name: str) -> int:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[name]

    def _fit_spec_to_shape(self, spec: P, shape) -> P:
        """Drop mesh axes that do not divide the corresponding dim (e.g.
        batch=1 long-context decode, odd vocab) — degrade, don't fail."""
        out = []
        for i, entry in enumerate(tuple(spec)):
            if entry is None or i >= len(shape):
                out.append(entry)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            kept = []
            prod = 1
            for a in axes:
                sz = self._axis_size(a)
                if shape[i] % (prod * sz) == 0:
                    kept.append(a)
                    prod *= sz
            out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def named(self, *axes: LogicalAxis, shape=None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        spec = self.spec(*axes)
        if shape is not None:
            spec = self._fit_spec_to_shape(spec, tuple(shape))
        return NamedSharding(self.mesh, spec)

    def act(self, x: jax.Array, *axes: LogicalAxis) -> jax.Array:
        """Constrain an activation's sharding (no-op without a mesh)."""
        if self.mesh is None:
            return x
        if len(axes) != x.ndim:
            raise ValueError(
                f"rank mismatch: {len(axes)} logical axes for rank-{x.ndim}"
            )
        return jax.lax.with_sharding_constraint(
            x, self.named(*axes, shape=x.shape)
        )

    def params_sharding(self, logical_tree, shapes_tree=None):
        """Map a pytree of logical-axis tuples to NamedShardings; if
        shapes_tree (matching structure of ShapeDtypeStructs) is given,
        shardings are shape-fitted."""
        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        )
        if self.mesh is None:
            return jax.tree.map(lambda _: None, logical_tree, is_leaf=is_axes)
        if shapes_tree is None:
            return jax.tree.map(
                lambda axes: self.named(*axes), logical_tree, is_leaf=is_axes
            )
        return jax.tree.map(
            lambda axes, s: self.named(*axes, shape=s.shape),
            logical_tree,
            shapes_tree,
            is_leaf=is_axes,
        )
