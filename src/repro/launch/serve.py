"""Serving launcher CLI (batched greedy decoding).

  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-15b \
      --requests 8 --max-new 16 [--energy] [--qos 0.05]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch, get_reduced
from repro.core.policies import energy_ucb
from repro.energy import EnergyController, StepEnergyModel, make_backend
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-15b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--energy", action="store_true")
    ap.add_argument("--qos", type=float, default=None)
    ap.add_argument("--window-discount", type=float, default=None,
                    help="sliding-window discount gamma < 1 for "
                         "nonstationary serving loads")
    ap.add_argument("--warmup", action="store_true",
                    help="round-robin warm-up instead of optimistic init")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def build_policy(args):
    # --qos 0.0 is a valid (strictest) slowdown budget and
    # --window-discount 0.0 a valid (last-sample-only) window: dispatch
    # on `is None`, never on truthiness
    kw = {"qos_delta": args.qos}
    if args.window_discount is not None:
        kw["window_discount"] = args.window_discount
    if args.warmup:
        kw["optimistic_init"] = False
    return energy_ucb(**kw)


def main():
    args = parse_args()
    cfg = get_arch(args.arch) if args.full_config else get_reduced(args.arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(args.seed))
    controller = None
    if args.energy:
        pol = build_policy(args)
        model = StepEnergyModel(t_compute_s=0.01, t_memory_s=0.05,
                                t_collective_s=0.02, n_chips=4, steps_total=500)
        controller = EnergyController(pol, make_backend(model))
    eng = ServeEngine(bundle, params, n_slots=args.slots, max_len=args.max_len,
                      controller=controller)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(3, 10))).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    done = eng.generate(reqs)
    for r in done[:4]:
        print(f"req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}{'...' if len(r.out)>8 else ''}")
    print("stats:", eng.stats)
    if controller is not None:
        print({k: round(v, 2) if isinstance(v, float) else v
               for k, v in controller.summary().items()})


if __name__ == "__main__":
    main()
