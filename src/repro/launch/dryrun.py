import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
against the production mesh with ShapeDtypeStruct inputs (no allocation),
then extract memory/cost/collective facts for the roofline.

MUST set XLA_FLAGS before ANY other import (jax locks the device count on
first init) — hence the lines above.

Usage:
  python -m repro.launch.dryrun --arch starcoder2-15b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
  python -m repro.launch.dryrun --arch X --shape Y --multi-pod

--all forks one subprocess per cell (failure isolation + a fresh XLA
compilation cache per cell keeps memory bounded on the 1-core host).
"""
import argparse
import functools
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict

PyTree = Any


def _parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def _build_cell(arch: str, shape_name: str, multi_pod: bool, overrides=None):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_arch
    from repro.launch.input_specs import batch_logical_axes, input_specs
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.parallel.sharding import Sharder
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.train_step import make_train_step

    import dataclasses

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape_name not in cfg.supported_shapes():
        return None  # N/A by design (long_500k on quadratic archs)
    layout = cfg.layout_for(shape_name)
    if overrides:
        layout = dataclasses.replace(layout, **overrides)
    if multi_pod and layout.parallelism == "fsdp":
        # a 256-batch cannot shard 512 ways; cross-pod runs use 2d + pod-DP
        layout = dataclasses.replace(layout, parallelism="2d")
    mesh = make_production_mesh(multi_pod=multi_pod)
    sharder = Sharder(
        mesh, seq_parallel=layout.seq_parallel, profile=layout.parallelism
    )
    bundle = build_model(cfg, layout, sharder)

    params_shapes = jax.eval_shape(bundle.init, jax.random.key(0))
    p_shard = sharder.params_sharding(bundle.logical_axes(), params_shapes)
    batch_sds = input_specs(cfg, shape_name)
    b_axes = batch_logical_axes(cfg, shape.kind)
    b_shard = {
        k: sharder.named(*b_axes[k], shape=batch_sds[k].shape) for k in batch_sds
    }

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=layout.opt_dtype)
        opt_shapes = jax.eval_shape(
            functools.partial(adamw_init, opt_cfg), params_shapes
        )
        o_shard = {
            "m": p_shard,
            "v": p_shard,
            "count": NamedSharding(mesh, P()),
        }
        step = make_train_step(bundle, opt_cfg, layout)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        args = (params_shapes, opt_shapes, batch_sds)
    elif shape.kind == "prefill":
        jitted = jax.jit(bundle.prefill, in_shardings=(p_shard, b_shard))
        args = (params_shapes, batch_sds)
    else:  # decode
        cache_shapes = jax.eval_shape(
            functools.partial(bundle.init_cache, shape.global_batch, shape.seq_len)
        )
        c_shard = sharder.params_sharding(bundle.cache_logical_axes(), cache_shapes)
        jitted = jax.jit(
            bundle.decode,
            in_shardings=(p_shard, c_shard, b_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        args = (params_shapes, cache_shapes, batch_sds)
    return jitted, args, mesh, cfg, layout


def run_cell(arch: str, shape_name: str, multi_pod: bool, overrides=None) -> Dict[str, Any]:
    import jax

    from repro.roofline.hlo_parse import collective_bytes_from_hlo

    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "layout_overrides": overrides or {},
    }
    built = _build_cell(arch, shape_name, multi_pod, overrides)
    if built is None:
        rec["status"] = "skipped_na"
        return rec
    jitted, args, mesh, cfg, layout = built
    t0 = time.time()
    with mesh:
        lowered = jitted.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory_per_device"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_est_bytes": int(
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        ),
    }
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device program
        ca = ca[0] if ca else {}
    ca = ca or {}
    rec["hlo_cost"] = {
        "flops_raw": float(ca.get("flops", -1.0)),
        "bytes_raw": float(ca.get("bytes accessed", -1.0)),
        "note": "XLA counts while/scan bodies once; see roofline.analysis",
    }
    hlo = compiled.as_text()
    rec["hlo_chars"] = len(hlo)
    coll = collective_bytes_from_hlo(hlo)
    rec["collectives"] = {
        "per_kind_bytes": coll["per_kind"],
        "total_bytes_per_device": coll["total"],
        "op_sites": coll["count"],
    }
    rec["status"] = "ok"
    return rec


def out_path(outdir: str, arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "multipod" if multi_pod else "pod"
    return os.path.join(outdir, f"{arch}__{shape}__{mesh}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--set", action="append", dest="overrides", metavar="K=V",
        help="layout override, e.g. --set seq_parallel=False --set microbatch=32",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        from repro.configs import get_arch, list_archs

        cells = []
        for a in list_archs():
            for s in get_arch(a).supported_shapes():
                for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
                    cells.append((a, s, mp))
        failures = 0
        for a, s, mp in cells:
            path = out_path(args.out, a, s, mp)
            if os.path.exists(path) and not args.force:
                print(f"CACHED {path}")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", a, "--shape", s, "--out", args.out,
            ] + (["--multi-pod"] if mp else [])
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures += 1
                print(f"FAIL {a} {s} mp={mp}\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
            else:
                print(r.stdout.strip().splitlines()[-1] if r.stdout.strip() else "")
        print(f"dry-run sweep complete; failures={failures}")
        return 1 if failures else 0

    rec = {}
    try:
        rec = run_cell(
            args.arch, args.shape, args.multi_pod, _parse_overrides(args.overrides)
        )
    except Exception as e:
        rec = {
            "arch": args.arch, "shape": args.shape,
            "mesh": "2x16x16" if args.multi_pod else "16x16",
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        path = out_path(args.out, args.arch, args.shape, args.multi_pod)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "status")}))
        print(rec["error"], file=sys.stderr)
        return 1
    path = out_path(args.out, args.arch, args.shape, args.multi_pod)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    brief = {k: rec.get(k) for k in ("arch", "shape", "mesh", "status", "compile_s")}
    if rec.get("status") == "ok":
        brief["peak_gb"] = round(rec["memory_per_device"]["peak_est_bytes"] / 2**30, 2)
        brief["coll_gb"] = round(
            rec["collectives"]["total_bytes_per_device"] / 2**30, 3
        )
    print(json.dumps(brief))
    return 0


if __name__ == "__main__":
    sys.exit(main())
