"""Input stand-ins for every (arch x shape) cell.

``input_specs`` returns jax.ShapeDtypeStruct pytrees (weak-type-correct,
shardable, no device allocation) used by the dry-run; ``make_batch``
returns small concrete arrays for smoke tests / real runs with the same
structure.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig

PyTree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def train_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    emb_dtype = jnp.dtype(cfg.layout.param_dtype)
    if cfg.family == "vlm":
        P = cfg.num_img_patches
        return {
            "tokens": _sds((B, S - P), jnp.int32),
            "img_emb": _sds((B, P, cfg.d_model), emb_dtype),
            "labels": _sds((B, S), jnp.int32),
        }
    if cfg.family == "encdec":
        return {
            "frames": _sds((B, S, cfg.d_model), emb_dtype),
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
    return {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    sp = train_specs(cfg, shape)
    sp.pop("labels")
    return sp


def decode_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    return {"token": _sds((B,), jnp.int32), "index": _sds((), jnp.int32)}


def batch_logical_axes(cfg: ArchConfig, kind: str) -> Dict[str, tuple]:
    if kind == "decode":
        return {"token": ("batch",), "index": ()}
    ax: Dict[str, tuple] = {"tokens": ("batch", None)}
    if kind == "train":
        ax["labels"] = ("batch", None)
    if cfg.family == "vlm":
        ax["img_emb"] = ("batch", None, None)
    if cfg.family == "encdec":
        ax["frames"] = ("batch", None, None)
    return ax


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    kind = shape.kind
    if kind == "train":
        return train_specs(cfg, shape)
    if kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)


def make_batch(
    cfg: ArchConfig,
    shape: ShapeConfig,
    key: Optional[jax.Array] = None,
    kind: Optional[str] = None,
) -> PyTree:
    """Concrete random batch with the input_specs structure."""
    key = jax.random.key(0) if key is None else key
    kind = kind or shape.kind
    specs = {
        "train": train_specs,
        "prefill": prefill_specs,
        "decode": decode_specs,
    }[kind](cfg, shape)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if np.issubdtype(s.dtype, np.integer):
            if name == "index":
                out[name] = jnp.asarray(shape.seq_len // 2, s.dtype)
            else:
                hi = cfg.vocab_size if name in ("tokens", "token", "labels") else 2
                out[name] = jax.random.randint(sub, s.shape, 0, hi, s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)
    if "labels" in out and cfg.family == "vlm":
        P = cfg.num_img_patches
        out["labels"] = out["labels"].at[:, :P].set(-1)
    return out
