"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --steps 50 --batch 8 --seq 128 [--reduced] [--energy] [--ckpt DIR]

Uses the REDUCED config by default on this CPU container (--full-config
selects the real architecture; on actual hardware pair it with the
production mesh via repro.launch.mesh.make_production_mesh and the
layout's sharding profile — the dry-run proves those configs compile).
"""
from __future__ import annotations

import argparse

from repro.configs import get_arch, get_reduced
from repro.configs.base import ShapeConfig
from repro.core.policies import energy_ucb
from repro.energy import EnergyController, StepEnergyModel, make_backend
from repro.models import build_model
from repro.train.trainer import Trainer, TrainerConfig


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--energy", action="store_true",
                    help="run the EnergyUCB controller in the loop")
    ap.add_argument("--qos", type=float, default=None)
    ap.add_argument("--window-discount", type=float, default=None,
                    help="sliding-window discount gamma < 1 (training "
                         "phase changes: warmup -> steady -> eval)")
    ap.add_argument("--warmup", action="store_true",
                    help="round-robin warm-up instead of optimistic init")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    return ap.parse_args(argv)


def build_policy(args):
    # --qos 0.0 is a valid (strictest) slowdown budget and
    # --window-discount 0.0 a valid (last-sample-only) window: dispatch
    # on `is None`, never on truthiness
    kw = {"qos_delta": args.qos}
    if args.window_discount is not None:
        kw["window_discount"] = args.window_discount
    if args.warmup:
        kw["optimistic_init"] = False
    return energy_ucb(**kw)


def main():
    args = parse_args()
    cfg = get_arch(args.arch) if args.full_config else get_reduced(args.arch)
    bundle = build_model(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    controller = None
    if args.energy:
        pol = build_policy(args)
        model = StepEnergyModel(t_compute_s=0.2, t_memory_s=0.3,
                                t_collective_s=0.1, n_chips=8,
                                steps_total=args.steps)
        controller = EnergyController(pol, make_backend(model))
    tr = Trainer(
        bundle, shape,
        tcfg=TrainerConfig(total_steps=args.steps, ckpt_every=max(10, args.steps // 4),
                           ckpt_dir=args.ckpt, log_every=max(1, args.steps // 10)),
        controller=controller,
    )
    start = tr.init_or_restore()
    print(f"arch={cfg.name} family={cfg.family} start_step={start}")
    res = tr.run()
    for m in res["metrics"]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}")
    if controller is not None:
        print({k: round(v, 2) if isinstance(v, float) else v
               for k, v in res["energy"].items()})


if __name__ == "__main__":
    main()
