"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
smoke tests must see 1 device while the dry-run forces 512).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh over the first prod(shape) local devices."""
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)


def host_mesh_or_none(min_devices: int = 2):
    """Small local mesh for CPU integration tests; None if single-device."""
    n = len(jax.devices())
    if n < min_devices:
        return None
    d = n - (n % 2)
    return make_mesh((d // 2, 2), ("data", "model"))
