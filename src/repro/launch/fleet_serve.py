"""Distributed fleet-control launcher: H controller processes, each
owning its EnergyBackend stripe + N/H fused-kernel controllers
(repro.parallel.distributed), coordinated over a stdlib socket.

One process per host (the production shape — run this on every host):

  PYTHONPATH=src python -m repro.launch.fleet_serve --nodes 64 \\
      --intervals 200 --num-hosts 2 --host-id 0 \\
      --coordinator 127.0.0.1:7733 --app tealeaf --report-every 50
  PYTHONPATH=src python -m repro.launch.fleet_serve --nodes 64 \\
      --intervals 200 --num-hosts 2 --host-id 1 \\
      --coordinator 127.0.0.1:7733 --app tealeaf --report-every 50

Single-command local demo / CI (forks the H host processes itself, on a
free port):

  PYTHONPATH=src python -m repro.launch.fleet_serve --spawn \\
      --num-hosts 2 --nodes 64 --intervals 100 --app tealeaf

Any deployment whose coordinator port is reachable beyond loopback MUST
set a per-deployment rendezvous secret in the ``FLEET_AUTHKEY`` env var
on every host (``--spawn`` generates a fresh one per run).

Nonstationary fleets ride the same fused kernel: ``--window-discount
0.95`` runs sliding-window EnergyUCB, ``--warmup`` the round-robin
warm-up ablation, and ``--drift miniswp --drift-every 100`` makes the
simulator cycle workload phases (keyed by global interval index, so
every host stripe switches at the same boundary).

``--episode-scan`` switches the per-interval streaming loop to the
megakernel episode scan (repro.kernels.episode_scan): each reporting
window becomes ONE launch with controller state resident across all of
its intervals, arm-for-arm with the streaming loop (sim and trace
backends both supported).

``--workload serve`` swaps the simulator for the request-driven
serving workload (repro.workload): each node runs the continuous-
batching serve loop against its own seeded bursty-diurnal traffic
stream (``--rate``, ``--serve-model``, ``--slots``), and QoS becomes a
p99-latency SLO against the f_max reference. ``--phase-split`` gives
every node a prefill lane and a decode lane (fleet width 2N); with
``--qos`` the compute-bound prefill lane keeps the slowdown budget
while the bandwidth-bound decode lane runs unconstrained
(``repro.core.phase_policy``). Streaming only: ``--episode-scan`` and
``--drift`` stay simulator-side.

``--uncore-ladder 0.6,0.8,1.0`` factorizes the action space into
(core, uncore) product arms on BOTH workloads — the simulator prices
the HBM-stretch/uncore-power tradeoff per app, the serving workload
gives prefill and decode their opposite uncore preferences — while the
controllers still run as one fused launch over the flat ladder
(``--lam-unc`` sets the per-move uncore switching penalty; omitted, one
shared penalty prices any move).

Replay a recorded trace shard-per-host instead of the simulator with
``--trace trace.npz`` (see repro.energy.record_trace); ``--out arms.npz``
makes host 0 gather and persist the full (T, N) arm trajectory — the
bit-parity oracle tests/test_distributed.py compares against a
single-process run. ``--jax-distributed`` switches coordination to
``jax.distributed`` initialization for real multi-host TPU/GPU
deployments (the socket coordinator still carries the periodic
aggregates).

**Fault tolerance & the crash-restart runbook.** ``--checkpoint-dir
CKPT --checkpoint-every K`` makes every host checkpoint its stripe
(fused-kernel controller state, backend env rows/cursor, arm log —
train.checkpoint async_save under ``CKPT/stripe_<lo>_<hi>/``) every K
GLOBAL intervals, plus a final blocking save. Recovery is then one
rule: RE-RUN THE SAME COMMAND LINE.

- One host crashed (OOM, SIGKILL, node reboot): relaunch that host's
  exact command. It restores the latest checkpoint for its stripe,
  dials the still-running coordinator (bounded retry with backoff),
  is admitted as a rejoining member — skipping the start barrier —
  and replays forward bit-exact (noise is keyed by global node id,
  drift phases by global interval index). Meanwhile the live fleet
  kept going: aggregate ticks are stale-tolerant folds over live
  hosts, never blocking on the dead one.
- The whole fleet died (power loss, preemption): relaunch every
  host's command. A fresh rendezvous forms, every host auto-resumes
  its stripe checkpoint, and the run continues from the latest common
  interval.
- Membership changed for good (a host is NOT coming back): restart
  the fleet at the new size against the same --checkpoint-dir — each
  new stripe is stitched row-wise out of the old stripe checkpoints
  at their latest common step (train.checkpoint.restore_stripe; the
  coordinator broadcasts the epoch-stamped stripe map live hosts
  WOULD own, see parallel.distributed.FleetEpoch).

The coordinator (host 0) is the one process that must stay up for
mid-run rejoin; if it dies, fall back to the whole-fleet restart rule.
``--pace S`` sleeps S seconds per interval (the paper's decision
intervals are seconds-scale; also what makes kill/rejoin windows
controllable in the fault-injection soak).
"""
from __future__ import annotations

import argparse
import os
import secrets
import socket
import subprocess
import sys
import time

import numpy as np

from repro.core import FREQS_GHZ, get_app, make_env_params
from repro.core.fleet import slice_policy_lanes
from repro.core.policies import (
    ActionSpace,
    energy_ucb,
    factored_energy_ucb,
    make_policy_params,
    phase_policy,
)
from repro.core.simulator import make_factored_env_params
from repro.energy import SimBackend, TraceReplayBackend
from repro.energy.backend import trace_n_nodes
from repro.parallel.distributed import (
    DEFAULT_AUTHKEY,
    DistributedFleetController,
    connect_fleet,
    init_jax_distributed,
    parse_address,
)
from repro.parallel.fleet import host_stripe, stripe_bounds


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=64,
                    help="fleet size N (ignored with --trace)")
    ap.add_argument("--intervals", type=int, default=200)
    ap.add_argument("--app", default="tealeaf")
    ap.add_argument("--trace", default=None,
                    help="replay this recorded .npz trace instead of the sim")
    ap.add_argument("--workload", choices=("sim", "serve"), default="sim",
                    help="sim: the calibrated bandit environment; serve: "
                         "the traffic-driven serving backend "
                         "(repro.workload); ignored with --trace")
    ap.add_argument("--serve-model", default="qwen2.5-3b",
                    help="arch config behind the serving roofline physics")
    ap.add_argument("--rate", type=float, default=5.0,
                    help="base request rate per node (requests/s); the "
                         "bursty diurnal modulation rides on top")
    ap.add_argument("--slots", type=int, default=8,
                    help="continuous-batching slots per serving node")
    ap.add_argument("--phase-split", action="store_true",
                    help="per-phase lanes: prefill row + decode row per "
                         "node (fleet width 2N); with --qos the budget "
                         "binds the prefill lane only")
    ap.add_argument("--slo-factor", type=float, default=4.0,
                    help="p99 SLO = slo_factor x the analytic f_max "
                         "no-queueing latency")
    ap.add_argument("--uncore-ladder", default=None,
                    help="comma-separated relative uncore clocks "
                         "ascending to 1.0 (e.g. 0.6,0.8,1.0): factored "
                         "(core x uncore) product arms end to end — the "
                         "policy splits per-dimension bonuses/penalties, "
                         "the sim/serve physics price the HBM stretch "
                         "and uncore power; one fused launch either way")
    ap.add_argument("--lam-unc", type=float, default=None,
                    help="per-move uncore switching penalty (factored "
                         "ladders only; default: one shared penalty on "
                         "any move, the scalar-compatible sentinel)")
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--coordinator", default="127.0.0.1:7733",
                    help="host:port of the host-0 rendezvous socket")
    ap.add_argument("--spawn", action="store_true",
                    help="fork all --num-hosts processes locally (demo/CI)")
    ap.add_argument("--jax-distributed", action="store_true",
                    help="also run jax.distributed.initialize on "
                         "--coordinator (real multi-host TPU/GPU "
                         "deployments); the aggregate rendezvous socket "
                         "then uses the next port up")
    ap.add_argument("--alpha", type=float, default=None)
    ap.add_argument("--lam", type=float, default=None)
    ap.add_argument("--qos", type=float, default=None)
    ap.add_argument("--window-discount", type=float, default=None,
                    help="sliding-window discount gamma < 1 (nonstationary "
                         "fleets; still dispatches the fused kernel)")
    ap.add_argument("--warmup", action="store_true",
                    help="round-robin warm-up instead of optimistic init "
                         "(the 'w/o Opt. Ini.' ablation)")
    ap.add_argument("--drift", default=None,
                    help="comma-separated extra phase apps: the simulator "
                         "cycles --app plus these every --drift-every "
                         "intervals (drifting-workload scenario; sim only)")
    ap.add_argument("--drift-every", type=int, default=0,
                    help="intervals per drift phase (required with --drift)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--report-every", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="stripe-checkpoint root: each host saves its "
                         "stripe under <dir>/stripe_<lo>_<hi>/ and "
                         "AUTO-RESUMES from it at launch (the crash-"
                         "restart runbook: re-run the same command)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint cadence in GLOBAL intervals "
                         "(0 = only the final state; needs "
                         "--checkpoint-dir)")
    ap.add_argument("--pace", type=float, default=0.0,
                    help="sleep this many seconds per interval "
                         "(seconds-scale decision intervals; streaming "
                         "only)")
    ap.add_argument("--interpret", action="store_true",
                    help="force the fused Pallas kernel in interpret mode "
                         "(parity testing off-TPU)")
    ap.add_argument("--episode-scan", action="store_true",
                    help="megakernel episode scan: run each reporting "
                         "window as ONE launch (kernels/episode_scan) "
                         "instead of one fleet_step per interval; "
                         "arm-for-arm with streaming")
    ap.add_argument("--out", default=None,
                    help="host 0 gathers the full (T, N) arm trajectory "
                         "and writes it (npz) here")
    return ap.parse_args(argv)


def parse_uncore_ladder(spec):
    """``--uncore-ladder`` string -> ascending tuple, or None for the
    scalar ladder (empty spec, or the degenerate single 1.0 rung)."""
    if not spec:
        return None
    y = tuple(float(v) for v in spec.split(",") if v.strip())
    return None if y == (1.0,) else y


def build_policy(args):
    # --qos 0.0 is a valid (strictest) budget, and --window-discount 0.0
    # a valid (last-sample-only) window: dispatch on `is None`, never on
    # truthiness
    kw = {"qos_delta": args.qos}
    if args.alpha is not None:
        kw["alpha"] = args.alpha
    if args.lam is not None:
        kw["switching_penalty"] = args.lam
    if args.window_discount is not None:
        kw["window_discount"] = args.window_discount
    if args.warmup:
        kw["optimistic_init"] = False
    ladder = parse_uncore_ladder(args.uncore_ladder)
    space = ActionSpace(len(FREQS_GHZ), len(ladder)) if ladder else None
    if args.workload == "serve" and args.phase_split and args.trace is None:
        # the physics-informed per-phase config: the slowdown budget
        # binds the compute-bound prefill lane; the bandwidth-bound
        # decode lane (step time flat in core frequency) stays
        # unconstrained. Factored ladders keep the same split — lanes
        # just select over the flat (core x uncore) product.
        pk = dict(kw)
        if space is not None:
            pk.update(k=space.k, default_arm=space.k - 1,
                      lam_unc=args.lam_unc)
        return phase_policy(
            args.nodes,
            prefill=make_policy_params(**pk),
            decode=make_policy_params(**{**pk, "qos_delta": None}),
            space=space,
        )
    if space is not None:
        return factored_energy_ucb(space, uncore_penalty=args.lam_unc, **kw)
    return energy_ucb(**kw)


def build_local_backend(args, lo: int, hi: int):
    """This host's backend stripe, built DIRECTLY — never the full
    fleet: a SimBackend stripe is just (n, node_offset) over shared
    params (identical to what ``local_slice`` would produce), and trace
    shards load only their columns. Per-host footprint stays O(N/H).
    ``--drift`` phase schedules are keyed by global interval index, so
    every stripe switches phase at the same boundary."""
    if args.trace is not None:
        if args.drift:
            raise ValueError("--drift drives the simulator; it cannot "
                             "apply to a recorded --trace replay")
        return TraceReplayBackend.load(args.trace, nodes=(lo, hi))
    if args.workload == "serve":
        if args.drift:
            raise ValueError("--drift drives the simulator; the serving "
                             "workload's nonstationarity is its traffic")
        if args.episode_scan:
            raise ValueError("--episode-scan needs an episode surface; "
                             "the serving workload streams (run without "
                             "--episode-scan)")
        from repro.workload import ServingBackend, bursty_diurnal_traffic
        from repro.workload.serving_backend import SERVE_P_UNC_W

        ladder = parse_uncore_ladder(args.uncore_ladder)
        f = 2 if args.phase_split else 1
        return ServingBackend(
            bursty_diurnal_traffic(args.rate), args.serve_model,
            n_nodes=(hi - lo) // f, n_slots=args.slots,
            phase_split=args.phase_split, node_offset=lo // f,
            slo_factor=args.slo_factor,
            uncore_ladder=ladder,
            p_unc_w=SERVE_P_UNC_W if ladder else 0.0,
        )
    ladder = parse_uncore_ladder(args.uncore_ladder)

    def env(app_name):
        app = get_app(app_name)
        return (make_factored_env_params(app, unc_freqs=ladder)
                if ladder else make_env_params(app))

    drift = ([env(a.strip()) for a in args.drift.split(",") if a.strip()]
             if args.drift else None)
    return SimBackend(env(args.app), n=hi - lo,
                      seed=args.seed, node_offset=lo,
                      drift_params=drift, drift_every=args.drift_every)


def _authkey() -> bytes:
    """Rendezvous secret: FLEET_AUTHKEY env var (REQUIRED for any
    coordinator reachable beyond loopback — the payloads are pickles,
    so the key gates code execution on host 0); falls back to the
    same-machine demo default."""
    key = os.environ.get("FLEET_AUTHKEY", "")
    return key.encode() if key else DEFAULT_AUTHKEY


def run_host(args) -> dict:
    """One controller process: build this host's stripe, stream
    intervals with zero cross-host traffic, gather periodic aggregates.
    Returns the final fleet summary (identical on every host)."""
    rendezvous = parse_address(args.coordinator)
    if args.jax_distributed:
        # jax's coordination service owns --coordinator's port; the
        # aggregate rendezvous socket moves to the next port up so both
        # can live on host 0
        init_jax_distributed(args.coordinator, args.num_hosts, args.host_id)
        rendezvous = (rendezvous[0], rendezvous[1] + 1)
    if args.trace is not None:
        n_total = trace_n_nodes(args.trace)
        lo, hi = host_stripe(n_total, args.num_hosts, args.host_id)
    else:
        # serve + --phase-split doubles the lane count; stripe over
        # SERVE nodes first so every host's lane slice stays
        # even-aligned (a node's prefill/decode pair never splits)
        f = (2 if args.workload == "serve" and args.phase_split else 1)
        lo, hi = host_stripe(args.nodes, args.num_hosts, args.host_id)
        n_total, lo, hi = args.nodes * f, lo * f, hi * f
    backend = build_local_backend(args, lo, hi)
    intervals = args.intervals
    if isinstance(backend, TraceReplayBackend):
        intervals = min(intervals, len(backend))
    comm = connect_fleet(args.num_hosts, args.host_id, rendezvous,
                         authkey=_authkey())
    lead = comm.host_id == 0
    with comm:
        ctl = DistributedFleetController(
            slice_policy_lanes(build_policy(args), lo, hi, n_total),
            backend, comm, stripe=(lo, hi), n_total=n_total,
            seed=args.seed, interpret=args.interpret,
            log_arms=args.out is not None,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
        )
        resumed = 0
        if ctl.try_restore():
            resumed = ctl.interval
            print(f"host {comm.host_id}: resumed stripe {ctl.stripe} "
                  f"from checkpoint at interval {resumed}", flush=True)
        # a host admitted to an already-running fleet must not wait on
        # the start barrier — that round completed long ago
        if not comm.rejoined:
            comm.barrier("start")

        def on_report(i, fleet):
            if lead:
                print(f"[interval {i:5d}] fleet energy {fleet['energy_j']:.1f} J"
                      + (f", saved {fleet['saved_energy_pct']:.1f}%"
                         if "saved_energy_pct" in fleet else "")
                      + f", {fleet['switches']} switches"
                      + f", {fleet['hosts']}/{comm.num_hosts} hosts",
                      flush=True)

        work_fn = ((lambda: time.sleep(args.pace)) if args.pace > 0
                   else None)
        fleet = ctl.run(max(0, intervals - resumed), work_fn=work_fn,
                        report_every=args.report_every,
                        on_report=on_report,
                        episode_scan=args.episode_scan)
        if args.out is not None:
            # one strict gather: each live host's stripe bounds, arm
            # log and final controller state (so parity tests can
            # compare state trajectories, not just arms). Dead hosts
            # leave None slots; their stripes are filled with -1/0 and
            # recorded in missing_hosts instead of stalling the fleet.
            local = (np.stack(ctl.arm_log) if ctl.arm_log
                     else np.zeros((0, ctl.n_local), np.int32))
            out = comm.allgather(
                {"stripe": ctl.stripe, "arms": local,
                 "states": {k: np.asarray(v)
                            for k, v in ctl.controller.states.items()}},
                tag="out",
            )
            if lead:
                t = max(g["arms"].shape[0] for g in out if g is not None)
                arms = np.full((t, ctl.n_total), -1, np.int32)
                merged = {}
                for g in out:
                    if g is None:
                        continue
                    glo, ghi = g["stripe"]
                    arms[: g["arms"].shape[0], glo:ghi] = g["arms"]
                    for k, v in g["states"].items():
                        merged.setdefault(
                            f"state_{k}",
                            np.zeros((ctl.n_total,) + v.shape[1:], v.dtype),
                        )[glo:ghi] = v
                stripes = stripe_bounds(ctl.n_total, comm.num_hosts)
                np.savez(args.out, arms=arms,
                         stripe_lo=np.asarray([s[0] for s in stripes]),
                         stripe_hi=np.asarray([s[1] for s in stripes]),
                         missing_hosts=np.asarray(
                             [h for h, g in enumerate(out) if g is None],
                             np.int32),
                         **merged)
        if args.workload == "serve" and args.trace is None:
            # QoS accounting is per completed request, so each host
            # reports its own stripe's tail latency
            rep = backend.slo_report(warmup_s=0.1 * intervals
                                     * backend.interval_s)
            print(f"host {comm.host_id} stripe SLO: p99 {rep['p99_s']:.3f} s "
                  f"vs {rep['slo_s']:.3f} s, violation rate "
                  f"{rep['violation_rate']:.3f} over {rep['completed']} "
                  f"requests, {backend.served_tokens} tokens", flush=True)
        if lead:
            kernel = "fused kernel" if ctl.use_kernel else "vmapped"
            print(f"host 0/{comm.num_hosts}: stripe {ctl.stripe} of "
                  f"N={ctl.n_total} ({kernel}); fleet summary:")
            print({k: round(v, 3) if isinstance(v, float) else v
                   for k, v in fleet.items()}, flush=True)
    return fleet


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn_local(args) -> int:
    """Fork --num-hosts copies of this launcher on a free local port and
    wait for the whole fleet (the zero-to-running path for demos/CI)."""
    coordinator = f"127.0.0.1:{_free_port()}"
    base = [sys.executable, "-m", "repro.launch.fleet_serve",
            "--nodes", str(args.nodes), "--intervals", str(args.intervals),
            "--app", args.app, "--num-hosts", str(args.num_hosts),
            "--coordinator", coordinator, "--seed", str(args.seed),
            "--report-every", str(args.report_every)]
    if args.trace is not None:
        base += ["--trace", args.trace]
    if args.workload != "sim":
        base += ["--workload", args.workload,
                 "--serve-model", args.serve_model,
                 "--rate", str(args.rate), "--slots", str(args.slots),
                 "--slo-factor", str(args.slo_factor)]
        if args.phase_split:
            base += ["--phase-split"]
    if args.alpha is not None:
        base += ["--alpha", str(args.alpha)]
    if args.lam is not None:
        base += ["--lam", str(args.lam)]
    if args.qos is not None:
        base += ["--qos", str(args.qos)]
    if args.window_discount is not None:
        base += ["--window-discount", str(args.window_discount)]
    if args.uncore_ladder is not None:
        base += ["--uncore-ladder", args.uncore_ladder]
    if args.lam_unc is not None:
        base += ["--lam-unc", str(args.lam_unc)]
    if args.warmup:
        base += ["--warmup"]
    if args.drift is not None:
        base += ["--drift", args.drift, "--drift-every",
                 str(args.drift_every)]
    if args.checkpoint_dir is not None:
        base += ["--checkpoint-dir", args.checkpoint_dir,
                 "--checkpoint-every", str(args.checkpoint_every)]
    if args.pace:
        base += ["--pace", str(args.pace)]
    if args.interpret:
        base += ["--interpret"]
    if args.episode_scan:
        base += ["--episode-scan"]
    if args.jax_distributed:
        base += ["--jax-distributed"]
    if args.out is not None:
        base += ["--out", args.out]
    # fresh random rendezvous secret per run (children inherit it; see
    # _authkey) unless the operator pinned one
    env = dict(os.environ)
    env.setdefault("FLEET_AUTHKEY", secrets.token_hex(16))
    procs = [subprocess.Popen(base + ["--host-id", str(h)], env=env)
             for h in range(args.num_hosts)]
    codes = [p.wait() for p in procs]
    # signal-killed children report negative codes; any nonzero child
    # must fail the whole fleet
    return next((c if c > 0 else 1 for c in codes if c != 0), 0)


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.spawn:
        return spawn_local(args)
    run_host(args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
