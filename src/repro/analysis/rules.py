"""The five repro-lint rules (RPL001..RPL005) — each mechanizes one of
the ROADMAP "Architecture invariants".

RPL001  parity    one-sided ``.at[...].add/.set`` scatter in a
                  parity-critical module (kernels/, core/fleet.py,
                  core/policies.py). Fused and vmapped paths must share
                  the select+onehot arithmetic expressions; a scatter on
                  one path lets XLA pick different FMA contractions and
                  drifts the trajectories by an ulp (the PR 5 bug).
RPL002  parity    ``unroll=`` on a ``lax.scan`` in kernels/core (fusing
                  across iterations breaks bit-parity with the stepwise
                  path — the PR 6 bug), and donation of the aliased
                  ``env_rows`` operand in the episode-scan fallbacks
                  (it aliases live backend counters).
RPL003  lanes     lane completeness: every ``PolicyParams`` field must
                  be registered in :mod:`repro.analysis.lanes` and
                  appear on every dispatch surface — ``_params_axes``,
                  ``slice_policy_lanes``, the fused-kernel and oracle
                  signatures, the Fleet dispatch methods, and the
                  sharded step's pad fills.
RPL004  determinism  wall clocks, ``np.random`` module state, argless
                  seeds, and local-count key splits in backend/sim/
                  kernel modules. All randomness must derive from
                  ``fold_in`` on a GLOBAL node id / GLOBAL interval
                  index so striped runs are bit-exact (the PR 4 bug).
RPL005  locks     lock discipline: attributes a class mutates under its
                  ``self._lock`` (or in ``*_locked`` methods) may only
                  be mutated under that lock; ``*_locked`` helpers may
                  only be called while holding it.
"""
from __future__ import annotations

import ast

from .engine import Finding, Rule, SourceFile, in_scope
from .lanes import (
    FLEET_DISPATCH_METHODS,
    INIT_ONLY_LANES,
    RUNTIME_LANES,
    SURFACE_FUNCS,
)

# ---------------------------------------------------------------- util


def dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression: ``jax.random.split`` ->
    "jax.random.split"; unresolvable parts render as ``?``."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return f"{dotted(node.value)}.{node.attr}"
    if isinstance(node, ast.Call):
        return f"{dotted(node.func)}()"
    return "?"


def param_names(fn: ast.FunctionDef) -> set:
    a = fn.args
    names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    return set(names)


def walk_functions(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def const_int_seq(node: ast.AST, module: ast.Module | None) -> list | None:
    """Const-evaluate a donate_argnums-style expression to a list of
    ints: literals, tuples/lists of literals, ``tuple(range(N))``, and
    one level of module-level Name indirection."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            sub = const_int_seq(e, module)
            if sub is None or len(sub) != 1:
                return None
            out.extend(sub)
        return out
    if isinstance(node, ast.Call):
        fn = dotted(node.func)
        inner = node.args[0] if node.args else None
        if fn == "tuple" and isinstance(inner, ast.Call):
            fn, node = "tuple(range)", inner
            if dotted(node.func) == "range" and len(node.args) == 1:
                n = const_int_seq(node.args[0], module)
                if n and len(n) == 1:
                    return list(range(n[0]))
        elif fn == "range" and len(node.args) == 1:
            n = const_int_seq(node.args[0], module)
            if n and len(n) == 1:
                return list(range(n[0]))
        return None
    if isinstance(node, ast.Name) and module is not None:
        for stmt in module.body:
            if isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == node.id:
                        return const_int_seq(stmt.value, None) or const_int_seq(
                            stmt.value, module
                        )
        return None
    return None


# ------------------------------------------------------------- RPL001

RPL001_SCOPE_DIRS = ("kernels",)
RPL001_SCOPE_SUFFIXES = ("core/fleet.py", "core/policies.py")
SCATTER_METHODS = {"add", "set", "mul", "min", "max", "subtract", "divide",
                   "apply", "power"}


def _check_rpl001(sf: SourceFile) -> list:
    if not in_scope(sf.relpath, RPL001_SCOPE_DIRS, RPL001_SCOPE_SUFFIXES):
        return []
    out = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in SCATTER_METHODS):
            continue
        sub = node.func.value
        if (isinstance(sub, ast.Subscript)
                and isinstance(sub.value, ast.Attribute)
                and sub.value.attr == "at"):
            out.append(Finding(
                "RPL001", "error", sf.relpath, node.lineno,
                f"one-sided `.at[...].{node.func.attr}` scatter in a "
                "parity-critical module; use the shared select+onehot "
                "expression so fused and vmapped paths contract "
                "identically",
            ))
    return out


# ------------------------------------------------------------- RPL002

RPL002_SCOPE_DIRS = ("kernels", "core")


def _jit_donations(fn: ast.FunctionDef, module: ast.Module):
    """Donated argnums from a ``@functools.partial(jax.jit, ...,
    donate_argnums=X)`` / ``@jax.jit(...)`` decorator on ``fn``."""
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        name = dotted(dec.func)
        target_kwargs = None
        if name.endswith("partial") and dec.args:
            if dotted(dec.args[0]).endswith("jit"):
                target_kwargs = dec.keywords
        elif name.endswith("jit"):
            target_kwargs = dec.keywords
        if target_kwargs is None:
            continue
        for kw in target_kwargs:
            if kw.arg in ("donate_argnums", "donate_argnames"):
                if kw.arg == "donate_argnames":
                    yield kw, None
                else:
                    yield kw, const_int_seq(kw.value, module)


def _check_rpl002(sf: SourceFile) -> list:
    if not in_scope(sf.relpath, RPL002_SCOPE_DIRS):
        return []
    out = []
    module = sf.tree
    fn_by_name = {
        fn.name: fn for fn in module.body
        if isinstance(fn, ast.FunctionDef)
    }
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        # (a) unroll on lax.scan
        if name.endswith("lax.scan") or name == "scan" or name.endswith(".scan"):
            for kw in node.keywords:
                if kw.arg == "unroll":
                    out.append(Finding(
                        "RPL002", "error", sf.relpath, kw.value.lineno,
                        "`unroll=` on lax.scan in a parity-critical "
                        "module: unrolling lets XLA fuse across "
                        "iterations and breaks bitwise parity with the "
                        "stepwise path",
                    ))
        # (b2) call-form jit: name = jax.jit(fn, donate_argnums=...)
        if name.endswith("jit") and node.args:
            target = node.args[0]
            fn = fn_by_name.get(target.id) if isinstance(target, ast.Name) else None
            for kw in node.keywords:
                if kw.arg not in ("donate_argnums", "donate_argnames"):
                    continue
                donated = (None if kw.arg == "donate_argnames"
                           else const_int_seq(kw.value, module))
                out.extend(_donation_findings(sf, kw, donated, fn))
    # (b1) decorator-form jit
    for fn in walk_functions(sf.tree):
        for kw, donated in _jit_donations(fn, module):
            out.extend(_donation_findings(sf, kw, donated, fn))
    return out


def _donation_findings(sf, kw, donated, fn):
    if fn is None:
        return []
    a = fn.args
    ordered = [p.arg for p in (*a.posonlyargs, *a.args)]
    bad = []
    if donated is not None:
        bad = [ordered[i] for i in donated
               if 0 <= i < len(ordered) and ordered[i] == "env_rows"]
    elif isinstance(kw.value, (ast.Tuple, ast.List, ast.Constant)):
        vals = (kw.value.elts
                if isinstance(kw.value, (ast.Tuple, ast.List))
                else [kw.value])
        bad = ["env_rows" for v in vals
               if isinstance(v, ast.Constant) and v.value == "env_rows"]
    if bad:
        return [Finding(
            "RPL002", "error", sf.relpath, kw.value.lineno,
            f"`{fn.name}` donates `env_rows`: the env rows alias live "
            "backend counters and must NOT be donated (the caller "
            "still reads them)",
        )]
    return []


# ------------------------------------------------------------- RPL003


def _lane_aliases(lane: str) -> tuple:
    return RUNTIME_LANES[lane]


def _find_class(files, name):
    for sf in files:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef) and node.name == name:
                yield sf, node


def _find_funcs(files, name):
    for sf in files:
        for fn in walk_functions(sf.tree):
            if fn.name == name:
                yield sf, fn


def _attr_reads(node: ast.AST) -> set:
    return {
        n.attr for n in ast.walk(node)
        if isinstance(n, ast.Attribute) and isinstance(n.ctx, ast.Load)
    }


def _check_rpl003(files: list) -> list:
    out = []
    pp = list(_find_class(files, "PolicyParams"))
    if not pp:
        return []  # fixture trees without the dataclass are exempt
    pp_sf, pp_cls = pp[0]
    fields = [
        stmt.target.id for stmt in pp_cls.body
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name)
    ]
    registered = set(RUNTIME_LANES) | set(INIT_ONLY_LANES)
    for f in fields:
        if f not in registered:
            out.append(Finding(
                "RPL003", "error", pp_sf.relpath, pp_cls.lineno,
                f"PolicyParams field `{f}` is not registered in "
                "repro/analysis/lanes.py — register the lane (and "
                "thread it through every surface) in the same PR",
            ))
    field_set = set(fields)
    runtime = [l for l in RUNTIME_LANES if l in field_set]

    # _params_axes must classify every field by keyword
    axes = list(_find_funcs(files, "_params_axes"))
    if not axes:
        out.append(Finding(
            "RPL003", "error", pp_sf.relpath, pp_cls.lineno,
            "PolicyParams exists but no `_params_axes` classifier was "
            "found — every lane needs a vmap/stripe axis",
        ))
    for sf, fn in axes:
        kw_seen = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and dotted(node.func).endswith("PolicyParams")):
                kw_seen |= {kw.arg for kw in node.keywords if kw.arg}
        for f in fields:
            if f not in kw_seen:
                out.append(Finding(
                    "RPL003", "error", sf.relpath, fn.lineno,
                    f"lane `{f}` missing from `_params_axes` — it will "
                    "not be classified for vmap/stripe slicing",
                ))

    # slice_policy_lanes must derive from _params_axes (not re-list lanes)
    for sf, fn in _find_funcs(files, "slice_policy_lanes"):
        names = {
            n.id for n in ast.walk(fn) if isinstance(n, ast.Name)
        } | _attr_reads(fn)
        if "_params_axes" not in names:
            out.append(Finding(
                "RPL003", "error", sf.relpath, fn.lineno,
                "`slice_policy_lanes` does not derive from "
                "`_params_axes`; a hand-maintained lane list will "
                "silently drop new lanes",
            ))

    # every kernel/oracle/dispatcher surface carries every runtime lane
    for name in sorted(SURFACE_FUNCS):
        for sf, fn in _find_funcs(files, name):
            params = param_names(fn)
            for lane in runtime:
                if not any(a in params for a in _lane_aliases(lane)):
                    out.append(Finding(
                        "RPL003", "error", sf.relpath, fn.lineno,
                        f"surface `{fn.name}` has no parameter for lane "
                        f"`{lane}` (aliases: "
                        f"{', '.join(_lane_aliases(lane))}) — callers "
                        "cannot thread the lane through this path",
                    ))

    # Fleet dispatch methods must forward each runtime lane
    for sf, cls in _find_class(files, "Fleet"):
        for stmt in cls.body:
            if (isinstance(stmt, ast.FunctionDef)
                    and stmt.name in FLEET_DISPATCH_METHODS):
                reads = _attr_reads(stmt)
                for lane in runtime:
                    if not any(a in reads for a in (lane,) + _lane_aliases(lane)):
                        out.append(Finding(
                            "RPL003", "error", sf.relpath, stmt.lineno,
                            f"Fleet.{stmt.name} never reads lane "
                            f"`{lane}` — the kernel will run with its "
                            "default instead of the configured value",
                        ))

    # sharded step: inner signature carries the lanes; pad fills cover
    # every operand (a new lane appended to `args` without a fill is
    # silently truncated by zip)
    for sf, fn in _find_funcs(files, "make_sharded_fleet_step"):
        inner = next(
            (f for f in walk_functions(fn) if f.name == "step" and f is not fn),
            None,
        )
        if inner is None:
            out.append(Finding(
                "RPL003", "error", sf.relpath, fn.lineno,
                "`make_sharded_fleet_step` has no inner `step` — cannot "
                "verify the sharded lane surface",
            ))
            continue
        params = param_names(inner)
        for lane in runtime:
            if not any(a in params for a in _lane_aliases(lane)):
                out.append(Finding(
                    "RPL003", "error", sf.relpath, inner.lineno,
                    f"sharded `step` has no parameter for lane `{lane}` "
                    f"(aliases: {', '.join(_lane_aliases(lane))})",
                ))
        n_args = n_fills = None
        fills_line = inner.lineno
        for node in ast.walk(inner):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name) and isinstance(
                        node.value, (ast.Tuple, ast.List)):
                    if tgt.id == "args" and n_args is None:
                        n_args = len(node.value.elts)
                    elif tgt.id == "fills":
                        n_fills = len(node.value.elts)
                        fills_line = node.lineno
        if n_args is not None and n_fills is not None and n_args != n_fills:
            out.append(Finding(
                "RPL003", "error", sf.relpath, fills_line,
                f"sharded pad fills cover {n_fills} operand(s) but "
                f"`args` has {n_args}: zip() silently drops the "
                "unmatched operands, so padded (ragged) fleets run "
                "with truncated inputs",
            ))
    return out


# ------------------------------------------------------------- RPL004

RPL004_SCOPE_DIRS = ("energy", "kernels", "workload")
RPL004_SCOPE_SUFFIXES = ("core/simulator.py",)
WALLCLOCK = {"time.time", "time.time_ns", "datetime.now", "datetime.utcnow",
             "datetime.datetime.now", "datetime.datetime.utcnow"}
NP_RANDOM_OK = {"default_rng", "SeedSequence", "Generator", "Philox", "PCG64"}


def _check_rpl004(sf: SourceFile) -> list:
    if not in_scope(sf.relpath, RPL004_SCOPE_DIRS, RPL004_SCOPE_SUFFIXES):
        return []
    out = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name in WALLCLOCK or any(name.endswith("." + w) for w in WALLCLOCK):
            out.append(Finding(
                "RPL004", "error", sf.relpath, node.lineno,
                f"wall-clock call `{name}` in a determinism-critical "
                "module; derive timing from the GLOBAL interval index",
            ))
            continue
        if name.endswith("random.split"):
            count = None
            if len(node.args) >= 2:
                count = node.args[1]
            for kw in node.keywords:
                if kw.arg == "num":
                    count = kw.value
            if count is not None and not (
                    isinstance(count, ast.Constant)
                    and isinstance(count.value, int)):
                out.append(Finding(
                    "RPL004", "error", sf.relpath, node.lineno,
                    f"`{name}(key, {ast.unparse(count)})` splits by a "
                    "runtime-local count: key streams then depend on "
                    "the local shard size. Use `fold_in` on the GLOBAL "
                    "node id / GLOBAL interval index instead",
                ))
            continue
        for mod in ("np.random.", "numpy.random."):
            if name.startswith(mod):
                tail = name[len(mod):]
                if tail.split(".")[0] not in NP_RANDOM_OK:
                    out.append(Finding(
                        "RPL004", "error", sf.relpath, node.lineno,
                        f"`{name}` draws from numpy's global RNG state "
                        "— not reproducible across processes; use a "
                        "seeded Generator or jax fold_in keys",
                    ))
                elif tail == "default_rng" and not node.args and not node.keywords:
                    out.append(Finding(
                        "RPL004", "error", sf.relpath, node.lineno,
                        "argless `default_rng()` seeds from the OS; "
                        "pass an explicit seed derived from the global "
                        "config",
                    ))
    return out


# ------------------------------------------------------------- RPL005

MUTATORS = {"pop", "append", "clear", "setdefault", "update", "add",
            "remove", "extend", "popitem", "discard", "insert",
            "appendleft", "popleft"}


def _self_attr(node: ast.AST) -> str | None:
    """`self.X` -> "X"; also unwraps subscripts: `self.X[k]` -> "X"."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _mutations(region: ast.AST):
    """Yield (attr, lineno) for every `self.<attr>` mutation inside
    ``region`` — assignment, augmented assignment, deletion, subscript
    store, or a call to a known container mutator."""
    for node in ast.walk(region):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) else [tgt]
                for t in elts:
                    attr = _self_attr(t)
                    if attr:
                        yield attr, node.lineno
        elif isinstance(node, ast.Delete):
            for tgt in node.targets:
                attr = _self_attr(tgt)
                if attr:
                    yield attr, node.lineno
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr in MUTATORS):
            attr = _self_attr(node.func.value)
            if attr:
                yield attr, node.lineno


def _locked_withs(fn: ast.FunctionDef, lock_attrs: set):
    for node in ast.walk(fn):
        if isinstance(node, ast.With):
            for item in node.items:
                attr = _self_attr(item.context_expr)
                if attr in lock_attrs:
                    yield node
                    break


def _check_rpl005(sf: SourceFile) -> list:
    out = []
    for cls in (n for n in ast.walk(sf.tree) if isinstance(n, ast.ClassDef)):
        lock_attrs = set()
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                name = dotted(node.value.func)
                if name.endswith("Lock") or name.endswith("RLock"):
                    for tgt in node.targets:
                        attr = _self_attr(tgt)
                        if attr:
                            lock_attrs.add(attr)
        if not lock_attrs:
            continue
        methods = [m for m in cls.body if isinstance(m, ast.FunctionDef)]
        # pass 1: what does this class mutate while holding the lock?
        guarded = set()
        for m in methods:
            regions = ([m] if m.name.endswith("_locked")
                       else list(_locked_withs(m, lock_attrs)))
            for region in regions:
                guarded |= {a for a, _ in _mutations(region)}
        guarded -= lock_attrs
        if not guarded:
            continue
        # pass 2: mutations of guarded attrs (and *_locked calls)
        # outside any locked region
        for m in methods:
            if m.name == "__init__" or m.name.endswith("_locked"):
                continue
            locked_lines = set()
            for region in _locked_withs(m, lock_attrs):
                for node in ast.walk(region):
                    if hasattr(node, "lineno"):
                        locked_lines.add(node.lineno)
            for attr, lineno in _mutations(m):
                if attr in guarded and lineno not in locked_lines:
                    out.append(Finding(
                        "RPL005", "error", sf.relpath, lineno,
                        f"`self.{attr}` is lock-guarded (mutated under "
                        f"`self.{next(iter(lock_attrs))}` elsewhere in "
                        f"`{cls.name}`) but `{m.name}` mutates it "
                        "without holding the lock — races the other "
                        "thread",
                    ))
            for node in ast.walk(m):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr.endswith("_locked")
                        and isinstance(node.func.value, ast.Name)
                        and node.func.value.id == "self"):
                    if node.lineno not in locked_lines:
                        out.append(Finding(
                            "RPL005", "error", sf.relpath, node.lineno,
                            f"`self.{node.func.attr}()` called outside "
                            "the lock — `*_locked` helpers assume the "
                            "caller holds it",
                        ))
    return out


# ---------------------------------------------------------------- API

RULES = [
    Rule("RPL001", "error",
         "one-sided scatter in parity-critical module",
         check_file=_check_rpl001),
    Rule("RPL002", "error",
         "scan unroll / aliased env-row donation in episode scans",
         check_file=_check_rpl002),
    Rule("RPL003", "error",
         "lane missing from a dispatch surface",
         check_project=_check_rpl003),
    Rule("RPL004", "error",
         "nondeterministic source in backend/sim/kernel module",
         check_file=_check_rpl004),
    Rule("RPL005", "error",
         "lock-guarded attribute touched without the lock",
         check_file=_check_rpl005),
]
