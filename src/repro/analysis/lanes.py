"""The lane registry: the one declarative copy of "what is a policy lane
and where must it appear" that RPL003 (lane completeness) checks against.

The hyperparams-as-data design (ROADMAP "Architecture invariants") means
every ``PolicyParams`` field is a per-controller data lane that must be
threaded through EVERY dispatch surface — the lane classifier
(``core.fleet._params_axes``), the stripe slicer (``slice_policy_lanes``,
which derives from the classifier), the fused kernel signatures
(``fleet_step`` / ``fleet_step_math`` / the episode scans and their XLA
fallbacks), the sharded step's pad fills, and the ``ref`` oracles. A lane
added to ``PolicyParams`` but missing from any of those silently gets a
default on that path — exactly the class of bug PR 5's scatter drift and
PR 4's RNG split were, so the linter turns it into a hard error.

Adding a real new lane is a REGISTERED act: extend ``RUNTIME_LANES``
(or ``INIT_ONLY_LANES``) here in the same PR that threads the lane
through the surfaces, and RPL003 will hold every surface to it from then
on. An unregistered ``PolicyParams`` field is itself a finding.
"""
from __future__ import annotations

# PolicyParams field -> parameter-name aliases accepted on the kernel /
# oracle / dispatcher signatures (the kernels abbreviate some lanes).
RUNTIME_LANES = {
    "alpha": ("alpha",),
    "lam": ("lam",),
    "qos_delta": ("qos_delta", "qos"),
    "gamma": ("gamma", "g"),
    "optimistic": ("optimistic", "opt"),
    "prior_mu": ("prior_mu", "prior"),
    "default_arm": ("default_arm", "def_arm"),
    "lam_unc": ("lam_unc",),
}

# Lanes consumed only at state-initialization time (ucb_init); they must
# still be classified by _params_axes / sliced by slice_policy_lanes, but
# have no per-interval kernel surface to appear on.
INIT_ONLY_LANES = {
    "prior_n",
}

# Function names that are per-interval lane surfaces: every RUNTIME_LANES
# entry must appear (under one of its aliases) in the parameter list of
# any function with one of these names.
SURFACE_FUNCS = {
    "fleet_step",          # kernels/fleet_ucb.py AND kernels/ops.py
    "fleet_step_math",     # THE one copy of the fused arithmetic
    "ref_fleet_step",      # kernels/ref.py oracle
    "ref_episode_scan",
    "ref_episode_scan_sim",
    "episode_scan_trace",  # megakernel + ops dispatcher
    "episode_scan_sim",
    "xla_episode_trace",   # lax.scan fallbacks
    "xla_episode_sim",
    "_episode_lanes",      # ops.py once-per-episode lane broadcast
}

# Methods of the Fleet control plane that must FORWARD every runtime
# lane (as a ``p.<lane>`` attribute read) into the kernel dispatch — a
# lane present in the kernel signature but never passed silently runs
# with the kernel default.
FLEET_DISPATCH_METHODS = {
    "step",
    "episode_trace",
    "episode_sim",
}
