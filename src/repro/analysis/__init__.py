"""repro-lint: AST static analysis mechanizing the repo's architecture
invariants (ROADMAP "Architecture invariants" → RPL001..RPL005).

Stdlib-only; never imports the code it analyses. CLI entry point:
``scripts/repro_lint.py`` (or ``scripts/tier1.sh lint``).
"""
from .engine import (
    Finding,
    Rule,
    SourceFile,
    exit_code,
    in_scope,
    load_files,
    render_human,
    render_json,
    run_rules,
)
from .rules import RULES

__all__ = [
    "Finding",
    "Rule",
    "RULES",
    "SourceFile",
    "exit_code",
    "in_scope",
    "load_files",
    "render_human",
    "render_json",
    "run_rules",
    "run_lint",
]


def run_lint(root, paths):
    """Lint ``paths`` (files or directories) relative to ``root``;
    returns the sorted finding list (suppressed ones included)."""
    files = load_files(root, paths)
    return run_rules(files, RULES)
