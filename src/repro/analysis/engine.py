"""repro-lint rule engine: findings, suppressions, file loading, and the
driver that runs every registered rule over a file set.

Stdlib-only on purpose — the lint lane must run on a box with no jax (CI
lint job, pre-commit) and must never import the code under analysis.

Suppression syntax (same line as the finding, or the line directly
above it)::

    x = state["n"].at[arm].add(1.0)  # repro-lint: disable=RPL001 baseline-only helper, no fused twin

Multiple rules: ``disable=RPL001,RPL004``. The free text after the rule
list is the REQUIRED justification; a suppression without one does not
suppress — it escalates to RPL000 so "all suppressions carry reasons"
is enforced by the tool itself rather than by review.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Iterable

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,]+)[ \t]*(.*?)\s*$"
)

SEVERITIES = ("error", "warning")


@dataclasses.dataclass
class Finding:
    rule: str
    severity: str
    path: str            # repo-relative, forward slashes
    line: int
    message: str
    suppressed: bool = False
    reason: str = ""     # justification text when suppressed

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        tag = f" [suppressed: {self.reason}]" if self.suppressed else ""
        return (
            f"{self.path}:{self.line}: {self.rule} {self.severity}: "
            f"{self.message}{tag}"
        )


@dataclasses.dataclass
class Suppression:
    rules: tuple[str, ...]
    reason: str
    line: int
    used: bool = False


class SourceFile:
    """One parsed file: AST + per-line suppression directives."""

    def __init__(self, path: Path, relpath: str, text: str):
        self.path = path
        self.relpath = relpath.replace("\\", "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree: ast.AST | None = None
        self.parse_error: str | None = None
        try:
            self.tree = ast.parse(text, filename=str(path))
        except SyntaxError as exc:  # surfaced as its own finding
            self.parse_error = f"syntax error: {exc.msg} (line {exc.lineno})"
        self.suppressions: dict[int, Suppression] = {}
        for i, line in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(line)
            if m:
                rules = tuple(
                    r.strip().upper() for r in m.group(1).split(",") if r.strip()
                )
                self.suppressions[i] = Suppression(
                    rules=rules, reason=m.group(2).strip(), line=i
                )

    def suppression_for(self, rule: str, line: int) -> Suppression | None:
        """Directive on the finding's line, or on the line directly above."""
        for ln in (line, line - 1):
            sup = self.suppressions.get(ln)
            if sup and rule in sup.rules:
                # a directive on the previous line only counts if that
                # line is comment-only (otherwise it belongs to the code
                # on that line, not to ours)
                if ln == line - 1:
                    stripped = self.lines[ln - 1].lstrip()
                    if not stripped.startswith("#"):
                        continue
                return sup
        return None


@dataclasses.dataclass
class Rule:
    rule_id: str
    severity: str
    summary: str
    check_file: Callable[[SourceFile], list[Finding]] | None = None
    check_project: Callable[[list[SourceFile]], list[Finding]] | None = None


def in_scope(
    relpath: str,
    dirs: tuple[str, ...] = (),
    suffixes: tuple[str, ...] = (),
) -> bool:
    """Path-based rule scoping that works both for the real tree
    (``src/repro/kernels/fleet_ucb.py``) and for test fixtures living in
    a tmp dir (``kernels/fleet_ucb.py``): a directory name matches as a
    path segment, a suffix matches the tail of the path."""
    p = "/" + relpath.replace("\\", "/")
    for d in dirs:
        if f"/{d}/" in p:
            return True
    for s in suffixes:
        if p.endswith("/" + s.lstrip("/")):
            return True
    return False


def load_files(root: Path, paths: Iterable[Path]) -> list[SourceFile]:
    files: list[SourceFile] = []
    seen: set[Path] = set()
    for p in paths:
        p = p.resolve()
        candidates = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in candidates:
            if f in seen or f.suffix != ".py":
                continue
            seen.add(f)
            try:
                rel = str(f.relative_to(root.resolve()))
            except ValueError:
                rel = f.name
            files.append(SourceFile(f, rel, f.read_text(encoding="utf-8")))
    return files


def run_rules(
    files: list[SourceFile], rules: list[Rule]
) -> list[Finding]:
    findings: list[Finding] = []
    for sf in files:
        if sf.parse_error:
            findings.append(
                Finding("RPL000", "error", sf.relpath, 1, sf.parse_error)
            )
    parsed = [sf for sf in files if sf.tree is not None]
    by_rel = {sf.relpath: sf for sf in parsed}
    for rule in rules:
        raw: list[Finding] = []
        if rule.check_file:
            for sf in parsed:
                raw.extend(rule.check_file(sf))
        if rule.check_project:
            raw.extend(rule.check_project(parsed))
        for f in raw:
            sf = by_rel.get(f.path)
            if sf is not None:
                sup = sf.suppression_for(f.rule, f.line)
                if sup is not None:
                    sup.used = True
                    if not sup.reason:
                        findings.append(
                            Finding(
                                "RPL000",
                                "error",
                                f.path,
                                sup.line,
                                "suppression without a justification: "
                                f"disable={f.rule} must carry a one-line "
                                "reason after the rule list",
                            )
                        )
                        # the reasonless directive does NOT suppress
                    else:
                        f.suppressed = True
                        f.reason = sup.reason
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def render_human(findings: list[Finding], show_suppressed: bool = False) -> str:
    active = [f for f in findings if not f.suppressed]
    shown = findings if show_suppressed else active
    out = [f.format() for f in shown]
    n_err = sum(1 for f in active if f.severity == "error")
    n_warn = sum(1 for f in active if f.severity == "warning")
    n_sup = sum(1 for f in findings if f.suppressed)
    out.append(
        f"repro-lint: {n_err} error(s), {n_warn} warning(s), "
        f"{n_sup} suppressed"
    )
    return "\n".join(out)


def render_json(findings: list[Finding]) -> str:
    active = [f for f in findings if not f.suppressed]
    return json.dumps(
        {
            "findings": [f.to_dict() for f in findings],
            "counts": {
                "error": sum(1 for f in active if f.severity == "error"),
                "warning": sum(1 for f in active if f.severity == "warning"),
                "suppressed": sum(1 for f in findings if f.suppressed),
            },
        },
        indent=2,
    )


def exit_code(findings: list[Finding]) -> int:
    return 1 if any(not f.suppressed for f in findings) else 0
