"""Shared leaf constants (no intra-repro imports, so both the core and
kernels packages can depend on it without layering cycles).

Default EnergyUCB hyperparameters, recalibrated to the normalized
reward scale in PR 1: rewards are ~[-1, 0], per-arm gaps on flat
landscapes sit below 0.01, so the switching penalty must stay under
that gap scale or SA-UCB locks into a near-best arm forever (see
ROADMAP.md design notes and tests/test_bandit.py).
"""

DEFAULT_ALPHA = 0.1  # UCB exploration coefficient
DEFAULT_LAM = 0.02  # switching penalty
