"""Batched serving engine: prefill + greedy decode with slot-based
continuous batching (finished slots are refilled from the request
queue), optionally under an EnergyController (each prefill/decode call
is one decision interval on the controller's EnergyBackend).

The KV cache is allocated once at (n_slots, max_len) and prefill writes
into a slot's prefix — decode steps are a single jitted call for the
whole batch.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelBundle


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    eos_id: int = -1  # -1: never stops early
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot engine. For families with per-request state (ssm /
    hybrid / encdec) the whole batch is prefilled together; the dense/
    moe/vlm path supports per-slot refill via cache splicing."""

    def __init__(self, bundle: ModelBundle, params, n_slots: int, max_len: int,
                 controller=None):
        self.bundle = bundle
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.energy = controller
        self._decode = jax.jit(bundle.decode)
        self._prefill = jax.jit(bundle.prefill)
        # greedy head jitted once, closing over the vocab size — the
        # logits buffer may be padded past vocab_size, and re-slicing
        # it in numpy every step re-materialized the whole row
        v = bundle.cfg.vocab_size
        self._argmax = jax.jit(
            lambda logits: jnp.argmax(logits[:, :v], axis=-1).astype(jnp.int32)
        )
        # telemetry the workload layer and benchmarks read from one
        # place: counts, emitted decode tokens, per-wave wall time,
        # and the request-queue depth behind the current wave
        self.stats: Dict[str, float] = {
            "prefills": 0,
            "decode_steps": 0,
            "decode_tokens": 0,
            "wave_time_s": 0.0,
            "last_wave_s": 0.0,
            "queue_depth": 0,
        }

    def _greedy(self, logits) -> np.ndarray:
        return np.asarray(self._argmax(logits))

    def generate(self, requests: List[Request]) -> List[Request]:
        """Run a batch of requests to completion (batched prefill, then
        lockstep greedy decode; slot i serves request i; with more
        requests than slots, waves of n_slots are processed)."""
        out: List[Request] = []
        for i in range(0, len(requests), self.n_slots):
            self.stats["queue_depth"] = len(requests) - i - min(
                self.n_slots, len(requests) - i
            )
            t0 = time.perf_counter()
            out.extend(self._wave(requests[i : i + self.n_slots]))
            dt = time.perf_counter() - t0
            self.stats["last_wave_s"] = dt
            self.stats["wave_time_s"] += dt
        self.stats["queue_depth"] = 0
        return out

    def _wave(self, reqs: List[Request]) -> List[Request]:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        cfg = self.bundle.cfg
        if cfg.family == "vlm":
            batch["img_emb"] = jnp.zeros(
                (b, cfg.num_img_patches, cfg.d_model), jnp.float32
            )
        if cfg.family == "encdec":
            batch = {
                "frames": jnp.zeros((b, cfg.decode_enc_len, cfg.d_model), jnp.float32),
                "tokens": jnp.asarray(toks),
            }

        def do_prefill():
            return self._prefill(self.params, batch)

        logits, cache = self._run(do_prefill)
        self.stats["prefills"] += b
        cache = self._grow_cache(cache, plen)
        next_tok = self._greedy(logits)
        index = plen
        max_new = max(r.max_new for r in reqs)
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if not r.done:
                    r.out.append(int(next_tok[i]))
                    self.stats["decode_tokens"] += 1
                    if next_tok[i] == r.eos_id or len(r.out) >= r.max_new:
                        r.done = True
            if all(r.done for r in reqs) or index >= self.max_len - 1:
                break
            db = {"token": jnp.asarray(next_tok), "index": jnp.int32(index)}

            def do_decode():
                return self._decode(self.params, cache, db)

            logits, cache = self._run(do_decode)
            self.stats["decode_steps"] += 1
            next_tok = self._greedy(logits)
            index += 1
        return reqs

    def _run(self, fn):
        if self.energy is not None:
            return self.energy.step(fn)["work"]
        return fn()

    def _grow_cache(self, cache, plen: int):
        """Pad prefill-produced caches out to max_len on the seq axis
        (dense/moe/vlm/hybrid KV stacks; ssm state is length-free)."""
        cfg = self.bundle.cfg
        if cfg.family == "ssm":
            return cache
        target = self.max_len

        def pad(x):
            # seq axis = the axis with size plen (KV stacks: (..., S, KV, HD))
            shape = list(x.shape)
            try:
                ax = shape.index(plen)
            except ValueError:
                return x
            if shape[ax] >= target:
                return x
            pads = [(0, 0)] * len(shape)
            pads[ax] = (0, target - shape[ax])
            return jnp.pad(x, pads)

        if cfg.family == "hybrid":
            return {
                "ssm": cache["ssm"],
                "k": pad(cache["k"]),
                "v": pad(cache["v"]),
            }
        if cfg.family == "encdec":
            k, v, mk, mv = cache
            return (pad(k), pad(v), mk, mv)
        return jax.tree.map(pad, cache)
