"""ServingBackend: LLM serving under request traffic as an EnergyBackend.

Each decision interval runs one continuous-batching serve loop per node
— slot refill from the arrival queue, one unbatched prefill per admitted
request, lockstep decode waves over the occupied slots — against the
roofline-parameterized per-phase physics of a real model config
(:class:`ServePhysics`, terms from ``repro.roofline.analysis``):

- **prefill** is compute-dominated (per-token matmul flops vs a fixed
  weight-streaming pass), so its step time stretches as 1/x at reduced
  relative frequency x = f/f_max — low frequency costs latency;
- **decode** is bandwidth-dominated (weights + KV cache streamed per
  wave), so its step time is nearly flat in x — low frequency is almost
  free energy savings.

That asymmetry is the whole point of phase-conditioned control:
``phase_split=True`` exposes every node as TWO controller lanes (row ``2m``
= prefill lane, row ``2m+1`` = decode lane of node ``m``), each with its
own counters and its own actuated arm, so per-phase EnergyUCB
controllers ride the existing (N,) hyperparameter-lane machinery and
the fused ``fleet_step`` unchanged. ``phase_split=False`` sums both
phases into one lane per node (the shared-controller baseline).

QoS is a p99-latency SLO against the f_max reference: request latency
(completion minus arrival, queueing included) is logged per node, and
``slo_report`` scores the violation rate against ``slo_s`` =
``slo_factor`` x the analytic no-queueing f_max latency. The bandit-side
coupling is the existing progress feasible set — progress per interval
is the SERVICE RATIO (tokens served / tokens f_max could have served
of the demandable work), which sits at 1.0 for any unsaturated arm and
drops exactly when a too-slow arm saturates the node — the precursor
of the queueing that blows the tail latency.

Counter semantics follow the calibrated simulator: ``core_active_s``
integrates actual engine-busy seconds and ``uncore_active_s`` the
f_max-equivalent service seconds of the work completed, so the
controller's R = UC/UU is the realized per-work slowdown vs f_max
(R == 1 at f_max, load noise divides out) and reward = -E*R/scale is
the energy-delay proxy.

Determinism: all randomness lives in the per-interval-keyed
:class:`~repro.workload.traffic.TrafficGen` streams (one per node,
keyed by GLOBAL node id), the slot loop itself is a deterministic
discrete-event simulation, and arms are observation-determined — so
striped fleets (``local_slice``) and `record_trace` replays are
bit-exact, interval counters included.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.calibration import (
    FREQS_GHZ,
    F_MAX,
    SWITCH_ENERGY_J,
    SWITCH_LATENCY_S,
)
from repro.energy.backend import Counters, EnergyBackend
from repro.energy.model import GAMMA, GAMMA_UNC, P_DYN_W, P_IDLE_W
from repro.roofline.analysis import HW, Hardware, exec_flops, hbm_bytes
from repro.workload.traffic import IntervalTraffic, TrafficConfig, TrafficGen

K = len(FREQS_GHZ)

# serving-class power envelope: deeper idle states than the training
# envelope in repro.energy.model, and a correspondingly larger dynamic
# range — the split that makes bandwidth-bound decode worth downclocking
SERVE_P_IDLE_W = 50.0
SERVE_P_DYN_W = 150.0
# uncore dynamic envelope for factored serving scenarios (p_unc_w=0
# keeps the scalar physics bit-exact). The phase asymmetry is the win:
# compute-bound prefill can shed nearly all of this at y < 1 for ~no
# slowdown, while bandwidth-bound decode must keep y high but sheds
# core power instead — a corner no scalar (core-only) ladder reaches.
SERVE_P_UNC_W = 70.0


@dataclass(frozen=True)
class ServePhysics:
    """Per-phase roofline terms of one serving node (seconds at f_max)
    plus the DVFS power envelope — the same max-overlap step-time and
    P(x) = P_idle + P_dyn * x^gamma * activity decomposition as
    :class:`repro.energy.model.StepEnergyModel`, specialized to the two
    serving phases."""

    # prefill: one unbatched (B=1) pass over a prompt
    t_pre_comp_tok: float  # compute seconds per prompt token
    t_pre_mem_fix: float  # fixed weight-stream seconds per prefill call
    t_pre_mem_tok: float  # memory seconds per prompt token (acts + KV)
    # decode: one lockstep wave over the full slot batch
    t_dec_comp: float
    t_dec_mem: float
    p_idle_w: float = P_IDLE_W
    p_dyn_w: float = P_DYN_W
    gamma: float = GAMMA
    # uncore (HBM) axis: memory time stretches as 1/y at relative uncore
    # clock y, and the chip pays p_unc_w * y^gamma_unc * uu extra. The
    # defaults (p_unc_w = 0, y = 1) make every scalar-ladder path
    # BIT-EXACT with the pre-factored physics: t_mem / 1.0 and + 0.0
    # are IEEE-exact identities, so no branch is needed.
    p_unc_w: float = 0.0
    gamma_unc: float = GAMMA_UNC

    @classmethod
    def from_arch(cls, cfg: ArchConfig, n_slots: int, ctx_len: int,
                  hw: Hardware = HW, **kw) -> "ServePhysics":
        """Derive the five terms from the analytic roofline of ``cfg``
        at serving shapes: prefill at B=1 over ``ctx_len`` tokens (the
        per-call weight stream is the B-independent part), decode at the
        full ``n_slots`` batch with ``ctx_len`` context."""
        ref = max(int(ctx_len), 8)
        shp_p = ShapeConfig("serve_prefill", ref, 1, "prefill")
        shp_d = ShapeConfig("serve_decode", ref, n_slots, "decode")
        lay = cfg.layout
        fl_p = exec_flops(cfg, shp_p, lay)
        hb_p = hbm_bytes(cfg, shp_p, lay, 1, 1)
        pbytes = cfg.param_count() * 2.0  # the per-call weight stream
        fl_d = exec_flops(cfg, shp_d, lay)
        hb_d = hbm_bytes(cfg, shp_d, lay, 1, 1)
        return cls(
            t_pre_comp_tok=fl_p / hw.peak_flops / ref,
            t_pre_mem_fix=pbytes / hw.hbm_bw,
            t_pre_mem_tok=max(hb_p - pbytes, 0.0) / hw.hbm_bw / ref,
            t_dec_comp=fl_d / hw.peak_flops,
            t_dec_mem=hb_d / hw.hbm_bw,
            **kw,
        )

    def _op(self, t_comp: float, t_mem: float, x: float,
            y: float = 1.0) -> Tuple[float, float, float, float]:
        """(wall_s, energy_j, uc, uu) of one op at relative core
        frequency x and relative uncore frequency y — max-overlap step
        time, core stretched by 1/x, memory stretched by 1/y."""
        tc = t_comp / x
        tm = t_mem / y
        t = max(tc, tm, 1e-12)
        uc = tc / t
        uu = max(tm / t, 1e-3)
        # the engine-activity proxy behind the core-dynamic term counts
        # WORK ISSUED (t_mem at the reference clock), not stall time: a
        # slower uncore stretches the wall clock but must not bill extra
        # core-dynamic power. At y = 1 both readings coincide, keeping
        # the scalar path bit-exact.
        act = (tc + t_mem) / (2.0 * t)
        p = (self.p_idle_w + self.p_dyn_w * (x ** self.gamma) * act
             + self.p_unc_w * (y ** self.gamma_unc) * uu)
        return t, p * t, uc, uu

    def prefill(self, plen: int, arm: int, y: float = 1.0):
        """One unbatched prefill at CORE ladder index ``arm`` and
        relative uncore clock ``y`` (factored backends decompose their
        flat product arm before calling)."""
        x = float(FREQS_GHZ[arm]) / F_MAX
        return self._op(plen * self.t_pre_comp_tok,
                        self.t_pre_mem_fix + plen * self.t_pre_mem_tok, x, y)

    def decode_wave(self, arm: int, y: float = 1.0):
        x = float(FREQS_GHZ[arm]) / F_MAX
        return self._op(self.t_dec_comp, self.t_dec_mem, x, y)

    def fmax_latency_s(self, plen: float, olen: float) -> float:
        """Analytic no-queueing request latency at f_max: one prefill
        plus olen decode waves."""
        return (self.prefill(int(round(plen)), K - 1)[0]
                + olen * self.decode_wave(K - 1)[0])


class _Node:
    """Mutable serve-loop state of one node (slots + queue + clock)."""

    __slots__ = ("queue", "slots", "carry_s", "lat", "done_t")

    def __init__(self, n_slots: int):
        self.queue: List[Tuple[float, int, int]] = []  # (arrival_s, plen, olen)
        # slot = None | [phase, plen, olen, produced, arrival_s]
        self.slots: List[Optional[list]] = [None] * n_slots
        self.carry_s = 0.0  # op overrun carried past the interval edge
        self.lat: List[float] = []  # completed-request latencies (s)
        self.done_t: List[float] = []  # absolute completion times (s)


class ServingBackend(EnergyBackend):
    """The serving workload as a streaming :class:`EnergyBackend`.

    ``n_serve_nodes`` independent nodes each run the slot loop against
    their own keyed traffic stream; ``n_nodes`` (the controller-facing
    fleet width) is ``2 * n_serve_nodes`` when ``phase_split`` else
    ``n_serve_nodes``. ``apply_arms`` consumes one arm per LANE.
    """

    def __init__(self, traffic: TrafficConfig, model,
                 n_nodes: int = 1, n_slots: int = 8,
                 phase_split: bool = False, node_offset: int = 0,
                 ctx_len: Optional[int] = None, slo_factor: float = 4.0,
                 hw: Hardware = HW, p_idle_w: float = SERVE_P_IDLE_W,
                 p_dyn_w: float = SERVE_P_DYN_W,
                 uncore_ladder: Optional[Sequence[float]] = None,
                 p_unc_w: float = 0.0):
        from repro.configs import get_arch

        self.traffic = traffic
        self.cfg: ArchConfig = (model if isinstance(model, ArchConfig)
                                else get_arch(model))
        self._m = int(n_nodes)
        self.n_slots = int(n_slots)
        self.phase_split = bool(phase_split)
        self._offset = int(node_offset)
        self.slo_factor = float(slo_factor)
        self._hw = hw
        self._pw = (float(p_idle_w), float(p_dyn_w))
        # factored product ladder: flat arm i = (core i // k_unc,
        # uncore i % k_unc), uncore MINOR and ascending to 1.0 so flat
        # arm n_arms-1 is the (f_max, max-uncore) default/QoS reference
        # corner. uncore_ladder=None keeps the scalar ladder verbatim.
        self.unc_freqs: Tuple[float, ...] = (
            tuple(float(v) for v in uncore_ladder)
            if uncore_ladder is not None else (1.0,))
        if (self.unc_freqs[-1] != 1.0
                or any(b <= a for a, b in zip(self.unc_freqs,
                                              self.unc_freqs[1:]))
                or self.unc_freqs[0] <= 0.0):
            raise ValueError(
                f"uncore_ladder must ascend to 1.0, got {self.unc_freqs}")
        self.k_unc = len(self.unc_freqs)
        self.n_arms = K * self.k_unc
        self._p_unc_w = float(p_unc_w)
        self.ctx_len = int(ctx_len if ctx_len is not None
                           else traffic.prompt_mean + traffic.output_mean)
        self.phys = ServePhysics.from_arch(self.cfg, self.n_slots,
                                           self.ctx_len, hw=hw,
                                           p_idle_w=p_idle_w,
                                           p_dyn_w=p_dyn_w,
                                           p_unc_w=self._p_unc_w)
        # decode tables are plen-independent: precompute all flat arms
        self._dec = [self.phys.decode_wave(*self._split(a))
                     for a in range(self.n_arms)]

        self._gens = [TrafficGen(traffic, node_id=self._offset + m)
                      for m in range(self._m)]
        self._nodes = [_Node(self.n_slots) for _ in range(self._m)]
        self._interval = 0
        n = self.n_nodes
        self._arms = np.full((n,), self.n_arms - 1, np.int32)
        self._prev_arms = self._arms.copy()
        self._energy = np.zeros(n, np.float64)
        self._core = np.zeros(n, np.float64)
        self._uncore = np.zeros(n, np.float64)
        self._time = np.zeros(n, np.float64)
        self._progress = np.zeros(n, np.float64)
        self._switches = np.zeros(n, np.int64)
        self._served_prompt_tok = 0
        self._served_decode_tok = 0

        # reward normalization + f_max reference, from the OFFERED load
        # (long-run mean rate). Counter semantics follow the calibrated
        # simulator: UC integrates ACTUAL engine-busy seconds, UU
        # integrates the f_max-EQUIVALENT service seconds of the work
        # completed (the throughput-tracking copy-engine counter), so
        # the derived R = UC/UU is the realized per-work slowdown vs
        # f_max — R == 1 at f_max by construction, load noise divides
        # out of R, and reward = -E*R/scale is the energy-delay proxy
        # with scale = the expected f_max interval energy per lane
        r = traffic.mean_rate_rps
        dt = traffic.interval_s
        mp, mo = traffic.prompt_mean, traffic.output_mean
        tp, ep = self.phys.prefill(int(round(mp)), K - 1)[:2]
        td, ed = self._dec[-1][:2]
        busy_p = r * dt * tp  # expected prefill-busy seconds / interval
        waves = r * dt * mo / self.n_slots  # full-batch wave estimate
        busy_d = waves * td
        idle = max(dt - busy_p - busy_d, 0.0) * self.phys.p_idle_w
        e_p, e_d = r * dt * ep, waves * ed
        if self.phase_split:
            scale = np.empty(n, np.float64)
            scale[0::2] = max(e_p + idle / 2, 1e-9)
            scale[1::2] = max(e_d + idle / 2, 1e-9)
            base_e = np.empty(n, np.float64)
            base_e[0::2] = e_p + idle / 2
            base_e[1::2] = e_d + idle / 2
        else:
            scale = np.full(n, max(e_p + e_d + idle, 1e-9))
            base_e = np.full(n, e_p + e_d + idle)
        self._scale = scale
        self._base_e = base_e
        self.slo_s = self.slo_factor * self.phys.fmax_latency_s(mp, mo)

    # -- EnergyBackend surface -----------------------------------------
    @property
    def n_serve_nodes(self) -> int:
        return self._m

    @property
    def n_nodes(self) -> int:
        return self._m * (2 if self.phase_split else 1)

    @property
    def ladder_ghz(self) -> Sequence[float]:
        """Per-FLAT-arm core GHz (uncore minor): the scalar ladder when
        ``k_unc == 1``, else each core step repeated ``k_unc`` times."""
        if self.k_unc == 1:
            return tuple(FREQS_GHZ)
        return tuple(float(g) for g in np.repeat(FREQS_GHZ, self.k_unc))

    @property
    def uncore_ladder(self) -> Tuple[float, ...]:
        return self.unc_freqs

    def _split(self, flat: int) -> Tuple[int, float]:
        """Flat product arm -> (core ladder index, relative uncore y)."""
        return flat // self.k_unc, self.unc_freqs[flat % self.k_unc]

    @property
    def interval_s(self) -> float:
        return self.traffic.interval_s

    @property
    def reward_scale(self):
        return self._scale

    def baseline_interval(self):
        """Analytic EXPECTED per-interval f_max energy under the offered
        load (the benchmark's headline baseline is a real static-f_max
        run; this feeds ``summary()``'s saved-energy estimate)."""
        return self._base_e.copy(), np.full(self.n_nodes,
                                            self.traffic.interval_s)

    def apply_arms(self, arms) -> None:
        a = np.asarray(arms, np.int32)
        self._arms = np.broadcast_to(
            a.reshape(-1) if a.ndim > 1 else a, (self.n_nodes,)).copy()

    def _lanes(self, m: int) -> Tuple[int, int]:
        """(prefill lane, decode lane) row indices of node m."""
        return (2 * m, 2 * m + 1) if self.phase_split else (m, m)

    @property
    def interval_index(self) -> int:
        return self._interval

    def advance(self, work_fn: Optional[Callable[[], Any]] = None) -> Any:
        out = work_fn() if work_fn is not None else None
        dt = self.traffic.interval_s
        for m in range(self._m):
            self._advance_node(m, self._gens[m].next_interval(), dt)
        self._time += dt
        self._prev_arms = self._arms.copy()
        self._interval += 1
        return out

    def _advance_node(self, m: int, iv: IntervalTraffic, dt: float) -> None:
        lp, ld = self._lanes(m)
        arm_p, arm_d = int(self._arms[lp]), int(self._arms[ld])
        core_p, y_p = self._split(arm_p)
        st = self._nodes[m]
        t0 = self._interval * dt
        for off, pl, ol in zip(iv.offsets_s, iv.prompt_len, iv.output_len):
            st.queue.append((t0 + float(off), int(pl), int(ol)))

        cursor = st.carry_s
        # frequency switches cost energy and a settle latency up front
        for lane, arm in ((lp, arm_p), (ld, arm_d)) if lp != ld \
                else ((lp, arm_p),):
            if arm != int(self._prev_arms[lane]):
                self._switches[lane] += 1
                self._energy[lane] += SWITCH_ENERGY_J
                cursor += SWITCH_LATENCY_S
        # demandable work this interval at the f_max reference rate —
        # the denominator of the service-ratio progress counter. Load
        # noise (how much happened to arrive) divides out; what remains
        # is the arm-dependent part: a too-slow arm saturates the node
        # and serves a FRACTION of what f_max would have, which is
        # exactly the slowdown the QoS feasible set prices — and the
        # precursor of the queueing that blows the p99 tail
        t_wd_ref = self._dec[-1][0]
        cap_d = dt / t_wd_ref  # decode tokens one slot can demand
        rem_p = rem_d = 0.0
        for sl in st.slots:
            if sl is not None:
                if sl[0] == "prefill":
                    rem_p += sl[1]
                    rem_d += min(sl[2], cap_d)
                else:
                    rem_d += min(sl[2] - sl[3], cap_d)
        t_end = t0 + dt
        for a, pl, ol in st.queue:
            w = min(max(t_end - a, 0.0), dt) / dt
            rem_p += pl * w
            rem_d += min(ol, cap_d * w)

        e_idle = [0.0, 0.0]  # [prefill share, decode share]
        tok_p = tok_d = 0
        td, ed = self._dec[arm_d][:2]
        while cursor < dt:
            now = t0 + cursor
            # slot refill from the arrival queue (FIFO, arrived only)
            qi = 0
            for s in range(self.n_slots):
                if st.slots[s] is None and qi < len(st.queue) \
                        and st.queue[qi][0] <= now:
                    a, pl, ol = st.queue[qi]
                    st.slots[s] = ["prefill", pl, ol, 0, a]
                    qi += 1
            if qi:
                del st.queue[:qi]
            pre = next((sl for sl in st.slots if sl is not None
                        and sl[0] == "prefill"), None)
            if pre is not None:
                t, e = self.phys.prefill(pre[1], core_p, y_p)[:2]
                self._energy[lp] += e
                self._core[lp] += t  # actual busy
                # f_max-equivalent service time of this prompt
                self._uncore[lp] += self.phys.prefill(pre[1], K - 1)[0]
                cursor += t
                tok_p += pre[1]
                pre[0] = "decode"
                continue
            dec = [sl for sl in st.slots if sl is not None]
            if dec:
                self._energy[ld] += ed
                self._core[ld] += td
                self._uncore[ld] += t_wd_ref  # same wave at f_max
                cursor += td
                done_at = t0 + cursor
                tok_d += len(dec)
                for sl in dec:
                    sl[3] += 1
                    if sl[3] >= sl[2]:
                        st.lat.append(done_at - sl[4])
                        st.done_t.append(done_at)
                        st.slots[st.slots.index(sl)] = None
                continue
            # idle: jump to the next arrival (or the interval edge)
            nxt = min(st.queue[0][0] - t0, dt) if st.queue else dt
            nxt = max(nxt, cursor + 1e-9)
            share = (nxt - min(cursor, dt)) if cursor < dt else 0.0
            half = 0.5 if self.phase_split else 1.0
            e_idle[0] += share * self.phys.p_idle_w * half
            if self.phase_split:
                e_idle[1] += share * self.phys.p_idle_w * 0.5
            cursor = nxt
        st.carry_s = max(cursor - dt, 0.0)
        self._energy[lp] += e_idle[0]
        if self.phase_split:
            self._energy[ld] += e_idle[1]
        ratio_p = min(tok_p / rem_p, 1.0) if rem_p >= 1.0 else 1.0
        ratio_d = min(tok_d / rem_d, 1.0) if rem_d >= 1.0 else 1.0
        if self.phase_split:
            self._progress[lp] += ratio_p
            self._progress[ld] += ratio_d
        else:
            self._progress[lp] += 0.5 * (ratio_p + ratio_d)
        self._served_prompt_tok += tok_p
        self._served_decode_tok += tok_d

    def read_counters(self) -> Counters:
        n = self.n_nodes
        return Counters(
            energy_j=self._energy.copy(),
            core_active_s=self._core.copy(),
            uncore_active_s=self._uncore.copy(),
            timestamp_s=self._time.copy(),
            progress=self._progress.copy(),
            switches=self._switches.astype(np.int32),
            active=np.ones(n, bool),
        )

    def local_slice(self, lo: int, hi: int) -> "ServingBackend":
        """The lane stripe [lo, hi) as a fresh backend. With
        ``phase_split`` the stripe must align to node boundaries (both
        lanes of a node live on one host)."""
        f = 2 if self.phase_split else 1
        if not 0 <= lo < hi <= self.n_nodes:
            raise ValueError(
                f"slice [{lo}, {hi}) out of range for N={self.n_nodes}")
        if lo % f or hi % f:
            raise ValueError(
                f"phase-split lanes pair per node: slice [{lo}, {hi}) "
                "must be even-aligned")
        return ServingBackend(
            self.traffic, self.cfg, n_nodes=(hi - lo) // f,
            n_slots=self.n_slots, phase_split=self.phase_split,
            node_offset=self._offset + lo // f, ctx_len=self.ctx_len,
            slo_factor=self.slo_factor, hw=self._hw,
            p_idle_w=self._pw[0], p_dyn_w=self._pw[1],
            uncore_ladder=(self.unc_freqs if self.k_unc > 1 else None),
            p_unc_w=self._p_unc_w)

    # -- serving telemetry ---------------------------------------------
    @property
    def served_tokens(self) -> int:
        """Generated (decode) tokens across the fleet — the denominator
        of joules-per-served-token."""
        return self._served_decode_tok

    @property
    def queue_depths(self) -> np.ndarray:
        return np.asarray([len(nd.queue) for nd in self._nodes])

    def latencies(self, since_s: float = 0.0) -> np.ndarray:
        """Completed-request latencies (s) across all nodes, restricted
        to completions at absolute time >= ``since_s``."""
        out = [l for nd in self._nodes
               for t, l in zip(nd.done_t, nd.lat) if t >= since_s]
        return np.asarray(out, np.float64)

    def slo_report(self, warmup_s: float = 0.0,
                   slo_s: Optional[float] = None) -> Dict[str, float]:
        """p50/p99 latency and the SLO violation rate over completions
        after ``warmup_s`` (the paper's post-warmup QoS accounting)."""
        slo = self.slo_s if slo_s is None else float(slo_s)
        lat = self.latencies(since_s=warmup_s)
        if lat.size == 0:
            return {"completed": 0, "p50_s": float("nan"),
                    "p99_s": float("nan"), "slo_s": slo,
                    "violation_rate": float("nan")}
        return {
            "completed": int(lat.size),
            "p50_s": float(np.percentile(lat, 50)),
            "p99_s": float(np.percentile(lat, 99)),
            "slo_s": slo,
            "violation_rate": float(np.mean(lat > slo)),
        }

    def busy_fractions(self, rate_rps: Optional[float] = None,
                       arm_p: int = -1, arm_d: int = -1
                       ) -> Dict[str, float]:
        """Analytic per-interval busy-time shares at a given load and
        FLAT arm pair (negative = the top/f_max corner) — the
        scenario-sizing diagnostic (keep the f_max total under 1.0 and
        the low-f total near/over 1.0 for a QoS-binding burst)."""
        r = self.traffic.mean_rate_rps if rate_rps is None else rate_rps
        dt = self.traffic.interval_s
        arm_p = arm_p if arm_p >= 0 else self.n_arms - 1
        arm_d = arm_d if arm_d >= 0 else self.n_arms - 1
        tp = self.phys.prefill(int(round(self.traffic.prompt_mean)),
                               *self._split(arm_p))
        tp = tp[0]
        td = self._dec[arm_d][0]
        waves = r * dt * self.traffic.output_mean / self.n_slots
        return {
            "prefill": r * dt * tp / dt,
            "decode": waves * td / dt,
            "total": (r * dt * tp + waves * td) / dt,
        }
