"""Deterministic request-traffic generator for the serving workload.

Three arrival processes, composable in one config (DESIGN: a bursty
diurnal trace is just ``diurnal_depth > 0`` plus ``burst_mult > 1``):

- **poisson**: homogeneous Poisson arrivals at ``rate_rps``.
- **diurnal**: the rate is modulated by a sinusoid with period
  ``diurnal_period`` intervals and relative depth ``diurnal_depth``
  (the day/night load swing every serving fleet sees).
- **bursty**: an MMPP-style two-state (on/off) modulator; in the ON
  state the rate is multiplied by ``burst_mult``, and the state flips
  with per-interval probability 1/mean-duration (geometric episode
  lengths — the discrete-time Markov-modulated Poisson process).

Prompt and output lengths are lognormal (arithmetic mean pinned to
``prompt_mean``/``output_mean``), clipped to [1, max].

Determinism contract (tests/test_workload.py): every draw for global
interval ``t`` of node ``node_id`` comes from a fresh
``np.random.Generator`` seeded by the tuple ``(seed, node_id, t)`` —
NOT from one long stream — so chunked generation (any chunking),
one-shot generation, and per-host striped generation all produce
bit-identical arrival/length streams. Only the MMPP on/off state is
sequential, and it is a deterministic function of the per-interval
draws from t=0, so every replay walks the same state path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, NamedTuple

import numpy as np


class IntervalTraffic(NamedTuple):
    """The requests arriving in one decision interval (one node).

    ``offsets_s`` are sorted arrival times within the interval (seconds
    from the interval start); lengths are per-request token counts."""

    offsets_s: np.ndarray  # (n,) float64, sorted, in [0, interval_s)
    prompt_len: np.ndarray  # (n,) int32, >= 1
    output_len: np.ndarray  # (n,) int32, >= 1


@dataclass(frozen=True)
class TrafficConfig:
    """One node's request process. All knobs compose; the presets below
    name the three canonical scenarios."""

    rate_rps: float = 5.0  # base mean arrival rate (requests / s)
    interval_s: float = 0.25  # decision-interval wall time
    # request shape: lognormal with pinned arithmetic mean. The default
    # prompt/output split is prefill-heavy on purpose: prefill is the
    # phase whose latency stretches under DVFS, so it must carry enough
    # of the load for the frequency choice to move the p99
    prompt_mean: float = 768.0
    prompt_sigma: float = 0.4  # log-space sigma
    prompt_max: int = 2048
    output_mean: float = 16.0
    output_sigma: float = 0.4
    output_max: int = 96
    # diurnal modulation: rate *= 1 + depth * sin(2*pi*t / period)
    diurnal_period: int = 0  # intervals per cycle; 0 disables
    diurnal_depth: float = 0.0
    # MMPP on/off bursts: rate *= burst_mult while ON
    burst_mult: float = 1.0  # 1.0 disables
    burst_on_mean: float = 16.0  # mean ON duration (intervals)
    burst_off_mean: float = 48.0  # mean OFF duration (intervals)
    seed: int = 0

    @property
    def mean_rate_rps(self) -> float:
        """Long-run mean arrival rate (diurnal averages out; the burst
        duty cycle does not)."""
        duty = (self.burst_on_mean / (self.burst_on_mean + self.burst_off_mean)
                if self.burst_mult != 1.0 else 0.0)
        return self.rate_rps * (1.0 + duty * (self.burst_mult - 1.0))


def poisson_traffic(rate_rps: float = 5.0, **kw) -> TrafficConfig:
    return TrafficConfig(rate_rps=rate_rps, **kw)


def diurnal_traffic(rate_rps: float = 5.0, period: int = 240,
                    depth: float = 0.3, **kw) -> TrafficConfig:
    return TrafficConfig(rate_rps=rate_rps, diurnal_period=period,
                         diurnal_depth=depth, **kw)


def bursty_traffic(rate_rps: float = 5.0, mult: float = 3.0,
                   on_mean: float = 16.0, off_mean: float = 48.0,
                   **kw) -> TrafficConfig:
    return TrafficConfig(rate_rps=rate_rps, burst_mult=mult,
                         burst_on_mean=on_mean, burst_off_mean=off_mean, **kw)


def bursty_diurnal_traffic(rate_rps: float = 5.0, **kw) -> TrafficConfig:
    """The benchmark's headline scenario: day/night swing plus on/off
    load bursts riding on top of it. Sized so static f_max keeps the
    p99 SLO with headroom while the lowest frequency overloads prefill
    during peak bursts — the region where QoS control earns its keep."""
    base = dict(diurnal_period=240, diurnal_depth=0.3, burst_mult=3.0,
                burst_on_mean=16.0, burst_off_mean=48.0)
    base.update(kw)
    return TrafficConfig(rate_rps=rate_rps, **base)


class TrafficGen:
    """Streaming per-node generator over a :class:`TrafficConfig`.

    ``take(T)`` yields the next T :class:`IntervalTraffic` rows and
    advances the cursor; any chunking of calls produces the same rows
    (the per-interval keyed-RNG contract above)."""

    def __init__(self, cfg: TrafficConfig, node_id: int = 0,
                 start_interval: int = 0):
        self.cfg = cfg
        self.node_id = int(node_id)
        self._t = 0
        self._on = False  # MMPP state entering interval 0: OFF
        if start_interval:
            self.skip(start_interval)

    @property
    def interval_index(self) -> int:
        """Global index of the next interval to generate."""
        return self._t

    def _rng(self, t: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, self.node_id, t]))

    def _step_state(self, u: float) -> bool:
        """Advance the MMPP state for one interval; returns the state in
        effect DURING that interval (pre-transition draw u)."""
        c = self.cfg
        if c.burst_mult == 1.0:
            return False
        if self._on:
            if u < 1.0 / max(c.burst_on_mean, 1.0):
                self._on = False
        else:
            if u < 1.0 / max(c.burst_off_mean, 1.0):
                self._on = True
        return self._on

    def _rate(self, t: int, on: bool) -> float:
        c = self.cfg
        r = c.rate_rps
        if c.diurnal_period > 0:
            r *= 1.0 + c.diurnal_depth * math.sin(
                2.0 * math.pi * t / c.diurnal_period)
        if on:
            r *= c.burst_mult
        return max(r, 0.0)

    def _lengths(self, rng, n: int, mean: float, sigma: float,
                 cap: int) -> np.ndarray:
        draw = rng.lognormal(math.log(mean) - 0.5 * sigma * sigma, sigma,
                             size=n)
        return np.clip(np.round(draw), 1, cap).astype(np.int32)

    def next_interval(self) -> IntervalTraffic:
        c = self.cfg
        t = self._t
        rng = self._rng(t)
        # fixed draw order per interval: burst transition, count,
        # offsets, prompt lengths, output lengths — the order IS the
        # determinism contract, never reorder
        on = self._step_state(rng.random())
        n = int(rng.poisson(self._rate(t, on) * c.interval_s))
        offsets = np.sort(rng.random(n)) * c.interval_s
        plen = self._lengths(rng, n, c.prompt_mean, c.prompt_sigma,
                             c.prompt_max)
        olen = self._lengths(rng, n, c.output_mean, c.output_sigma,
                             c.output_max)
        self._t += 1
        return IntervalTraffic(offsets, plen, olen)

    def take(self, n_intervals: int) -> List[IntervalTraffic]:
        return [self.next_interval() for _ in range(n_intervals)]

    def skip(self, n_intervals: int) -> None:
        """Advance the cursor without materializing requests (the MMPP
        state still has to walk every interval)."""
        for _ in range(n_intervals):
            t = self._t
            self._step_state(self._rng(t).random())
            self._t += 1


def concat_intervals(rows: List[IntervalTraffic],
                     interval_s: float) -> IntervalTraffic:
    """Flatten T interval rows into one absolute-time stream (offsets
    become seconds from the FIRST interval's start) — the one-shot view
    the chunking tests compare against."""
    offs = [r.offsets_s + i * interval_s for i, r in enumerate(rows)]
    cat = lambda xs, d: (np.concatenate(xs) if xs
                         else np.zeros(0, d))
    return IntervalTraffic(
        cat(offs, np.float64),
        cat([r.prompt_len for r in rows], np.int32),
        cat([r.output_len for r in rows], np.int32),
    )
