"""Request-driven serving workloads for the energy control plane.

This package is where TRAFFIC, not a fixed app schedule, drives the
load the bandit sees:

- :mod:`repro.workload.traffic` — deterministic seeded request
  processes (Poisson / diurnal / bursty MMPP), keyed per (seed,
  node, interval) so chunked, one-shot, and striped generation are
  bit-identical.
- :mod:`repro.workload.serving_backend` — the continuous-batching
  serve loop (slot refill from the arrival queue, unbatched prefill,
  lockstep decode waves) as an :class:`~repro.energy.backend
  .EnergyBackend`, with per-phase roofline physics: compute-bound
  prefill stretches 1/x under DVFS, bandwidth-bound decode barely
  moves — so ``phase_split=True`` lanes (prefill row / decode row per
  node) let per-phase EnergyUCB controllers capture both sweet spots
  through the one fused ``fleet_step``. QoS is a p99-latency SLO
  against the f_max reference (``slo_report``); the bandit enforces it
  through the existing progress feasible set.

Entry points: ``benchmarks/serve_energy.py`` (joules-per-served-token
vs SLO-violation-rate on a bursty diurnal trace) and
``repro.launch.fleet_serve --workload serve``.
"""
from repro.workload.serving_backend import ServePhysics, ServingBackend
from repro.workload.traffic import (
    IntervalTraffic,
    TrafficConfig,
    TrafficGen,
    bursty_diurnal_traffic,
    bursty_traffic,
    concat_intervals,
    diurnal_traffic,
    poisson_traffic,
)

__all__ = [
    "IntervalTraffic",
    "ServePhysics",
    "ServingBackend",
    "TrafficConfig",
    "TrafficGen",
    "bursty_diurnal_traffic",
    "bursty_traffic",
    "concat_intervals",
    "diurnal_traffic",
    "poisson_traffic",
]
