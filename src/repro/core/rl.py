"""RL baselines adapted to GPU frequency control (paper §4.1):

- RL-Power [Wang+ 2021]: online tabular Q-learning; state = discretized
  core/uncore utilization ratio, actions = the K frequencies.
- DRLCap [Wang+ 2024]: a small DQN (MLP over counter features) with a
  target network. The offline/online protocol variants (20% pretrain +
  1.25x-scaled deployment, -Online, -Cross) live in repro.core.rollout.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policies import Policy
from repro.core.simulator import K_ARMS, Obs

N_BINS = 8


def _ratio_bin(uc, uu):
    r = jnp.log(jnp.clip(uc / uu, 1e-3, 1e3))
    edges = jnp.linspace(-1.5, 2.5, N_BINS - 1)
    return jnp.searchsorted(edges, r).astype(jnp.int32)


def rl_power(
    k: int = K_ARMS,
    lr: float = 0.2,
    gamma: float = 0.9,
    eps: float = 0.1,
    q_init: float = 0.0,
) -> Policy:
    def init(key):
        return {
            "Q": jnp.full((N_BINS, k), q_init, jnp.float32),
            "s": jnp.int32(N_BINS // 2),
            "t": jnp.float32(0.0),
        }

    def select(state, key):
        k1, k2 = jax.random.split(key)
        explore = jax.random.bernoulli(k1, eps)
        rand_arm = jax.random.randint(k2, (), 0, k)
        greedy = jnp.argmax(state["Q"][state["s"]])
        return jnp.where(explore, rand_arm, greedy).astype(jnp.int32)

    def update(state, arm, obs: Obs):
        s, Q = state["s"], state["Q"]
        s2 = _ratio_bin(obs.uc, obs.uu)
        td = obs.reward + gamma * jnp.max(Q[s2]) - Q[s, arm]
        Q = Q.at[s, arm].add(lr * td)
        return {"Q": Q, "s": s2, "t": state["t"] + 1.0}

    return Policy("RL-Power", init, select, update)


# ---------------------------------------------------------------------------
# DRLCap (DQN)
# ---------------------------------------------------------------------------

_HID = 32
_FDIM = K_ARMS + 6


def _features(prev_arm, obs: Obs):
    onehot = jax.nn.one_hot(prev_arm, K_ARMS)
    return jnp.concatenate(
        [
            onehot,
            jnp.stack(
                [
                    obs.uc,
                    obs.uu,
                    jnp.clip(obs.uc / jnp.maximum(obs.uu, 1e-3), 0, 20.0) / 10.0,
                    obs.energy_j / 30.0,
                    obs.progress * 1e3,
                    jnp.float32(1.0),
                ]
            ),
        ]
    )


def _qnet(p, phi):
    h = jax.nn.relu(phi @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def drlcap(
    k: int = K_ARMS,
    lr: float = 1e-2,
    gamma: float = 0.9,
    sync_every: int = 200,
    trainable: bool = True,
    name: str = "DRLCap",
) -> Policy:
    def init(key):
        k1, k2 = jax.random.split(key)
        net = {
            "w1": jax.random.normal(k1, (_FDIM, _HID)) * 0.1,
            "b1": jnp.zeros((_HID,)),
            "w2": jax.random.normal(k2, (_HID, k)) * 0.1,
            "b2": jnp.zeros((k,)),
        }
        dummy = Obs(
            energy_j=jnp.float32(20.0), uc=jnp.float32(0.9), uu=jnp.float32(0.3),
            progress=jnp.float32(1e-4), reward=jnp.float32(-1.0),
            switched=jnp.bool_(False), active=jnp.bool_(True),
        )
        return {
            "net": net,
            "target": jax.tree.map(jnp.copy, net),
            "phi": _features(jnp.int32(k - 1), dummy),
            "t": jnp.float32(0.0),
        }

    def select(state, key):
        k1, k2 = jax.random.split(key)
        eps = jnp.maximum(0.05, 0.5 * jnp.exp(-state["t"] / 500.0))
        explore = jax.random.bernoulli(k1, eps)
        rand_arm = jax.random.randint(k2, (), 0, k)
        greedy = jnp.argmax(_qnet(state["net"], state["phi"]))
        return jnp.where(explore, rand_arm, greedy).astype(jnp.int32)

    def update(state, arm, obs: Obs):
        phi2 = _features(arm, obs)
        if not trainable:
            return {**state, "phi": phi2, "t": state["t"] + 1.0}
        target = obs.reward + gamma * jnp.max(_qnet(state["target"], phi2))

        def td_loss(net):
            q = _qnet(net, state["phi"])[arm]
            return jnp.square(q - jax.lax.stop_gradient(target))

        grads = jax.grad(td_loss)(state["net"])
        net = jax.tree.map(lambda p, g: p - lr * g, state["net"], grads)
        t = state["t"] + 1.0
        sync = jnp.mod(t, sync_every) < 0.5
        tgt = jax.tree.map(
            lambda tp, np_: jnp.where(sync, np_, tp), state["target"], net
        )
        return {"net": net, "target": tgt, "phi": phi2, "t": t}

    return Policy(name, init, select, update)


def freeze(policy: Policy, name=None) -> Policy:
    """Deployment-mode wrapper: state keeps tracking features but stops
    learning (used by the DRLCap offline->online protocol)."""
    if policy.name.startswith("DRLCap"):
        return drlcap(trainable=False, name=name or policy.name + "-frozen")
    raise ValueError("freeze() currently supports DRLCap policies")
