"""RL baselines adapted to GPU frequency control (paper §4.1):

- RL-Power [Wang+ 2021]: online tabular Q-learning; state = discretized
  core/uncore utilization ratio, actions = the K frequencies.
- DRLCap [Wang+ 2024]: a small DQN (MLP over counter features) with a
  target network. The offline/online protocol variants (20% pretrain +
  1.25x-scaled deployment, -Online, -Cross) live in repro.core.rollout.

Both follow the hyperparams-as-data convention (repro.core.policies):
module-level fns + a params pytree, so the unified rollout engine runs
them without retracing per configuration. DRLCap's trainable/frozen
switch is a data flag resolved with lax.cond, so the offline protocol's
two phases share one trace.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policies import Policy, PolicyFns
from repro.core.simulator import K_ARMS, Obs

N_BINS = 8


def _ratio_bin(uc, uu):
    r = jnp.log(jnp.clip(uc / uu, 1e-3, 1e3))
    edges = jnp.linspace(-1.5, 2.5, N_BINS - 1)
    return jnp.searchsorted(edges, r).astype(jnp.int32)


def _rlp_init(params, key):
    del key
    return {
        "Q": params["q0"],
        "s": jnp.int32(N_BINS // 2),
        "t": jnp.float32(0.0),
    }


def _rlp_select(params, state, key):
    k = state["Q"].shape[-1]
    k1, k2 = jax.random.split(key)
    explore = jax.random.bernoulli(k1, params["eps"])
    rand_arm = jax.random.randint(k2, (), 0, k)
    greedy = jnp.argmax(state["Q"][state["s"]])
    return jnp.where(explore, rand_arm, greedy).astype(jnp.int32)


def _rlp_update(params, state, arm, obs: Obs):
    s, Q = state["s"], state["Q"]
    s2 = _ratio_bin(obs.uc, obs.uu)
    td = obs.reward + params["gamma"] * jnp.max(Q[s2]) - Q[s, arm]
    Q = Q.at[s, arm].add(params["lr"] * td)
    return {"Q": Q, "s": s2, "t": state["t"] + 1.0}


RL_POWER_FNS = PolicyFns(_rlp_init, _rlp_select, _rlp_update)


def rl_power(
    k: int = K_ARMS,
    lr: float = 0.2,
    gamma: float = 0.9,
    eps: float = 0.1,
    q_init: float = 0.0,
) -> Policy:
    params = {
        "q0": jnp.full((N_BINS, k), q_init, jnp.float32),
        "lr": jnp.float32(lr),
        "gamma": jnp.float32(gamma),
        "eps": jnp.float32(eps),
    }
    return Policy("RL-Power", RL_POWER_FNS, params)


# ---------------------------------------------------------------------------
# DRLCap (DQN)
# ---------------------------------------------------------------------------

_HID = 32
_FDIM = K_ARMS + 6


def _features(prev_arm, obs: Obs):
    onehot = jax.nn.one_hot(prev_arm, K_ARMS)
    return jnp.concatenate(
        [
            onehot,
            jnp.stack(
                [
                    obs.uc,
                    obs.uu,
                    jnp.clip(obs.uc / jnp.maximum(obs.uu, 1e-3), 0, 20.0) / 10.0,
                    obs.energy_j / 30.0,
                    obs.progress * 1e3,
                    jnp.float32(1.0),
                ]
            ),
        ]
    )


def _qnet(p, phi):
    h = jax.nn.relu(phi @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _drl_init(params, key):
    k1, k2 = jax.random.split(key)
    net = {
        "w1": jax.random.normal(k1, (_FDIM, _HID)) * 0.1,
        "b1": jnp.zeros((_HID,)),
        "w2": jax.random.normal(k2, (_HID, K_ARMS)) * 0.1,
        "b2": jnp.zeros((K_ARMS,)),
    }
    dummy = Obs(
        energy_j=jnp.float32(20.0), uc=jnp.float32(0.9), uu=jnp.float32(0.3),
        progress=jnp.float32(1e-4), reward=jnp.float32(-1.0),
        switched=jnp.bool_(False), active=jnp.bool_(True),
    )
    return {
        "net": net,
        "target": jax.tree.map(jnp.copy, net),
        # initial prev-arm feature = the environment's f_max default arm
        "phi": _features(params["k"] - 1, dummy),
        "t": jnp.float32(0.0),
    }


def _drl_select(params, state, key):
    k1, k2 = jax.random.split(key)
    eps = jnp.maximum(0.05, 0.5 * jnp.exp(-state["t"] / 500.0))
    explore = jax.random.bernoulli(k1, eps)
    rand_arm = jax.random.randint(k2, (), 0, params["k"])
    # network output stays K_ARMS-wide (static shapes); arms beyond the
    # environment's k are masked out of the greedy pick
    q = _qnet(state["net"], state["phi"])
    q = jnp.where(jnp.arange(K_ARMS) < params["k"], q, -jnp.inf)
    greedy = jnp.argmax(q)
    return jnp.where(explore, rand_arm, greedy).astype(jnp.int32)


def _drl_update(params, state, arm, obs: Obs):
    phi2 = _features(arm, obs)
    t = state["t"] + 1.0

    def frozen(_):
        return {**state, "phi": phi2, "t": t}

    def trained(_):
        target = obs.reward + params["gamma"] * jnp.max(
            _qnet(state["target"], phi2)
        )

        def td_loss(net):
            q = _qnet(net, state["phi"])[arm]
            return jnp.square(q - jax.lax.stop_gradient(target))

        grads = jax.grad(td_loss)(state["net"])
        net = jax.tree.map(lambda p, g: p - params["lr"] * g, state["net"], grads)
        sync = jnp.mod(t, params["sync_every"]) < 0.5
        tgt = jax.tree.map(
            lambda tp, np_: jnp.where(sync, np_, tp), state["target"], net
        )
        return {"net": net, "target": tgt, "phi": phi2, "t": t}

    return jax.lax.cond(params["trainable"] > 0.5, trained, frozen, None)


DRLCAP_FNS = PolicyFns(_drl_init, _drl_select, _drl_update)


def drlcap(
    k: int = K_ARMS,
    lr: float = 1e-2,
    gamma: float = 0.9,
    sync_every: int = 200,
    trainable: bool = True,
    name: str = "DRLCap",
) -> Policy:
    if k > K_ARMS:
        raise ValueError(f"DRLCap network is sized for at most {K_ARMS} arms")
    params = {
        "k": jnp.int32(k),
        "lr": jnp.float32(lr),
        "gamma": jnp.float32(gamma),
        "sync_every": jnp.float32(sync_every),
        "trainable": jnp.float32(1.0 if trainable else 0.0),
    }
    return Policy(name, DRLCAP_FNS, params)


def freeze(policy: Policy, name=None) -> Policy:
    """Deployment-mode wrapper: state keeps tracking features but stops
    learning (used by the DRLCap offline->online protocol). With the
    trainable flag as data, this is a pure params edit — no retrace."""
    if not (isinstance(policy.params, dict) and "trainable" in policy.params):
        raise ValueError("freeze() supports policies with a 'trainable' flag")
    frozen = dict(policy.params)
    frozen["trainable"] = jnp.float32(0.0)
    return Policy(name or policy.name + "-frozen", policy.fns, frozen)
