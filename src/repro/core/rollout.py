"""Jitted episode rollouts: the paper's experimental loop.

An episode = lax.scan over decision intervals with a masked variable
horizon (the job completes when cumulative progress reaches 1, §3.1).
``run_repeats`` vmaps over seeds (paper: 10 repeats). The DRLCap
offline/online protocols (§4.1) are built from two-phase rollouts.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import Policy
from repro.core.simulator import (
    EnvParams,
    EnvState,
    Obs,
    env_init,
    env_step,
    expected_rewards,
    max_steps_hint,
)

PyTree = Any


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


@functools.partial(jax.jit, static_argnames=("policy", "max_steps", "reward_fn"))
def _episode(
    policy: Policy,
    params: EnvParams,
    key: jax.Array,
    max_steps: int,
    reward_fn: Optional[Callable[[Obs], jax.Array]] = None,
    init_pstate: Optional[PyTree] = None,
    init_estate: Optional[EnvState] = None,
):
    k_init, k_run = jax.random.split(key)
    pstate0 = policy.init(k_init) if init_pstate is None else init_pstate
    estate0 = env_init(params) if init_estate is None else init_estate
    mu = expected_rewards(params)
    mu_star = jnp.max(mu)

    def step(carry, k):
        pstate, estate = carry
        k1, k2 = jax.random.split(k)
        arm = policy.select(pstate, k1)
        new_estate, obs = env_step(params, estate, arm, k2)
        if reward_fn is not None:
            obs = obs._replace(reward=reward_fn(obs))
        new_pstate = policy.update(pstate, arm, obs)
        # freeze everything once the job is done
        pstate = _tree_where(obs.active, new_pstate, pstate)
        estate = _tree_where(obs.active, new_estate, estate)
        regret_inc = (mu_star - mu[arm]) * obs.active
        return (pstate, estate), (arm, regret_inc)

    keys = jax.random.split(k_run, max_steps)
    (pstate, estate), (arms, regret_inc) = jax.lax.scan(
        step, (pstate0, estate0), keys
    )
    return {
        "energy_kj": estate.energy_kj,
        "time_s": estate.time_s,
        "switches": estate.switches,
        "steps": estate.t,
        "completed": estate.remaining <= 0.0,
        "arms": arms,
        "cum_regret": jnp.cumsum(regret_inc),
        "pstate": pstate,
        "estate": estate,
    }


def run_episode(policy, params, key, max_steps=None, reward_fn=None,
                init_pstate=None, init_estate=None):
    ms = int(max_steps or max_steps_hint(params))
    return _episode(policy, params, key, ms, reward_fn, init_pstate, init_estate)


def run_repeats(
    policy: Policy,
    params: EnvParams,
    key: jax.Array,
    n_repeats: int = 10,
    max_steps: Optional[int] = None,
    reward_fn=None,
) -> Dict[str, np.ndarray]:
    ms = int(max_steps or max_steps_hint(params))
    keys = jax.random.split(key, n_repeats)
    out = jax.vmap(
        lambda k: _episode(policy, params, k, ms, reward_fn)
    )(keys)
    return {
        "energy_kj": np.asarray(out["energy_kj"]),
        "time_s": np.asarray(out["time_s"]),
        "switches": np.asarray(out["switches"]),
        "steps": np.asarray(out["steps"]),
        "completed": np.asarray(out["completed"]),
        "cum_regret": np.asarray(out["cum_regret"]),
    }


# ---------------------------------------------------------------------------
# DRLCap protocols (§4.1)
# ---------------------------------------------------------------------------


def run_drlcap_protocol(
    make_policy: Callable[..., Policy],
    params: EnvParams,
    key: jax.Array,
    pretrain_frac: float = 0.2,
    deploy_scale: float = 1.25,
) -> Dict[str, jax.Array]:
    """Paper protocol: first 20% of the job trains online; the learned
    policy is frozen for the remaining 80%, whose energy is scaled by
    1.25x for fair comparison with fully-online methods."""
    k1, k2 = jax.random.split(key)
    trainable = make_policy(trainable=True)
    ms = max_steps_hint(params)
    # phase 1 = the first pretrain_frac of the job (env budget masked)
    est0 = env_init(params)._replace(remaining=jnp.float32(pretrain_frac))
    phase1 = _episode(trainable, params, k1, int(ms), None, None, est0)
    e1 = phase1["energy_kj"]
    frozen = make_policy(trainable=False)
    est1 = env_init(params)._replace(remaining=jnp.float32(1.0 - pretrain_frac))
    phase2 = _episode(frozen, params, k2, int(ms), None, phase1["pstate"], est1)
    return {
        "energy_kj": e1 + deploy_scale * phase2["energy_kj"],
        "time_s": phase1["time_s"] + phase2["time_s"],
        "switches": phase1["switches"] + phase2["switches"],
    }


def run_drlcap_cross(
    make_policy: Callable[..., Policy],
    target: EnvParams,
    sources: list,
    key: jax.Array,
) -> Dict[str, jax.Array]:
    """DRLCap-Cross: pretrain on other apps, deploy frozen on target."""
    trainable = make_policy(trainable=True)
    keys = jax.random.split(key, len(sources) + 1)
    pstate = None
    for src, k in zip(sources, keys[:-1]):
        out = _episode(trainable, src, k, max_steps_hint(src), None, pstate, None)
        pstate = out["pstate"]
    frozen = make_policy(trainable=False)
    out = _episode(frozen, target, keys[-1], max_steps_hint(target), None, pstate, None)
    return {k: out[k] for k in ("energy_kj", "time_s", "switches")}
