"""One rollout engine for every experimental loop in the repo.

The seed grew three hand-rolled ``lax.scan`` loops (the single-episode
``_episode``, and the independent / coordinated fleet loops in
``fleet.py``). They are now one engine with a declared batch topology:

    RolloutSpec(n_nodes=1)                    the paper's loop (§3.1)
    RolloutSpec(n_nodes=N)                    N vmapped controllers,
                                              synchronous gang timing
    RolloutSpec(n_nodes=N, coordinated=True)  one shared controller,
                                              fleet-mean reward

The engine takes the policy split into a static ``PolicyFns`` triple and
a traced hyperparameter pytree, so ONE jitted trace serves every
EnergyUCB variant, and ``run_sweep`` vmaps configs x seeds through that
single trace (``engine_trace_count`` exists so tests can assert it).
``fleet.run_fleet_episode``, the DRLCap protocols (§4.1) and the
benchmarks all route through here.

An episode = lax.scan over decision intervals with a masked variable
horizon (the job completes when cumulative progress reaches 1, §3.1).
``run_repeats`` vmaps over seeds (paper: 10 repeats).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import Policy, PolicyFns
from repro.core.simulator import (
    EnvParams,
    EnvState,
    Obs,
    env_init,
    env_step,
    expected_rewards,
    max_steps_hint,
)

PyTree = Any


class RolloutSpec(NamedTuple):
    """Declared batch axes of one rollout (static under jit)."""

    n_nodes: int = 1
    coordinated: bool = False


SINGLE = RolloutSpec()

# Bumped once per (re)trace of the engine body; a hyperparameter sweep
# must not move it by more than one (tests/test_rollout_engine.py).
_TRACE_COUNT = 0


def engine_trace_count() -> int:
    return _TRACE_COUNT


def reset_engine_trace_count() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT = 0


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _row_where(mask, new, old):
    """Per-node freeze: mask (N,) selects rows of every leaf."""
    return jax.tree.map(
        lambda a, b: jnp.where(mask.reshape((-1,) + (1,) * (a.ndim - 1)), a, b),
        new,
        old,
    )


def _single_rollout(fns, pparams, params, key, max_steps, reward_fn,
                    init_pstate, init_estate):
    k_init, k_run = jax.random.split(key)
    pstate0 = fns.init(pparams, k_init) if init_pstate is None else init_pstate
    estate0 = env_init(params) if init_estate is None else init_estate
    mu = expected_rewards(params)
    mu_star = jnp.max(mu)

    def step(carry, k):
        pstate, estate = carry
        k1, k2 = jax.random.split(k)
        arm = fns.select(pparams, pstate, k1)
        new_estate, obs = env_step(params, estate, arm, k2)
        if reward_fn is not None:
            obs = obs._replace(reward=reward_fn(obs))
        new_pstate = fns.update(pparams, pstate, arm, obs)
        # freeze everything once the job is done
        pstate = _tree_where(obs.active, new_pstate, pstate)
        estate = _tree_where(obs.active, new_estate, estate)
        regret_inc = (mu_star - mu[arm]) * obs.active
        return (pstate, estate), (arm, regret_inc)

    keys = jax.random.split(k_run, max_steps)
    (pstate, estate), (arms, regret_inc) = jax.lax.scan(
        step, (pstate0, estate0), keys
    )
    return {
        "energy_kj": estate.energy_kj,
        "time_s": estate.time_s,
        "switches": estate.switches,
        "steps": estate.t,
        "completed": estate.remaining <= 0.0,
        "arms": arms,
        "cum_regret": jnp.cumsum(regret_inc),
        "pstate": pstate,
        "estate": estate,
    }


def _indep_fleet_rollout(fns, pparams, params, key, max_steps, n_nodes):
    k0, kr = jax.random.split(key)
    pstates = jax.vmap(fns.init, in_axes=(None, 0))(
        pparams, jax.random.split(k0, n_nodes)
    )
    estates = jax.vmap(lambda _: env_init(params))(jnp.arange(n_nodes))

    def step(carry, k):
        pstates, estates, gang_time = carry
        ks = jax.random.split(k, 2 * n_nodes).reshape(2, n_nodes)
        arms = jax.vmap(fns.select, in_axes=(None, 0, 0))(pparams, pstates, ks[0])
        estates2, obs = jax.vmap(lambda e, a, kk: env_step(params, e, a, kk))(
            estates, arms, ks[1]
        )
        pstates2 = jax.vmap(fns.update, in_axes=(None, 0, 0, 0))(
            pparams, pstates, arms, obs
        )
        active = obs.active
        pstates = _row_where(active, pstates2, pstates)
        estates = _row_where(active, estates2, estates)
        # synchronous step: gang advances at the slowest node's pace
        step_t = jnp.where(
            jnp.any(active), jnp.max(params.t_rel[arms] * params.dt_s), 0.0
        )
        return (pstates, estates, gang_time + step_t), None

    (pstates, estates, gang_time), _ = jax.lax.scan(
        step, (pstates, estates, jnp.float32(0.0)),
        jax.random.split(kr, max_steps),
    )
    return {
        "energy_kj": jnp.sum(estates.energy_kj),
        "gang_time_s": gang_time,
        "switches": jnp.sum(estates.switches),
    }


def _coord_fleet_rollout(fns, pparams, params, key, max_steps, n_nodes):
    k0, kr = jax.random.split(key)
    pstate = fns.init(pparams, k0)
    estates = jax.vmap(lambda _: env_init(params))(jnp.arange(n_nodes))

    def step(carry, k):
        pstate, estates, gang_time = carry
        k_sel, k_env = jax.random.split(k)
        arm = fns.select(pparams, pstate, k_sel)
        arms = jnp.full((n_nodes,), arm)
        estates2, obs = jax.vmap(lambda e, a, kk: env_step(params, e, a, kk))(
            estates, arms, jax.random.split(k_env, n_nodes)
        )
        active = obs.active
        # coordinated reward: fleet-mean (pmean on real hardware)
        mean_obs = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32)), obs)
        pstate2 = fns.update(pparams, pstate, arm, mean_obs)
        any_active = jnp.any(active)
        pstate = _tree_where(any_active, pstate2, pstate)
        estates = _row_where(active, estates2, estates)
        step_t = jnp.where(any_active, params.t_rel[arm] * params.dt_s, 0.0)
        return (pstate, estates, gang_time + step_t), None

    (pstate, estates, gang_time), _ = jax.lax.scan(
        step, (pstate, estates, jnp.float32(0.0)),
        jax.random.split(kr, max_steps),
    )
    return {
        "energy_kj": jnp.sum(estates.energy_kj),
        "gang_time_s": gang_time,
        "switches": jnp.sum(estates.switches),
    }


def _engine_impl(
    fns: PolicyFns,
    pparams: PyTree,
    params: EnvParams,
    key: jax.Array,
    max_steps: int,
    reward_fn: Optional[Callable[[Obs], jax.Array]],
    spec: RolloutSpec,
    init_pstate: Optional[PyTree] = None,
    init_estate: Optional[EnvState] = None,
):
    global _TRACE_COUNT
    _TRACE_COUNT += 1  # Python side effect: runs once per trace
    if spec.n_nodes == 1 and not spec.coordinated:
        return _single_rollout(
            fns, pparams, params, key, max_steps, reward_fn,
            init_pstate, init_estate,
        )
    if reward_fn is not None or init_pstate is not None or init_estate is not None:
        raise NotImplementedError("fleet rollouts take no custom reward/init state")
    if spec.coordinated:
        return _coord_fleet_rollout(fns, pparams, params, key, max_steps, spec.n_nodes)
    return _indep_fleet_rollout(fns, pparams, params, key, max_steps, spec.n_nodes)


_engine = functools.partial(
    jax.jit, static_argnames=("fns", "max_steps", "reward_fn", "spec")
)(_engine_impl)


def rollout(policy: Policy, params: EnvParams, key, max_steps=None,
            spec: RolloutSpec = SINGLE, reward_fn=None,
            init_pstate=None, init_estate=None):
    """The engine's front door: one call, any declared topology."""
    ms = int(max_steps or max_steps_hint(params))
    return _engine(policy.fns, policy.params, params, key, ms, reward_fn, spec,
                   init_pstate, init_estate)


def run_episode(policy, params, key, max_steps=None, reward_fn=None,
                init_pstate=None, init_estate=None):
    return rollout(policy, params, key, max_steps, SINGLE, reward_fn,
                   init_pstate, init_estate)


def run_repeats(
    policy: Policy,
    params: EnvParams,
    key: jax.Array,
    n_repeats: int = 10,
    max_steps: Optional[int] = None,
    reward_fn=None,
) -> Dict[str, np.ndarray]:
    ms = int(max_steps or max_steps_hint(params))
    keys = jax.random.split(key, n_repeats)
    out = jax.vmap(
        lambda k: _engine(policy.fns, policy.params, params, k, ms, reward_fn,
                          SINGLE, None, None)
    )(keys)
    return {
        "energy_kj": np.asarray(out["energy_kj"]),
        "time_s": np.asarray(out["time_s"]),
        "switches": np.asarray(out["switches"]),
        "steps": np.asarray(out["steps"]),
        "completed": np.asarray(out["completed"]),
        "cum_regret": np.asarray(out["cum_regret"]),
    }


_SWEEP_KEYS = ("energy_kj", "time_s", "switches", "steps", "completed",
               "cum_regret")


@functools.partial(
    jax.jit, static_argnames=("fns", "max_steps", "reward_fn", "n_repeats")
)
def _sweep(fns, stacked, params, key, max_steps, reward_fn, n_repeats):
    keys = jax.random.split(key, n_repeats)
    per_cfg = lambda pp: jax.vmap(
        lambda k: _engine_impl(fns, pp, params, k, max_steps, reward_fn,
                               SINGLE, None, None)
    )(keys)
    out = jax.vmap(per_cfg)(stacked)
    # drop per-step arms and the stacked pstate/estate trees here, inside
    # jit, so XLA dead-code-eliminates their scan accumulators instead of
    # materializing (configs, repeats, max_steps) buffers the caller
    # never reads
    return {k: out[k] for k in _SWEEP_KEYS}


def run_sweep(
    policy: Policy,
    stacked_params: PyTree,
    params: EnvParams,
    key: jax.Array,
    n_repeats: int = 3,
    max_steps: Optional[int] = None,
    reward_fn=None,
) -> Dict[str, np.ndarray]:
    """Batched hyperparameter sweep: configs x seeds through ONE trace.

    ``stacked_params`` is a pytree of configs stacked on axis 0 (see
    policies.stack_policy_params / sweep_policy_params). Outputs are
    shaped (n_configs, n_repeats, ...).
    """
    ms = int(max_steps or max_steps_hint(params))
    out = _sweep(policy.fns, stacked_params, params, key, ms, reward_fn, n_repeats)
    return {k: np.asarray(v) for k, v in out.items()}


def run_fleet_episode(
    policy: Policy,
    params: EnvParams,
    key: jax.Array,
    n_nodes: int,
    max_steps: int,
    coordinated: bool = False,
) -> Dict[str, jax.Array]:
    """N identical nodes on the same job — see RolloutSpec modes."""
    spec = RolloutSpec(n_nodes=n_nodes, coordinated=coordinated)
    return _engine(policy.fns, policy.params, params, key, int(max_steps),
                   None, spec, None, None)


# ---------------------------------------------------------------------------
# DRLCap protocols (§4.1)
# ---------------------------------------------------------------------------


def run_drlcap_protocol(
    make_policy: Callable[..., Policy],
    params: EnvParams,
    key: jax.Array,
    pretrain_frac: float = 0.2,
    deploy_scale: float = 1.25,
) -> Dict[str, jax.Array]:
    """Paper protocol: first 20% of the job trains online; the learned
    policy is frozen for the remaining 80%, whose energy is scaled by
    1.25x for fair comparison with fully-online methods."""
    k1, k2 = jax.random.split(key)
    trainable = make_policy(trainable=True)
    ms = int(max_steps_hint(params))
    # phase 1 = the first pretrain_frac of the job (env budget masked)
    est0 = env_init(params)._replace(remaining=jnp.float32(pretrain_frac))
    phase1 = run_episode(trainable, params, k1, ms, init_estate=est0)
    e1 = phase1["energy_kj"]
    frozen = make_policy(trainable=False)
    est1 = env_init(params)._replace(remaining=jnp.float32(1.0 - pretrain_frac))
    phase2 = run_episode(frozen, params, k2, ms,
                         init_pstate=phase1["pstate"], init_estate=est1)
    return {
        "energy_kj": e1 + deploy_scale * phase2["energy_kj"],
        "time_s": phase1["time_s"] + phase2["time_s"],
        "switches": phase1["switches"] + phase2["switches"],
    }


def run_drlcap_cross(
    make_policy: Callable[..., Policy],
    target: EnvParams,
    sources: list,
    key: jax.Array,
) -> Dict[str, jax.Array]:
    """DRLCap-Cross: pretrain on other apps, deploy frozen on target."""
    trainable = make_policy(trainable=True)
    keys = jax.random.split(key, len(sources) + 1)
    pstate = None
    for src, k in zip(sources, keys[:-1]):
        out = run_episode(trainable, src, k, init_pstate=pstate)
        pstate = out["pstate"]
    frozen = make_policy(trainable=False)
    out = run_episode(frozen, target, keys[-1], init_pstate=pstate)
    return {k: out[k] for k in ("energy_kj", "time_s", "switches")}
