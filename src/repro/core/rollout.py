"""One rollout engine for every experimental loop in the repo.

The seed grew three hand-rolled ``lax.scan`` loops (the single-episode
``_episode``, and the independent / coordinated fleet loops in
``fleet.py``). They are now one engine with a declared batch topology:

    RolloutSpec(n_nodes=1)                    the paper's loop (§3.1)
    RolloutSpec(n_nodes=N)                    N vmapped controllers,
                                              synchronous gang timing
    RolloutSpec(n_nodes=N, coordinated=True)  one shared controller,
                                              fleet-mean reward

The engine takes the policy split into a static ``PolicyFns`` triple and
a traced hyperparameter pytree, so ONE jitted trace serves every
EnergyUCB variant, and ``run_sweep`` vmaps configs x seeds through that
single trace (``engine_trace_count`` exists so tests can assert it).
``fleet.run_fleet_episode``, the DRLCap protocols (§4.1) and the
benchmarks all route through here.

An episode = lax.scan over decision intervals with a masked variable
horizon (the job completes when cumulative progress reaches 1, §3.1).
``run_repeats`` vmaps over seeds (paper: 10 repeats).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import UCB_FNS, Policy, PolicyFns, ucb_family_k_unc
from repro.core.simulator import (
    EnvParams,
    EnvState,
    Obs,
    env_init,
    env_step,
    expected_rewards,
    max_steps_hint,
)

PyTree = Any


class RolloutSpec(NamedTuple):
    """Declared batch axes of one rollout (static under jit)."""

    n_nodes: int = 1
    coordinated: bool = False


SINGLE = RolloutSpec()

# Bumped once per (re)trace of the engine body; a hyperparameter sweep
# must not move it by more than one (tests/test_rollout_engine.py).
_TRACE_COUNT = 0


def engine_trace_count() -> int:
    return _TRACE_COUNT


def reset_engine_trace_count() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT = 0


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _row_where(mask, new, old):
    """Per-node freeze: mask (N,) selects rows of every leaf."""
    return jax.tree.map(
        lambda a, b: jnp.where(mask.reshape((-1,) + (1,) * (a.ndim - 1)), a, b),
        new,
        old,
    )


def _single_rollout(fns, pparams, params, key, max_steps, reward_fn,
                    init_pstate, init_estate):
    k_init, k_run = jax.random.split(key)
    pstate0 = fns.init(pparams, k_init) if init_pstate is None else init_pstate
    estate0 = env_init(params) if init_estate is None else init_estate
    mu = expected_rewards(params)
    mu_star = jnp.max(mu)

    def step(carry, k):
        pstate, estate = carry
        k1, k2 = jax.random.split(k)
        arm = fns.select(pparams, pstate, k1)
        new_estate, obs = env_step(params, estate, arm, k2)
        if reward_fn is not None:
            obs = obs._replace(reward=reward_fn(obs))
        new_pstate = fns.update(pparams, pstate, arm, obs)
        # freeze everything once the job is done
        pstate = _tree_where(obs.active, new_pstate, pstate)
        estate = _tree_where(obs.active, new_estate, estate)
        regret_inc = (mu_star - mu[arm]) * obs.active
        return (pstate, estate), (arm, regret_inc)

    keys = jax.random.split(k_run, max_steps)
    (pstate, estate), (arms, regret_inc) = jax.lax.scan(
        step, (pstate0, estate0), keys
    )
    return {
        "energy_kj": estate.energy_kj,
        "time_s": estate.time_s,
        "switches": estate.switches,
        "steps": estate.t,
        "completed": estate.remaining <= 0.0,
        "arms": arms,
        "cum_regret": jnp.cumsum(regret_inc),
        "pstate": pstate,
        "estate": estate,
    }


def _indep_fleet_rollout(fns, pparams, params, key, max_steps, n_nodes):
    k0, kr = jax.random.split(key)
    pstates = jax.vmap(fns.init, in_axes=(None, 0))(
        pparams, jax.random.split(k0, n_nodes)
    )
    estates = jax.vmap(lambda _: env_init(params))(jnp.arange(n_nodes))

    def step(carry, k):
        pstates, estates, gang_time = carry
        ks = jax.random.split(k, 2 * n_nodes).reshape(2, n_nodes)
        arms = jax.vmap(fns.select, in_axes=(None, 0, 0))(pparams, pstates, ks[0])
        estates2, obs = jax.vmap(lambda e, a, kk: env_step(params, e, a, kk))(
            estates, arms, ks[1]
        )
        pstates2 = jax.vmap(fns.update, in_axes=(None, 0, 0, 0))(
            pparams, pstates, arms, obs
        )
        active = obs.active
        pstates = _row_where(active, pstates2, pstates)
        estates = _row_where(active, estates2, estates)
        # synchronous step: gang advances at the slowest node's pace
        step_t = jnp.where(
            jnp.any(active), jnp.max(params.t_rel[arms] * params.dt_s), 0.0
        )
        return (pstates, estates, gang_time + step_t), None

    (pstates, estates, gang_time), _ = jax.lax.scan(
        step, (pstates, estates, jnp.float32(0.0)),
        jax.random.split(kr, max_steps),
    )
    return {
        "energy_kj": jnp.sum(estates.energy_kj),
        "gang_time_s": gang_time,
        "switches": jnp.sum(estates.switches),
    }


def _coord_fleet_rollout(fns, pparams, params, key, max_steps, n_nodes):
    k0, kr = jax.random.split(key)
    pstate = fns.init(pparams, k0)
    estates = jax.vmap(lambda _: env_init(params))(jnp.arange(n_nodes))

    def step(carry, k):
        pstate, estates, gang_time = carry
        k_sel, k_env = jax.random.split(k)
        arm = fns.select(pparams, pstate, k_sel)
        arms = jnp.full((n_nodes,), arm)
        estates2, obs = jax.vmap(lambda e, a, kk: env_step(params, e, a, kk))(
            estates, arms, jax.random.split(k_env, n_nodes)
        )
        active = obs.active
        # coordinated reward: fleet-mean (pmean on real hardware)
        mean_obs = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32)), obs)
        pstate2 = fns.update(pparams, pstate, arm, mean_obs)
        any_active = jnp.any(active)
        pstate = _tree_where(any_active, pstate2, pstate)
        estates = _row_where(active, estates2, estates)
        step_t = jnp.where(any_active, params.t_rel[arm] * params.dt_s, 0.0)
        return (pstate, estates, gang_time + step_t), None

    (pstate, estates, gang_time), _ = jax.lax.scan(
        step, (pstate, estates, jnp.float32(0.0)),
        jax.random.split(kr, max_steps),
    )
    return {
        "energy_kj": jnp.sum(estates.energy_kj),
        "gang_time_s": gang_time,
        "switches": jnp.sum(estates.switches),
    }


def _engine_impl(
    fns: PolicyFns,
    pparams: PyTree,
    params: EnvParams,
    key: jax.Array,
    max_steps: int,
    reward_fn: Optional[Callable[[Obs], jax.Array]],
    spec: RolloutSpec,
    init_pstate: Optional[PyTree] = None,
    init_estate: Optional[EnvState] = None,
):
    global _TRACE_COUNT
    _TRACE_COUNT += 1  # Python side effect: runs once per trace
    if spec.n_nodes == 1 and not spec.coordinated:
        return _single_rollout(
            fns, pparams, params, key, max_steps, reward_fn,
            init_pstate, init_estate,
        )
    if reward_fn is not None or init_pstate is not None or init_estate is not None:
        raise NotImplementedError("fleet rollouts take no custom reward/init state")
    if spec.coordinated:
        return _coord_fleet_rollout(fns, pparams, params, key, max_steps, spec.n_nodes)
    return _indep_fleet_rollout(fns, pparams, params, key, max_steps, spec.n_nodes)


_engine = functools.partial(
    jax.jit, static_argnames=("fns", "max_steps", "reward_fn", "spec")
)(_engine_impl)


def rollout(policy: Policy, params: EnvParams, key, max_steps=None,
            spec: RolloutSpec = SINGLE, reward_fn=None,
            init_pstate=None, init_estate=None):
    """The engine's front door: one call, any declared topology."""
    ms = int(max_steps or max_steps_hint(params))
    return _engine(policy.fns, policy.params, params, key, ms, reward_fn, spec,
                   init_pstate, init_estate)


def run_episode(policy, params, key, max_steps=None, reward_fn=None,
                init_pstate=None, init_estate=None):
    return rollout(policy, params, key, max_steps, SINGLE, reward_fn,
                   init_pstate, init_estate)


def run_repeats(
    policy: Policy,
    params: EnvParams,
    key: jax.Array,
    n_repeats: int = 10,
    max_steps: Optional[int] = None,
    reward_fn=None,
) -> Dict[str, np.ndarray]:
    ms = int(max_steps or max_steps_hint(params))
    keys = jax.random.split(key, n_repeats)
    out = jax.vmap(
        lambda k: _engine(policy.fns, policy.params, params, k, ms, reward_fn,
                          SINGLE, None, None)
    )(keys)
    return {
        "energy_kj": np.asarray(out["energy_kj"]),
        "time_s": np.asarray(out["time_s"]),
        "switches": np.asarray(out["switches"]),
        "steps": np.asarray(out["steps"]),
        "completed": np.asarray(out["completed"]),
        "cum_regret": np.asarray(out["cum_regret"]),
    }


_SWEEP_KEYS = ("energy_kj", "time_s", "switches", "steps", "completed",
               "cum_regret")


# ---------------------------------------------------------------------------
# episode-scan lane: the whole sweep/fleet episode as ONE fused scan
# (kernels.episode_scan) instead of a lax.scan of per-step policy calls.
# The env noise is the one thing the scan cannot draw itself without
# replicating the engine's key tree, so these helpers precompute the raw
# standard normals on the engine's EXACT key schedule.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_steps",))
def _engine_noise(keys, max_steps):
    """(R, max_steps, 4) raw normals: per repeat, the draws
    ``_single_rollout`` would consume (split -> per-step split ->
    env key -> split(4))."""

    def per_repeat(key):
        _, k_run = jax.random.split(key)
        ks = jax.random.split(k_run, max_steps)

        def per_step(k):
            _, k2 = jax.random.split(k)
            kk = jax.random.split(k2, 4)
            return jnp.stack([jax.random.normal(kk[i]) for i in range(4)])

        return jax.vmap(per_step)(ks)

    return jax.vmap(per_repeat)(keys)


@functools.partial(jax.jit, static_argnames=("max_steps", "n_nodes"))
def _fleet_noise(k_run, max_steps, n_nodes):
    """(max_steps, N, 4) raw normals on ``_indep_fleet_rollout``'s key
    schedule (per step: split(k, 2N) -> row 1 are the env keys)."""
    ks = jax.random.split(k_run, max_steps)

    def per_step(k):
        kk = jax.random.split(k, 2 * n_nodes).reshape(2, n_nodes)[1]

        def draw(q):
            qs = jax.random.split(q, 4)
            return jnp.stack([jnp.asarray(jax.random.normal(qs[i]))
                              for i in range(4)])

        return jax.vmap(draw)(kk)

    return jax.vmap(per_step)(ks)


@functools.partial(jax.jit, static_argnames=("fns", "n"))
def _flat_ucb_start(fns, flat, n):
    """Vmapped init + first select over per-node UCB-family params (keys
    are dummies: the UCB fns are deterministic; ``fns`` is static so the
    scalar and each factored function set get their own trace)."""
    ks = jax.random.split(jax.random.key(0), n)
    states = jax.vmap(fns.init)(flat, ks)
    return states, jax.vmap(fns.select)(flat, states, ks)


@functools.partial(jax.jit, static_argnames=("n_configs", "n_repeats"))
def _sweep_episode_metrics(env_f, arms, params, n_configs, n_repeats):
    """run_sweep's output dict reconstructed from the scan's final env
    rows + (T, N) arm trace. ``active[t] = t < steps`` is exact because
    a node's active intervals are a prefix (remaining is monotone and
    sticks at 0)."""
    ms = arms.shape[0]
    mu = expected_rewards(params)
    mu_star = jnp.max(mu)
    active = jnp.arange(ms)[:, None] < env_f.t[None, :]
    regret_inc = (mu_star - mu[arms]) * active
    shape = lambda x: x.reshape((n_configs, n_repeats))
    return {
        "energy_kj": shape(env_f.energy_kj),
        "time_s": shape(env_f.time_s),
        "switches": shape(env_f.switches),
        "steps": shape(env_f.t),
        "completed": shape(env_f.remaining <= 0.0),
        "cum_regret": jnp.cumsum(regret_inc, axis=0).T.reshape(
            (n_configs, n_repeats, ms)
        ),
    }


def _run_sweep_episode(policy, stacked, params, key, n_repeats, max_steps):
    from repro.kernels import ops
    from repro.kernels.episode_scan import env_rows_init, make_scan_env

    ku = ucb_family_k_unc(policy.fns)
    if ku is None:
        raise ValueError(
            f"policy {policy.name!r} is not kernel-exact; episode_scan "
            "sweeps cover the fused-UCB family only"
        )
    c = int(jnp.shape(stacked.alpha)[0])
    r = int(n_repeats)
    n = c * r
    # configs x repeats flattened config-major onto the fleet axis:
    # node c*R + r runs config c with repeat r's noise
    flat = jax.tree.map(lambda x: jnp.repeat(x, r, axis=0), stacked)
    ms = int(max_steps)
    z4 = _engine_noise(jax.random.split(key, r), ms)  # (R, ms, 4)
    zz = jnp.tile(jnp.transpose(z4, (1, 0, 2)), (1, c, 1))  # (ms, N, 4)
    states, arm0 = _flat_ucb_start(policy.fns, flat, n)
    (_, env_f, arms) = ops.episode_scan_sim(
        states["mu"], states["n"], states["phat"], states["pn"],
        states["prev"], states["t"], arm0, env_rows_init(n),
        tuple(zz[..., i] for i in range(4)), make_scan_env([params]),
        flat.alpha, flat.lam, flat.qos_delta, flat.default_arm,
        flat.gamma, flat.optimistic, flat.prior_mu, flat.lam_unc,
        k_unc=ku, counter_obs=False,
    )
    out = _sweep_episode_metrics(env_f, arms, params, c, r)
    return {k: np.asarray(v) for k, v in out.items()}


@functools.partial(
    jax.jit, static_argnames=("fns", "max_steps", "reward_fn", "n_repeats")
)
def _sweep(fns, stacked, params, key, max_steps, reward_fn, n_repeats):
    keys = jax.random.split(key, n_repeats)
    per_cfg = lambda pp: jax.vmap(
        lambda k: _engine_impl(fns, pp, params, k, max_steps, reward_fn,
                               SINGLE, None, None)
    )(keys)
    out = jax.vmap(per_cfg)(stacked)
    # drop per-step arms and the stacked pstate/estate trees here, inside
    # jit, so XLA dead-code-eliminates their scan accumulators instead of
    # materializing (configs, repeats, max_steps) buffers the caller
    # never reads
    return {k: out[k] for k in _SWEEP_KEYS}


def run_sweep(
    policy: Policy,
    stacked_params: PyTree,
    params: EnvParams,
    key: jax.Array,
    n_repeats: int = 3,
    max_steps: Optional[int] = None,
    reward_fn=None,
    episode_scan: bool = False,
) -> Dict[str, np.ndarray]:
    """Batched hyperparameter sweep: configs x seeds through ONE trace.

    ``stacked_params`` is a pytree of configs stacked on axis 0 (see
    policies.stack_policy_params / sweep_policy_params). Outputs are
    shaped (n_configs, n_repeats, ...).

    ``episode_scan=True`` flattens configs x repeats onto one fleet axis
    and runs the WHOLE sweep as a single fused episode scan
    (kernels.episode_scan, sim-fused mode) on the engine's exact noise
    schedule — the same arm trajectories and integer outputs, float
    accumulators equal to round-off — instead of a per-interval
    scan-of-policy-calls per (config, repeat). UCB-family policies and
    the plain env reward only (``reward_fn`` keeps the legacy lane).
    """
    ms = int(max_steps or max_steps_hint(params))
    if episode_scan:
        if reward_fn is not None:
            raise NotImplementedError(
                "episode_scan sweeps use the env reward; pass "
                "reward_fn only on the legacy lane"
            )
        return _run_sweep_episode(policy, stacked_params, params, key,
                                  n_repeats, ms)
    out = _sweep(policy.fns, stacked_params, params, key, ms, reward_fn, n_repeats)
    return {k: np.asarray(v) for k, v in out.items()}


@functools.partial(jax.jit, static_argnames=())
def _fleet_episode_metrics(env_f, arms, params):
    """Independent-fleet outputs from the scan's final env rows + arm
    trace. Gang time is re-folded sequentially (lax.scan) so the float
    accumulation order matches the streaming loop's."""
    ms = arms.shape[0]
    active = jnp.arange(ms)[:, None] < env_f.t[None, :]
    step_t = jnp.where(
        jnp.any(active, axis=1),
        jnp.max(params.t_rel[arms] * params.dt_s, axis=1),
        0.0,
    )
    gang_time, _ = jax.lax.scan(lambda s, x: (s + x, None),
                                jnp.float32(0.0), step_t)
    return {
        "energy_kj": jnp.sum(env_f.energy_kj),
        "gang_time_s": gang_time,
        "switches": jnp.sum(env_f.switches),
    }


def _run_fleet_episode_scan(policy, params, key, n_nodes, max_steps):
    from repro.kernels import ops
    from repro.kernels.episode_scan import env_rows_init, make_scan_env

    ku = ucb_family_k_unc(policy.fns)
    if ku is None:
        raise ValueError(
            f"policy {policy.name!r} is not kernel-exact; episode_scan "
            "fleets cover the fused-UCB family only"
        )
    n, ms = int(n_nodes), int(max_steps)
    k0, kr = jax.random.split(key)
    p = policy.params
    flat = jax.tree.map(
        lambda x: jnp.broadcast_to(
            jnp.asarray(x), (n,) + jnp.shape(jnp.asarray(x))
        ),
        p,
    )
    states, arm0 = _flat_ucb_start(policy.fns, flat, n)
    zz = _fleet_noise(kr, ms, n)  # (ms, N, 4)
    (_, env_f, arms) = ops.episode_scan_sim(
        states["mu"], states["n"], states["phat"], states["pn"],
        states["prev"], states["t"], arm0, env_rows_init(n),
        tuple(zz[..., i] for i in range(4)), make_scan_env([params]),
        p.alpha, p.lam, p.qos_delta, p.default_arm, p.gamma, p.optimistic,
        p.prior_mu, p.lam_unc, k_unc=ku, counter_obs=False,
    )
    return _fleet_episode_metrics(env_f, arms, params)


def run_fleet_episode(
    policy: Policy,
    params: EnvParams,
    key: jax.Array,
    n_nodes: int,
    max_steps: int,
    coordinated: bool = False,
    episode_scan: bool = False,
) -> Dict[str, jax.Array]:
    """N identical nodes on the same job — see RolloutSpec modes.

    ``episode_scan=True`` runs the INDEPENDENT fleet as one fused
    episode scan (kernels.episode_scan) on the engine's exact noise
    schedule; the coordinated gang shares one controller across nodes
    (a cross-node reduction per interval) and keeps the legacy engine.
    """
    if episode_scan:
        if coordinated:
            raise NotImplementedError(
                "the coordinated gang reduces across nodes every "
                "interval; only independent fleets episode-scan"
            )
        return _run_fleet_episode_scan(policy, params, key, n_nodes,
                                       int(max_steps))
    spec = RolloutSpec(n_nodes=n_nodes, coordinated=coordinated)
    return _engine(policy.fns, policy.params, params, key, int(max_steps),
                   None, spec, None, None)


# ---------------------------------------------------------------------------
# DRLCap protocols (§4.1)
# ---------------------------------------------------------------------------


def run_drlcap_protocol(
    make_policy: Callable[..., Policy],
    params: EnvParams,
    key: jax.Array,
    pretrain_frac: float = 0.2,
    deploy_scale: float = 1.25,
) -> Dict[str, jax.Array]:
    """Paper protocol: first 20% of the job trains online; the learned
    policy is frozen for the remaining 80%, whose energy is scaled by
    1.25x for fair comparison with fully-online methods."""
    k1, k2 = jax.random.split(key)
    trainable = make_policy(trainable=True)
    ms = int(max_steps_hint(params))
    # phase 1 = the first pretrain_frac of the job (env budget masked)
    est0 = env_init(params)._replace(remaining=jnp.float32(pretrain_frac))
    phase1 = run_episode(trainable, params, k1, ms, init_estate=est0)
    e1 = phase1["energy_kj"]
    frozen = make_policy(trainable=False)
    est1 = env_init(params)._replace(remaining=jnp.float32(1.0 - pretrain_frac))
    phase2 = run_episode(frozen, params, k2, ms,
                         init_pstate=phase1["pstate"], init_estate=est1)
    return {
        "energy_kj": e1 + deploy_scale * phase2["energy_kj"],
        "time_s": phase1["time_s"] + phase2["time_s"],
        "switches": phase1["switches"] + phase2["switches"],
    }


def run_drlcap_cross(
    make_policy: Callable[..., Policy],
    target: EnvParams,
    sources: list,
    key: jax.Array,
) -> Dict[str, jax.Array]:
    """DRLCap-Cross: pretrain on other apps, deploy frozen on target."""
    trainable = make_policy(trainable=True)
    keys = jax.random.split(key, len(sources) + 1)
    pstate = None
    for src, k in zip(sources, keys[:-1]):
        out = run_episode(trainable, src, k, init_pstate=pstate)
        pstate = out["pstate"]
    frozen = make_policy(trainable=False)
    out = run_episode(frozen, target, keys[-1], init_pstate=pstate)
    return {k: out[k] for k in ("energy_kj", "time_s", "switches")}
