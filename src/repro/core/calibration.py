"""Aurora calibration data (paper Table 1 + Fig. 1b) and the per-app
DVFS model fit.

Table 1 gives measured per-node GPU energy E(f) for 9 static frequencies
x 9 applications. We fit the classic DVFS decomposition per app:

    T(f) = T_ref * (c * f_max/f + (1 - c))          execution time
    P(f) = P_s + P_d * (f/f_max)^gamma               node GPU power

with c = compute-bound fraction. The fit is a grid over (c, gamma) with
a nonneg least-squares inner solve for (P_s*T_ref, P_d*T_ref); T_ref is
anchored by Fig. 1b's pot3d wall time (56.42 s @ 1.6 GHz) and by
E(f_max)/2.277 kW for the other apps (same node power class).

The *simulator* then uses the fitted T(f) for time/progress/utilization
but pins interval energy to the MEASURED Table-1 value
(P_used(f) = E_table(f) / T(f)), so static-frequency energies reproduce
the paper row-for-row by construction and the bandit faces the real
reward landscape.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

FREQS_GHZ = np.round(np.arange(0.8, 1.61, 0.1), 1)  # arm order: ascending
F_MAX = 1.6
DEFAULT_ARM = 8  # 1.6 GHz

# Table 1 static rows, ordered 1.6 -> 0.8 in the paper; stored ascending.
_TABLE1_DESC = {
    "lbm": [93.94, 93.71, 97.42, 99.88, 104.42, 109.59, 116.04, 124.28, 131.61],
    "tealeaf": [109.79, 107.09, 105.52, 105.37, 101.65, 99.81, 98.61, 99.10, 100.59],
    "clvleaf": [100.65, 98.72, 94.72, 91.61, 90.99, 90.35, 88.41, 89.00, 91.23],
    "miniswp": [187.13, 177.10, 171.60, 167.25, 164.45, 161.72, 160.17, 160.15, 158.74],
    "pot3d": [131.13, 129.11, 127.24, 125.75, 126.66, 123.38, 125.19, 125.45, 128.79],
    "sph_exa": [1353.41, 1259.65, 1216.60, 1191.01, 1163.51, 1146.37, 1116.52, 1107.28, 1090.24],
    "weather": [134.61, 128.43, 125.52, 122.80, 121.75, 120.47, 122.52, 123.38, 122.97],
    "llama": [1277.71, 1257.58, 1211.42, 1294.05, 1177.68, 1202.81, 1114.29, 1360.93, 1210.13],
    "diffusion": [772.21, 771.50, 770.91, 766.59, 771.07, 751.82, 766.73, 805.50, 747.20],
}
TABLE1_KJ: Dict[str, np.ndarray] = {
    k: np.asarray(v[::-1], np.float64) for k, v in _TABLE1_DESC.items()
}

# Paper-reported EnergyUCB results (used as test targets, not by the code)
PAPER_ENERGYUCB_KJ = {
    "lbm": 94.25, "tealeaf": 99.06, "clvleaf": 90.08, "miniswp": 162.72,
    "pot3d": 124.93, "sph_exa": 1095.89, "weather": 122.73,
    "llama": 1127.17, "diffusion": 750.90,
}

POT3D_T_REF_S = 56.42  # Fig. 1b @ 1.6 GHz
NODE_POWER_KW = 2.277  # Fig. 1b pot3d @ 1.6 GHz; power-class anchor
SWITCH_LATENCY_S = 150e-6  # §4.4
SWITCH_ENERGY_J = 0.3  # §4.4

# Published TIME anchors pin the compute-bound fraction c where the paper
# reports slowdowns (energy alone cannot identify the time/power split):
#   pot3d  Fig. 1b: T(0.8)/T(1.6) = 75.02/56.42 -> c = 0.33
#   clvleaf §4.6: ~14.46% slowdown at its energy-optimal ~1.0-1.1 GHz
#   miniswp §4.6: ~6.26% slowdown at its energy-optimal 0.8 GHz
C_ANCHORS = {
    "pot3d": 0.30,
    "clvleaf": 0.24,
    "miniswp": 0.063,
}
# Unanchored apps: c fitted from the energy curve, bounded to a
# physically plausible range for saturated offload workloads.
C_RANGE = (0.02, 0.65)


@dataclass(frozen=True)
class AppModel:
    name: str
    e_table_kj: Tuple[float, ...]  # measured static energies (ascending f)
    c: float  # compute-bound fraction
    gamma: float  # dynamic-power exponent
    p_static_kw: float
    p_dyn_kw: float
    t_ref_s: float  # wall time at f_max
    uc_base: float = 0.9  # core (compute-engine) active fraction
    noise_energy: float = 0.03  # relative counter noise
    noise_util: float = 0.05
    early_noise: float = 10.0  # extra early-phase noise multiplier (§3.2:
    early_tau: float = 40.0  # clock-sync/thermal transients ~0.4 s)

    def time_s(self, f):
        f = np.asarray(f, np.float64)
        return self.t_ref_s * (self.c * F_MAX / f + (1.0 - self.c))

    def power_used_kw(self, arm: int) -> float:
        return float(self.e_table_kj[arm]) / self.time_s(FREQS_GHZ[arm])


def fit_app(name: str, e_kj: np.ndarray, t_ref_s: float) -> AppModel:
    f = FREQS_GHZ
    x = f / F_MAX
    best = None
    if name in C_ANCHORS:
        c_grid = np.asarray([C_ANCHORS[name]])
    else:
        c_grid = np.linspace(C_RANGE[0], C_RANGE[1], 64)
    for c in c_grid:
        tf = c * F_MAX / f + (1 - c)  # T(f)/T_ref
        for gamma in np.linspace(1.0, 3.0, 41):
            # E(f) = a*tf + b*tf*x^gamma, a=Ps*Tref, b=Pd*Tref (nonneg)
            A = np.stack([tf, tf * x ** gamma], 1)
            coef, *_ = np.linalg.lstsq(A, e_kj, rcond=None)
            coef = np.maximum(coef, 0.0)
            resid = float(np.sum((A @ coef - e_kj) ** 2))
            if best is None or resid < best[0]:
                best = (resid, c, gamma, coef)
    _, c, gamma, (a, b) = best
    return AppModel(
        name=name,
        e_table_kj=tuple(float(v) for v in e_kj),
        c=float(c),
        gamma=float(gamma),
        p_static_kw=float(a / t_ref_s),
        p_dyn_kw=float(b / t_ref_s),
        t_ref_s=float(t_ref_s),
    )


def _build_apps() -> Dict[str, AppModel]:
    apps = {}
    for name, e in TABLE1_KJ.items():
        t_ref = POT3D_T_REF_S if name == "pot3d" else float(e[-1]) / NODE_POWER_KW
        apps[name] = fit_app(name, e, t_ref)
    return apps


_APPS: Dict[str, AppModel] = {}


def get_app(name: str) -> AppModel:
    if not _APPS:
        _APPS.update(_build_apps())
    return _APPS[name]


def app_names() -> Tuple[str, ...]:
    return tuple(TABLE1_KJ)
