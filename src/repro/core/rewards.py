"""Reward formulations (§4.5): r = -(E^a) * (R^b) with (a,b) in
{(1,1), (2,1), (1,2)}. Components are normalized by their f_max values
so exponents change the trade-off shape, not the scale."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core.simulator import EnvParams, Obs


def make_reward_fn(
    params: EnvParams, e_exp: float = 1.0, r_exp: float = 1.0
) -> Callable[[Obs], jnp.ndarray]:
    e_ref = params.e_interval_kj[-1] * 1e3
    r_ref = params.uc[-1] / params.uu[-1]

    def fn(obs: Obs):
        e = obs.energy_j / e_ref
        r = (obs.uc / obs.uu) / r_ref
        return -(e ** e_exp) * (r ** r_exp)

    return fn


REWARD_VARIANTS = {
    "E*R": (1.0, 1.0),
    "E^2*R": (2.0, 1.0),
    "E*R^2": (1.0, 2.0),
}
