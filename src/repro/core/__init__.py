# The paper's contribution: EnergyUCB and its experimental apparatus.
from repro.core.calibration import (
    DEFAULT_ARM,
    FREQS_GHZ,
    TABLE1_KJ,
    AppModel,
    app_names,
    get_app,
)
from repro.core.policies import (
    ActionSpace,
    Policy,
    PolicyFns,
    PolicyParams,
    UCB_FNS,
    energy_ts,
    energy_ucb,
    eps_greedy,
    factored_energy_ucb,
    factored_ucb_fns,
    interleave_policy_params,
    make_policy_params,
    phase_policy,
    rr_freq,
    stack_policy_params,
    static_policy,
    sweep_policy_params,
    ucb_family_k_unc,
)
from repro.core.regret import (
    energy_regret_kj,
    saved_energy_kj,
    summarize,
    summarize_sweep,
)
from repro.core.rewards import REWARD_VARIANTS, make_reward_fn
from repro.core.rl import drlcap, rl_power
from repro.core.rollout import (
    RolloutSpec,
    engine_trace_count,
    reset_engine_trace_count,
    run_drlcap_cross,
    run_drlcap_protocol,
    run_episode,
    run_fleet_episode,
    run_repeats,
    run_sweep,
)
from repro.core.simulator import (
    K_ARMS,
    EnvParams,
    Obs,
    env_init,
    env_step,
    expected_rewards,
    make_env_params,
    make_factored_env_params,
    max_steps_hint,
    static_energy_kj,
)

__all__ = [
    "DEFAULT_ARM", "FREQS_GHZ", "TABLE1_KJ", "AppModel", "app_names", "get_app",
    "ActionSpace", "Policy", "PolicyFns", "PolicyParams", "UCB_FNS",
    "energy_ucb", "energy_ts", "eps_greedy", "rr_freq", "static_policy",
    "factored_energy_ucb", "factored_ucb_fns", "ucb_family_k_unc",
    "interleave_policy_params", "make_policy_params", "phase_policy",
    "stack_policy_params", "sweep_policy_params",
    "drlcap", "rl_power", "make_reward_fn", "REWARD_VARIANTS",
    "RolloutSpec", "run_episode", "run_repeats", "run_sweep",
    "run_fleet_episode", "run_drlcap_protocol", "run_drlcap_cross",
    "engine_trace_count", "reset_engine_trace_count",
    "K_ARMS", "EnvParams", "Obs", "env_init", "env_step", "expected_rewards",
    "make_env_params", "make_factored_env_params", "max_steps_hint",
    "static_energy_kj",
    "saved_energy_kj", "energy_regret_kj", "summarize", "summarize_sweep",
]
