"""The bandit environment: a calibrated Aurora-node DVFS simulator as a
pure-JAX step function (jit/scan/vmap-friendly).

Semantics per decision interval (10 ms, paper §4.1):
  - progress  p_i = dt / T(f_i)            (completion-time model, §3.1)
  - energy    E_i = P_used(f_i) * dt       with P_used = E_table/T (so a
              static policy reproduces Table 1 exactly), + 0.3 J and
              150 us added on a frequency switch (§4.4)
  - counters  UC = core-active fraction ~ uc_base (offload kernels keep
              compute engines busy at any f); UU = copy-engine active
              fraction ~ (1-c) * T(f_max)/T(f) (data moved per unit time
              tracks throughput). The paper's performance proxy
              R = UC/UU is then ~ energy-per-unit-progress, which is
              what makes reward = -E*R the right online objective.
  - noise     multiplicative Gaussian on counters, inflated by
              early_noise * exp(-t/early_tau) at the start of a job
              (clock sync / thermal transients, §3.2), motivating
              optimistic initialization.

Rewards are normalized by the app's f_max scale so policy
hyper-parameters (alpha, lambda, mu_init) are app-independent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import (
    F_MAX,
    FREQS_GHZ,
    SWITCH_ENERGY_J,
    SWITCH_LATENCY_S,
    AppModel,
)

K_ARMS = len(FREQS_GHZ)


class EnvParams(NamedTuple):
    """Static, device-resident app description."""

    freqs: jax.Array  # (K,)
    p_used_kw: jax.Array  # (K,) energy-table-pinned interval power
    t_rel: jax.Array  # (K,) T(f)/T_ref
    progress: jax.Array  # (K,) job fraction per interval (noise-free)
    uc: jax.Array  # (K,)
    uu: jax.Array  # (K,)
    t_ref_s: jax.Array  # ()
    dt_s: jax.Array  # ()
    noise_energy: jax.Array
    noise_util: jax.Array
    early_noise: jax.Array
    early_tau: jax.Array
    reward_scale: jax.Array  # () normalizer: E*R at f_max
    e_interval_kj: jax.Array  # (K,) = p_used * dt (noise-free)


class EnvState(NamedTuple):
    remaining: jax.Array  # () job fraction left
    prev_arm: jax.Array  # () int32
    t: jax.Array  # () int32 step
    energy_kj: jax.Array  # () total energy so far
    time_s: jax.Array  # () wall time so far
    switches: jax.Array  # () int32


class Obs(NamedTuple):
    energy_j: jax.Array  # interval energy (J, noisy, incl. switch)
    uc: jax.Array
    uu: jax.Array
    progress: jax.Array  # noisy progress estimate
    reward: jax.Array  # normalized -E*R (default formulation)
    switched: jax.Array
    active: jax.Array  # pre-step: job still running


def make_env_params(app: AppModel, dt_s: float = 0.010) -> EnvParams:
    f = np.asarray(FREQS_GHZ)
    t_rel = app.c * F_MAX / f + (1 - app.c)
    t_abs = app.t_ref_s * t_rel
    p_used = np.asarray(app.e_table_kj) / t_abs  # kW
    uc = np.full(K_ARMS, app.uc_base)
    uu = np.clip((1 - app.c) / t_rel * app.uc_base, 1e-3, 1.0)
    progress = dt_s / t_abs
    e_interval = p_used * dt_s  # kJ
    r_scale = float(e_interval[-1] * uc[-1] / uu[-1] * 1e3)  # J-scale at fmax
    return EnvParams(
        freqs=jnp.asarray(f, jnp.float32),
        p_used_kw=jnp.asarray(p_used, jnp.float32),
        t_rel=jnp.asarray(t_rel, jnp.float32),
        progress=jnp.asarray(progress, jnp.float32),
        uc=jnp.asarray(uc, jnp.float32),
        uu=jnp.asarray(uu, jnp.float32),
        t_ref_s=jnp.float32(app.t_ref_s),
        dt_s=jnp.float32(dt_s),
        noise_energy=jnp.float32(app.noise_energy),
        noise_util=jnp.float32(app.noise_util),
        early_noise=jnp.float32(app.early_noise),
        early_tau=jnp.float32(app.early_tau),
        reward_scale=jnp.float32(r_scale),
        e_interval_kj=jnp.asarray(e_interval, jnp.float32),
    )


# Default relative uncore (HBM/interconnect) ladder, ascending with the
# max setting LAST so the flat arm K-1 = (f_max core, max uncore) keeps
# the scalar f_max / QoS-reference convention.
UNC_FREQS = (0.6, 0.8, 1.0)
# Uncore share of an app's pinned power budget: a floor for the fabric
# everything pays plus a term growing with memory intensity (1 - c) —
# the roofline-style calibration: bandwidth-bound apps spend more of
# their power moving bytes.
UNC_POWER_BASE = 0.12
UNC_POWER_MEM = 0.45
GAMMA_UNC = 2.0


def make_factored_env_params(
    app: AppModel,
    dt_s: float = 0.010,
    unc_freqs=UNC_FREQS,
    unc_power_frac=None,
) -> EnvParams:
    """Product-ladder environment: ``K = K_core * K_unc`` flat arms with
    the uncore axis MINOR (arm ``i`` = core ``i // K_unc``, uncore
    ``i % K_unc``, matching the policies/kernels decomposition), so
    every (K,)-table consumer — env_step, SimBackend, the sim-fused
    episode scan — runs unchanged on a factored ladder.

    Physics relative to :func:`make_env_params` (its tables ARE the
    ``y = 1`` column, exactly):

    - time: ``t_rel(f, y) = c * F_MAX/f + (1 - c)/y`` — the bandwidth
      term stretches as the uncore clock drops, the compute term does
      not (compute-bound apps are ~flat in uncore).
    - power: ``P(f, y) = P_used(f) * (1 - u_frac * (1 - y^GAMMA_UNC))``
      where ``P_used`` is the energy-table-pinned scalar power and
      ``u_frac`` is the uncore power share, calibrated from the app's
      memory intensity (``UNC_POWER_BASE + UNC_POWER_MEM * (1 - c)``)
      unless given. At ``y = 1`` the correction term is exactly zero.
    - counters: UU tracks copy-engine busy time ``(1 - c)/y`` over the
      stretched interval — dropping uncore on a bandwidth-bound app
      drives UU up, which the reward R = UC/UU penalizes, exactly the
      paper's proxy generalized to two knobs.

    ``unc_freqs`` must ascend to 1.0 so arm ``K - 1`` is the
    (f_max, max-uncore) corner (the scalar default-arm convention).
    """
    y = np.asarray(unc_freqs, np.float64)
    if y[-1] != 1.0 or np.any(np.diff(y) <= 0) or np.any(y <= 0):
        raise ValueError(
            f"unc_freqs must ascend to 1.0, got {tuple(unc_freqs)}"
        )
    if unc_power_frac is None:
        unc_power_frac = UNC_POWER_BASE + UNC_POWER_MEM * (1.0 - app.c)
    u = float(np.clip(unc_power_frac, 0.0, 0.6))
    f = np.asarray(FREQS_GHZ)
    # flat (K_core * K_unc,) tables, uncore minor
    ff = np.repeat(f, len(y))
    yy = np.tile(y, len(f))
    t_rel = app.c * F_MAX / ff + (1 - app.c) / yy
    t_abs = app.t_ref_s * t_rel
    p_used_scalar = np.asarray(app.e_table_kj) / (
        app.t_ref_s * (app.c * F_MAX / f + (1 - app.c))
    )  # kW, the y = 1 pinned power per core step
    p_used = np.repeat(p_used_scalar, len(y)) * (
        1.0 - u * (1.0 - yy ** GAMMA_UNC)
    )
    uc = np.full(ff.shape, app.uc_base)
    uu = np.clip((1 - app.c) / yy / t_rel * app.uc_base, 1e-3, 1.0)
    progress = dt_s / t_abs
    e_interval = p_used * dt_s  # kJ
    r_scale = float(e_interval[-1] * uc[-1] / uu[-1] * 1e3)
    return EnvParams(
        freqs=jnp.asarray(ff, jnp.float32),
        p_used_kw=jnp.asarray(p_used, jnp.float32),
        t_rel=jnp.asarray(t_rel, jnp.float32),
        progress=jnp.asarray(progress, jnp.float32),
        uc=jnp.asarray(uc, jnp.float32),
        uu=jnp.asarray(uu, jnp.float32),
        t_ref_s=jnp.float32(app.t_ref_s),
        dt_s=jnp.float32(dt_s),
        noise_energy=jnp.float32(app.noise_energy),
        noise_util=jnp.float32(app.noise_util),
        early_noise=jnp.float32(app.early_noise),
        early_tau=jnp.float32(app.early_tau),
        reward_scale=jnp.float32(r_scale),
        e_interval_kj=jnp.asarray(e_interval, jnp.float32),
    )


def env_init(params: EnvParams) -> EnvState:
    # the top-of-ladder corner: arm K-1 == DEFAULT_ARM on the scalar
    # ladder, and the (f_max, max-uncore) corner on factored ladders
    return EnvState(
        remaining=jnp.float32(1.0),
        prev_arm=jnp.int32(params.freqs.shape[0] - 1),
        t=jnp.int32(0),
        energy_kj=jnp.float32(0.0),
        time_s=jnp.float32(0.0),
        switches=jnp.int32(0),
    )


def env_step(params: EnvParams, state: EnvState, arm, key) -> tuple:
    """One decision interval. Returns (new_state, obs)."""
    arm = jnp.asarray(arm, jnp.int32)
    active = state.remaining > 0.0
    switched = (arm != state.prev_arm) & active

    k1, k2, k3, k4 = jax.random.split(key, 4)
    early = 1.0 + params.early_noise * jnp.exp(
        -state.t.astype(jnp.float32) / params.early_tau
    )
    n_e = 1.0 + params.noise_energy * early * jax.random.normal(k1)
    n_uc = 1.0 + params.noise_util * early * jax.random.normal(k2)
    n_uu = 1.0 + params.noise_util * early * jax.random.normal(k3)
    n_p = 1.0 + params.noise_util * jax.random.normal(k4)

    e_kj = params.e_interval_kj[arm] * jnp.maximum(n_e, 0.05)
    e_kj = e_kj + switched * (SWITCH_ENERGY_J / 1e3)
    uc = jnp.clip(params.uc[arm] * jnp.maximum(n_uc, 0.05), 1e-3, 1.0)
    uu = jnp.clip(params.uu[arm] * jnp.maximum(n_uu, 0.05), 1e-3, 1.0)
    # switch latency eats into the interval's useful time
    eff = 1.0 - switched * (SWITCH_LATENCY_S / params.dt_s)
    prog = params.progress[arm] * jnp.maximum(n_p, 0.0) * eff

    reward = -(e_kj * 1e3) * (uc / uu) / params.reward_scale

    new_state = EnvState(
        remaining=jnp.maximum(state.remaining - prog * active, 0.0),
        prev_arm=jnp.where(active, arm, state.prev_arm),
        t=state.t + active.astype(jnp.int32),
        energy_kj=state.energy_kj + e_kj * active,
        time_s=state.time_s + (params.dt_s + switched * SWITCH_LATENCY_S) * active,
        switches=state.switches + switched.astype(jnp.int32),
    )
    obs = Obs(
        energy_j=e_kj * 1e3,
        uc=uc,
        uu=uu,
        progress=prog,
        reward=reward,
        switched=switched,
        active=active,
    )
    return new_state, obs


def expected_rewards(params: EnvParams) -> jax.Array:
    """Noise-free E[r] per arm (for regret traces / oracle)."""
    return -(params.e_interval_kj * 1e3) * (params.uc / params.uu) / params.reward_scale


def static_energy_kj(params: EnvParams, arm: int) -> float:
    """Total job energy at a static frequency (closed form)."""
    steps = 1.0 / params.progress[arm]
    return float(params.e_interval_kj[arm] * steps)


def max_steps_hint(params: EnvParams, slack: float = 1.35) -> int:
    worst = float(jnp.max(1.0 / params.progress))
    return int(worst * slack) + int(params.progress.shape[0])
