"""Bandit policies: EnergyUCB (Alg. 1) and the paper's baselines.

Hyperparameters are DATA, not code. Every policy family is a triple of
module-level pure functions

    init(params, key)              -> state
    select(params, state, key)     -> arm        (int32)
    update(params, state, arm, obs)-> state

bundled in a hashable :class:`PolicyFns`, plus a pytree of
hyperparameter arrays (:class:`PolicyParams` for the EnergyUCB family).
Because the functions are module-level singletons and everything
configurable flows through the params pytree, ONE jitted trace serves
every EnergyUCB variant — the ablations (no optimistic init, no
switching penalty), the QoS-constrained mode, the sliding-window mode,
and the RooflineUCB warm start are all just different param values, and
``jax.vmap`` batches seeds x apps x hyperparams x fleet nodes through
the same trace (see repro.core.rollout.run_sweep).

Flags are encoded static-safe: ``qos_delta < 0`` disables the QoS
feasible set, ``gamma >= 1`` disables the sliding-window discount, and
``optimistic`` is a 0/1 float — all branchless ``jnp.where`` selects, so
a single vmap can mix variants.

:class:`Policy` keeps the seed's ergonomic surface (``policy.init(key)``
etc. bind the params) for interactive use; batch code should pass
``policy.fns`` (static) and ``policy.params`` (traced) separately.

Default hyperparameters: rewards are normalized to ~[-1, 0] by the
app's f_max scale, so per-arm gaps on flat landscapes are below 0.01.
The switching penalty must sit BELOW that gap scale or SA-UCB locks
into a near-best arm forever (linear regret); alpha=0.2's exploration
spend exceeds a single-job horizon at these gaps. alpha=0.1 /
lam=0.02 converge on every calibrated app while still cutting switches
by >3x (see tests/test_bandit.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.constants import DEFAULT_ALPHA, DEFAULT_LAM
from repro.core.simulator import K_ARMS, Obs

PyTree = Any


class PolicyFns(NamedTuple):
    """Hashable triple of module-level pure functions (the static half
    of a policy; jit keys on function identity, so reusing one of these
    singletons across configs means zero retraces)."""

    init: Callable[[PyTree, jax.Array], PyTree]
    select: Callable[[PyTree, PyTree, jax.Array], jax.Array]
    update: Callable[[PyTree, PyTree, jax.Array, Obs], PyTree]


@dataclass(frozen=True, eq=False)
class Policy:
    """A (fns, params) pair. ``eq=False``: params hold arrays, and jit
    never needs to hash a Policy — engines take fns/params separately."""

    name: str
    fns: PolicyFns
    params: PyTree

    # Seed-compatible bound surface (closures over params) for
    # interactive / per-step use; batch paths unpack fns/params.
    def init(self, key):
        return self.fns.init(self.params, key)

    def select(self, state, key):
        return self.fns.select(self.params, state, key)

    def update(self, state, arm, obs):
        return self.fns.update(self.params, state, arm, obs)

    def with_params(self, params) -> "Policy":
        return replace(self, params=params)


class ActionSpace(NamedTuple):
    """Static descriptor of the arm ladder: ``k_core`` core-frequency
    steps x ``k_unc`` uncore/memory-frequency steps, flattened to one
    arm index ``i = core * k_unc + unc`` so every (N, K) state array,
    kernel, and trace format works unchanged at ``K = k_core * k_unc``.
    ``k_unc == 1`` IS the scalar ladder (the degenerate case is the
    common case, and it is bit-exact with the pre-factored code). Both
    fields are Python ints — the descriptor is hashable and rides jit
    static arguments."""

    k_core: int
    k_unc: int = 1

    @property
    def k(self) -> int:
        return self.k_core * self.k_unc

    def flat(self, core, unc):
        """Flat arm index of a (core, unc) pair (array-friendly)."""
        return core * self.k_unc + unc

    def split(self, arm) -> Tuple[Any, Any]:
        """(core, unc) decomposition of a flat arm (array-friendly)."""
        return arm // self.k_unc, arm % self.k_unc


def _masked_argmax(scores: jax.Array, feasible: jax.Array) -> jax.Array:
    neg = jnp.finfo(scores.dtype).min
    has_feasible = jnp.any(feasible)
    masked = jnp.where(feasible, scores, neg)
    return jnp.where(has_feasible, jnp.argmax(masked), jnp.argmax(scores)).astype(
        jnp.int32
    )


# ---------------------------------------------------------------------------
# EnergyUCB (Algorithm 1) + QoS-constrained variant (§3.3) — one function
# set; every paper variant is a PolicyParams value.
# ---------------------------------------------------------------------------


class PolicyParams(NamedTuple):
    """EnergyUCB-family hyperparameters as a pytree of arrays.

    All leaves are arrays so configs stack/vmap; sentinel encodings keep
    every variant reachable without Python branches:

    - ``qos_delta < 0``  -> unconstrained (QoS feasible set disabled)
    - ``gamma >= 1``     -> stationary means (no sliding window)
    - ``optimistic``     -> 1.0 = optimistic init; 0.0 = round-robin
                            warm-up (the 'w/o Opt. Ini.' ablation)
    - ``prior_mu/prior_n`` -> RooflineUCB warm start; prior_n == 0 with
                            prior_mu == mu_init reproduces the flat init
    - ``lam_unc < 0``    -> one shared switching penalty on any move
                            (factored ladders only consult this lane;
                            ``lam_unc >= 0`` splits the cost into
                            lam*1[core moved] + lam_unc*1[unc moved])
    """

    alpha: jax.Array  # () exploration coefficient
    lam: jax.Array  # () switching penalty (core dimension when factored)
    qos_delta: jax.Array  # () slowdown budget; negative disables
    gamma: jax.Array  # () sliding-window discount; >=1 disables
    optimistic: jax.Array  # () 0/1 flag
    prior_mu: jax.Array  # (K,) initial mean-reward estimates
    prior_n: jax.Array  # () prior pseudo-count
    default_arm: jax.Array  # () int32 reference arm (f_max)
    # appended LAST so positional PolicyParams(*leaves) reconstructions
    # of pre-factored 8-leaf checkpoints keep working via the default
    lam_unc: jax.Array = -1.0  # () uncore penalty; < 0 = shared


def make_policy_params(
    k: int = K_ARMS,
    alpha: float = DEFAULT_ALPHA,
    switching_penalty: float = DEFAULT_LAM,
    mu_init: float = 0.0,
    optimistic_init: bool = True,
    qos_delta: Optional[float] = None,
    default_arm: int = K_ARMS - 1,
    window_discount: Optional[float] = None,
    prior_mu: Optional[jax.Array] = None,
    prior_n: float = 0.0,
    lam_unc: Optional[float] = None,
) -> PolicyParams:
    pm = (
        jnp.full((k,), mu_init, jnp.float32)
        if prior_mu is None
        else jnp.asarray(prior_mu, jnp.float32)
    )
    return PolicyParams(
        alpha=jnp.float32(alpha),
        lam=jnp.float32(switching_penalty),
        qos_delta=jnp.float32(-1.0 if qos_delta is None else qos_delta),
        gamma=jnp.float32(1.0 if window_discount is None else window_discount),
        optimistic=jnp.float32(1.0 if optimistic_init else 0.0),
        prior_mu=pm,
        prior_n=jnp.float32(prior_n),
        default_arm=jnp.int32(default_arm),
        lam_unc=jnp.float32(-1.0 if lam_unc is None else lam_unc),
    )


def stack_policy_params(cfgs: Sequence[PolicyParams]) -> PolicyParams:
    """Stack configs along a new leading axis for vmapped sweeps."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *cfgs)


def sweep_policy_params(alphas, lams, **common) -> PolicyParams:
    """The alpha x lambda grid as one stacked PolicyParams (row-major)."""
    return stack_policy_params(
        [
            make_policy_params(alpha=float(a), switching_penalty=float(l), **common)
            for a in alphas
            for l in lams
        ]
    )


def interleave_policy_params(
    prefill: PolicyParams, decode: PolicyParams, n_pairs: int
) -> PolicyParams:
    """Per-phase hyperparameters on the lane layout of a phase-split
    serving fleet: lane ``2m`` carries the prefill config and lane
    ``2m + 1`` the decode config of node ``m``, for ``n_pairs`` nodes —
    a (2*n_pairs,)-lane PolicyParams ((2*n_pairs, K) for prior_mu) that
    rides the existing hyperparams-as-data machinery, so mixed
    per-phase alpha/lambda/qos_delta fleets still dispatch through the
    one fused ``fleet_step`` and slice cleanly under
    ``slice_policy_lanes`` (even-aligned slices, matching
    ``ServingBackend.local_slice``)."""

    def leaf(a, b):
        pair = jnp.stack([jnp.asarray(a), jnp.asarray(b)])  # (2, ...)
        return jnp.tile(pair, (n_pairs,) + (1,) * (pair.ndim - 1))

    return jax.tree.map(leaf, prefill, decode)


def phase_policy(
    n_pairs: int,
    prefill: Optional[PolicyParams] = None,
    decode: Optional[PolicyParams] = None,
    name: Optional[str] = None,
    space: Optional["ActionSpace"] = None,
) -> Policy:
    """EnergyUCB with independent prefill/decode hyperparameter lanes
    for a ``phase_split=True`` :class:`~repro.workload.serving_backend
    .ServingBackend` of ``n_pairs`` nodes. Defaults both phases to the
    stock config; pass e.g. ``decode=make_policy_params(qos_delta=None)``
    to leave the bandwidth-bound phase unconstrained while the
    compute-bound prefill lane keeps a tight slowdown budget. A factored
    ``space`` swaps in the (core x uncore) select rule — pass params
    built at ``k=space.k`` (e.g. from ``factored_energy_ucb(...).params``)
    so the lanes match the flat product ladder."""
    dk = {} if space is None else {"k": space.k, "default_arm": space.k - 1}
    pp = prefill if prefill is not None else make_policy_params(**dk)
    dp = decode if decode is not None else make_policy_params(**dk)
    fns = (UCB_FNS if space is None
           else factored_ucb_fns(space.k_core, space.k_unc))
    return Policy(
        name or "EnergyUCB-phase",
        fns,
        interleave_policy_params(pp, dp, n_pairs),
    )


def ucb_init(params: PolicyParams, key) -> PyTree:
    del key
    k = params.prior_mu.shape[-1]
    return {
        "mu": params.prior_mu,
        "n": jnp.full((k,), params.prior_n, jnp.float32),
        "prev": jnp.asarray(params.default_arm, jnp.int32),
        "t": jnp.float32(0.0),
        "phat": jnp.zeros((k,), jnp.float32),
        "pn": jnp.zeros((k,), jnp.float32),
    }


def _select_bonus_penalty(params: PolicyParams, state: PyTree, arms, t,
                          k_unc: int):
    """Exploration bonus and switching penalty of the select rule, with
    the factored/scalar split on the STATIC ``k_unc`` (the scalar branch
    keeps the pre-factored expressions verbatim, so ``k_unc == 1`` is
    bit-exact with the seed policy). Factored ladders mirror the fused
    kernel: per-dimension bonuses over the marginal pull counts
    (integer-valued float32 sums — exact), and switching cost
    ``lam*1[core moved] + lam_unc*1[unc moved]`` with the sentinel
    ``lam_unc < 0`` = one shared penalty on any move."""
    if k_unc == 1:
        bonus = params.alpha * jnp.sqrt(
            jnp.log(t) / jnp.maximum(state["n"], 1.0)
        )
        return bonus, params.lam * (arms != state["prev"])
    k = state["n"].shape[-1]
    m = state["n"].reshape(k // k_unc, k_unc)
    lt = jnp.log(t)
    b_core = params.alpha * jnp.sqrt(lt / jnp.maximum(m.sum(1), 1.0))
    b_unc = params.alpha * jnp.sqrt(lt / jnp.maximum(m.sum(0), 1.0))
    bonus = (b_core[:, None] + b_unc[None, :]).reshape(k)
    prev = state["prev"]
    shared = params.lam * (arms != prev)
    core_moved = (arms // k_unc) != (prev // k_unc)
    unc_moved = (arms % k_unc) != (prev % k_unc)
    split = params.lam * core_moved + params.lam_unc * unc_moved
    return bonus, jnp.where(params.lam_unc < 0.0, shared, split)


def _ucb_select_impl(params: PolicyParams, state: PyTree, *,
                     k_unc: int = 1) -> jax.Array:
    k = state["mu"].shape[-1]
    arms = jnp.arange(k)
    t = jnp.maximum(state["t"] + 1.0, 2.0)
    bonus, penalty = _select_bonus_penalty(params, state, arms, t, k_unc)
    # sliding-window optimism: under a discount, an arm's effective count
    # decays toward 0 between pulls, but the bonus is floored at n=1 — a
    # noise-corrupted stale estimate would never be revisited. Shrink the
    # estimate back to the optimistic prior (pseudo-weight 0.25: heals
    # within ~2 windows without over-exploring the tail) so stale arms
    # decay to "untried" instead of "bad forever". Stationary
    # (gamma >= 1) keeps the raw mean bit-exactly.
    w0 = 0.25
    shrunk = (state["n"] * state["mu"] + w0 * params.prior_mu) / (state["n"] + w0)
    mu_eff = jnp.where(params.gamma < 1.0, shrunk, state["mu"])
    sa = mu_eff + bonus - penalty
    # round-robin warm-up over all K arms (the naive-UCB1 ablation)
    untried = state["n"] < 1.0
    warm = jnp.where(untried, 1e9 - arms * 1.0, -1e9)
    sa = jnp.where((params.optimistic < 0.5) & jnp.any(untried), warm, sa)
    # feasible set {i : 1 - p_hat_i / p_hat[f_max] <= delta}; untried
    # arms stay feasible (optimism under uncertainty), and until the
    # reference arm itself has a progress sample EVERY arm stays
    # feasible — p_ref = inf would otherwise give every tried arm
    # slowdown 1.0 and leave only untried arms selectable
    pn_ref = state["pn"][params.default_arm]
    p_ref = jnp.where(pn_ref > 0, state["phat"][params.default_arm], jnp.inf)
    slowdown = 1.0 - state["phat"] / p_ref
    feasible = (
        (params.qos_delta < 0.0)
        | (pn_ref < 1.0)
        | (state["pn"] < 1.0)
        | (slowdown <= params.qos_delta)
    )
    return _masked_argmax(sa, feasible)


def ucb_select(params: PolicyParams, state: PyTree, key) -> jax.Array:
    """SA-UCB_i = mu_i + alpha*sqrt(ln t / max(1, n_i)) - lam*1{i != prev},
    restricted to the QoS-feasible set when qos_delta >= 0."""
    del key
    return _ucb_select_impl(params, state, k_unc=1)


def ucb_update(params: PolicyParams, state: PyTree, arm, obs: Obs) -> PyTree:
    # one incremental running mean serves the stationary AND the
    # discounted (sliding-window) lanes: decaying every arm's effective
    # count by gamma and then folding the sample in incrementally,
    # mu + (r - mu) / (n*g + 1), is algebraically the discounted mean
    # (mu*n*g + r) / (n*g + 1) — so gamma only ever touches the counts
    # and the seed's exact mean dataflow is preserved bit-for-bit on
    # stationary rows. The counts add an elementwise one-hot (not a
    # scatter): it is the same select(g<1, n*g, n) + onehot expression
    # the fused kernel carries, so XLA makes the same mul-add
    # contraction choice on both paths and fused-vs-vmapped fleets stay
    # bit-identical. The MEANS stay one-sided scatters: the rollout
    # engine is pinned bit-for-bit against the frozen seed episode
    # (test_rollout_engine), whose reference policy computes mu/phat as
    # scatters — rewriting them to the kernel's one-hot form shifts the
    # scanned graph by 1 ulp. The fused twin's parity is carried by the
    # n/pn count expressions plus the shared select, and is covered by
    # the 116 fused-vs-vmapped parity tests.
    g = params.gamma
    stationary = g >= 1.0
    hot = (jnp.arange(state["n"].shape[-1]) == arm).astype(state["n"].dtype)
    n = jnp.where(stationary, state["n"], state["n"] * g) + hot
    # repro-lint: disable=RPL001 seed-frozen mean dataflow; engine bit-parity pins this scatter (see comment above)
    mu = state["mu"].at[arm].set(
        state["mu"][arm] + (obs.reward - state["mu"][arm]) / n[arm]
    )
    # the progress statistics discount under gamma < 1 too: after a
    # workload phase change the QoS feasible set would otherwise be
    # computed from stale slowdown estimates forever (an arm that was
    # fast in the old phase keeps passing the budget check in the new
    # one). Decayed pn also re-arms the untried-arm feasibility rule, so
    # stale arms revert to "unknown" rather than "known fast".
    pn = jnp.where(stationary, state["pn"], state["pn"] * g) + hot
    # repro-lint: disable=RPL001 seed-frozen mean dataflow; engine bit-parity pins this scatter (see comment above)
    phat = state["phat"].at[arm].set(
        state["phat"][arm] + (obs.progress - state["phat"][arm]) / pn[arm]
    )
    return {
        "mu": mu,
        "n": n,
        "prev": jnp.asarray(arm, jnp.int32),
        "t": state["t"] + 1.0,
        "phat": phat,
        "pn": pn,
    }


UCB_FNS = PolicyFns(ucb_init, ucb_select, ucb_update)


@functools.lru_cache(maxsize=None)
def factored_ucb_fns(k_core: int, k_unc: int) -> PolicyFns:
    """The EnergyUCB function set over a factored ``k_core x k_unc``
    ladder. ``k_unc`` is STATIC (it changes expression shapes), so each
    factorization gets its own cached PolicyFns singleton — jit keys on
    function identity, and every policy sharing a factorization shares
    one trace. ``k_unc == 1`` returns UCB_FNS itself: the scalar ladder
    is the degenerate factorization, bit-exactly. ``update`` and
    ``init`` are the scalar functions unchanged (the flat (K,) state is
    factorization-blind; only select decomposes the index)."""
    if k_core < 1 or k_unc < 1:
        raise ValueError(f"need k_core, k_unc >= 1, got {k_core}x{k_unc}")
    if k_unc == 1:
        return UCB_FNS

    def select(params: PolicyParams, state: PyTree, key) -> jax.Array:
        del key
        return _ucb_select_impl(params, state, k_unc=k_unc)

    select.__name__ = select.__qualname__ = f"ucb_select_f{k_core}x{k_unc}"
    select.k_unc = k_unc
    return PolicyFns(ucb_init, select, ucb_update)


def ucb_family_k_unc(fns: PolicyFns) -> Optional[int]:
    """``k_unc`` when ``fns`` is the fused-kernel-exact EnergyUCB family
    (1 for the scalar UCB_FNS, the factory's static otherwise); None for
    every other policy family — the one place kernel dispatch learns a
    policy's factorization."""
    if fns is UCB_FNS:
        return 1
    if (fns.init is ucb_init and fns.update is ucb_update
            and getattr(fns.select, "k_unc", 0) > 1):
        return int(fns.select.k_unc)
    return None


def factored_energy_ucb(
    space: ActionSpace,
    alpha: float = DEFAULT_ALPHA,
    switching_penalty: float = DEFAULT_LAM,
    uncore_penalty: Optional[float] = None,
    mu_init: float = 0.0,
    optimistic_init: bool = True,
    qos_delta: Optional[float] = None,
    default_arm: Optional[int] = None,
    window_discount: Optional[float] = None,
    prior_mu: Optional[jax.Array] = None,
    prior_n: float = 0.0,
    name: Optional[str] = None,
) -> Policy:
    """EnergyUCB over a factored (core, uncore) product ladder: the flat
    ``K = k_core * k_unc`` state rides every existing code path, select
    decomposes the index for per-dimension bonuses and switching costs.
    ``uncore_penalty=None`` keeps the sentinel (one shared penalty on
    any move — how a scalar config behaves on a product ladder);
    ``default_arm`` defaults to the (f_max core, f_max uncore) corner
    ``K - 1``, matching the scalar f_max convention."""
    k = space.k
    params = make_policy_params(
        k=k,
        alpha=alpha,
        switching_penalty=switching_penalty,
        mu_init=mu_init,
        optimistic_init=optimistic_init,
        qos_delta=qos_delta,
        default_arm=k - 1 if default_arm is None else default_arm,
        window_discount=window_discount,
        prior_mu=prior_mu,
        prior_n=prior_n,
        lam_unc=uncore_penalty,
    )
    nm = name or (
        f"EnergyUCB-{space.k_core}x{space.k_unc}"
        + (f"-QoS{qos_delta}" if qos_delta is not None else "")
        + (f"-SW{window_discount}" if window_discount else "")
    )
    return Policy(nm, factored_ucb_fns(space.k_core, space.k_unc), params)


def energy_ucb(
    k: int = K_ARMS,
    alpha: float = DEFAULT_ALPHA,
    switching_penalty: float = DEFAULT_LAM,
    mu_init: float = 0.0,
    optimistic_init: bool = True,
    qos_delta: Optional[float] = None,
    default_arm: int = K_ARMS - 1,
    window_discount: Optional[float] = None,
    prior_mu: Optional[jax.Array] = None,
    prior_n: float = 0.0,
    name: Optional[str] = None,
) -> Policy:
    """Every EnergyUCB variant over one function set (UCB_FNS):

    - optimistic_init=False reproduces the 'w/o Opt. Ini.' ablation.
    - qos_delta enables Constrained EnergyUCB (§3.3).
    - window_discount (gamma<1) gives the beyond-paper sliding-window
      SW-SA-UCB for non-stationary phases.
    - prior_mu/prior_n give the beyond-paper RooflineUCB warm start.
    """
    params = make_policy_params(
        k=k,
        alpha=alpha,
        switching_penalty=switching_penalty,
        mu_init=mu_init,
        optimistic_init=optimistic_init,
        qos_delta=qos_delta,
        default_arm=default_arm,
        window_discount=window_discount,
        prior_mu=prior_mu,
        prior_n=prior_n,
    )
    nm = name or (
        "EnergyUCB"
        + ("" if optimistic_init else "-noOptInit")
        + ("" if switching_penalty else "-noPenalty")
        + (f"-QoS{qos_delta}" if qos_delta is not None else "")
        + (f"-SW{window_discount}" if window_discount else "")
    )
    return Policy(nm, UCB_FNS, params)


# ---------------------------------------------------------------------------
# Baselines (§4.1) — same fns/params shape so the one rollout engine
# runs them unchanged.
# ---------------------------------------------------------------------------


def _static_init(params, key):
    del params, key
    return {"t": jnp.float32(0.0)}


def _static_select(params, state, key):
    del state, key
    return jnp.asarray(params["arm"], jnp.int32)


def _static_update(params, state, arm, obs):
    del params, arm, obs
    return {"t": state["t"] + 1.0}


STATIC_FNS = PolicyFns(_static_init, _static_select, _static_update)


def static_policy(arm: int, k: int = K_ARMS) -> Policy:
    del k
    return Policy(f"Static-{arm}", STATIC_FNS, {"arm": jnp.int32(arm)})


def _rr_init(params, key):
    del params, key
    return {"t": jnp.int32(0)}


def _rr_select(params, state, key):
    del key
    return jnp.mod(state["t"], params["k"]).astype(jnp.int32)


def _rr_update(params, state, arm, obs):
    del params, arm, obs
    return {"t": state["t"] + 1}


RR_FNS = PolicyFns(_rr_init, _rr_select, _rr_update)


def rr_freq(k: int = K_ARMS) -> Policy:
    return Policy("RRFreq", RR_FNS, {"k": jnp.int32(k)})


def _eps_init(params, key):
    del key
    return {
        "mu": params["mu0"],
        "n": jnp.zeros_like(params["mu0"]),
        "t": jnp.float32(0.0),
    }


def _eps_select(params, state, key):
    k = state["mu"].shape[-1]
    k1, k2 = jax.random.split(key)
    explore = jax.random.bernoulli(k1, params["eps"])
    rand_arm = jax.random.randint(k2, (), 0, k)
    return jnp.where(explore, rand_arm, jnp.argmax(state["mu"])).astype(jnp.int32)


def _mean_update(state, arm, obs):
    # baseline-only helper (eps-greedy / TS): no fused kernel twin, so
    # there is no second arithmetic path to hold bit-parity with
    n = state["n"].at[arm].add(1.0)  # repro-lint: disable=RPL001 baseline policy, no fused twin to match
    mu = state["mu"].at[arm].set(  # repro-lint: disable=RPL001 baseline policy, no fused twin to match
        state["mu"][arm] + (obs.reward - state["mu"][arm]) / n[arm]
    )
    return mu, n


def _eps_update(params, state, arm, obs):
    del params
    mu, n = _mean_update(state, arm, obs)
    return {"mu": mu, "n": n, "t": state["t"] + 1.0}


EPS_FNS = PolicyFns(_eps_init, _eps_select, _eps_update)


def eps_greedy(k: int = K_ARMS, eps: float = 0.05, mu_init: float = 0.0) -> Policy:
    params = {
        "eps": jnp.float32(eps),
        "mu0": jnp.full((k,), mu_init, jnp.float32),
    }
    return Policy("eps-greedy", EPS_FNS, params)


def _ts_init(params, key):
    del key
    return {"mu": params["mu0"], "n": jnp.zeros_like(params["mu0"])}


def _ts_select(params, state, key):
    k = state["mu"].shape[-1]
    std = params["sigma0"] / jnp.sqrt(state["n"] + 1.0)
    theta = state["mu"] + std * jax.random.normal(key, (k,))
    return jnp.argmax(theta).astype(jnp.int32)


def _ts_update(params, state, arm, obs):
    del params
    mu, n = _mean_update(state, arm, obs)
    return {"mu": mu, "n": n}


TS_FNS = PolicyFns(_ts_init, _ts_select, _ts_update)


def energy_ts(k: int = K_ARMS, sigma0: float = 0.5, mu_init: float = 0.0) -> Policy:
    """Gaussian Thompson sampling over per-arm mean rewards."""
    params = {
        "sigma0": jnp.float32(sigma0),
        "mu0": jnp.full((k,), mu_init, jnp.float32),
    }
    return Policy("EnergyTS", TS_FNS, params)
