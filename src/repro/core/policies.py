"""Bandit policies: EnergyUCB (Alg. 1) and the paper's baselines.

All policies are triples of pure functions over jnp pytrees:

    init(key) -> state
    select(state, key) -> arm          (int32)
    update(state, arm, obs) -> state

so a whole episode runs under lax.scan, vmaps across seeds/apps, and
scales to a fleet of controllers (repro.core.fleet).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.simulator import K_ARMS, Obs

PyTree = Any


@dataclass(frozen=True)
class Policy:
    name: str
    init: Callable[[jax.Array], PyTree]
    select: Callable[[PyTree, jax.Array], jax.Array]
    update: Callable[[PyTree, jax.Array, Obs], PyTree]


def _masked_argmax(scores: jax.Array, feasible: jax.Array) -> jax.Array:
    neg = jnp.finfo(scores.dtype).min
    has_feasible = jnp.any(feasible)
    masked = jnp.where(feasible, scores, neg)
    return jnp.where(has_feasible, jnp.argmax(masked), jnp.argmax(scores)).astype(
        jnp.int32
    )


# ---------------------------------------------------------------------------
# EnergyUCB (Algorithm 1) + QoS-constrained variant (§3.3)
# ---------------------------------------------------------------------------


def energy_ucb(
    k: int = K_ARMS,
    alpha: float = 0.2,
    switching_penalty: float = 0.05,
    mu_init: float = 0.0,
    optimistic_init: bool = True,
    qos_delta: Optional[float] = None,
    default_arm: int = K_ARMS - 1,
    window_discount: Optional[float] = None,
    prior_mu: Optional[jax.Array] = None,
    prior_n: float = 0.0,
    name: Optional[str] = None,
) -> Policy:
    """SA-UCB_i = mu_i + alpha*sqrt(ln t / max(1, n_i)) - lam*1{i != prev}.

    - optimistic_init=False reproduces the 'w/o Opt. Ini.' ablation: a
      forced round-robin warm-up over all K arms (naive UCB1 init).
    - qos_delta enables Constrained EnergyUCB: arms restricted to the
      feasible set {i : 1 - p_hat_i / p_hat[f_max] <= delta} (untried
      arms stay feasible — optimism under uncertainty).
    - window_discount (gamma<1) gives the beyond-paper sliding-window
      SW-SA-UCB for non-stationary phases.
    - prior_mu/prior_n give the beyond-paper RooflineUCB warm start.
    """
    lam = switching_penalty

    def init(key):
        del key
        mu0 = jnp.full((k,), mu_init, jnp.float32)
        n0 = jnp.zeros((k,), jnp.float32)
        if prior_mu is not None:
            mu0 = jnp.asarray(prior_mu, jnp.float32)
            n0 = jnp.full((k,), float(prior_n), jnp.float32)
        return {
            "mu": mu0,
            "n": n0,
            "prev": jnp.int32(default_arm),
            "t": jnp.float32(0.0),
            "phat": jnp.zeros((k,), jnp.float32),
            "pn": jnp.zeros((k,), jnp.float32),
        }

    def select(state, key):
        del key
        t = jnp.maximum(state["t"] + 1.0, 2.0)
        bonus = alpha * jnp.sqrt(jnp.log(t) / jnp.maximum(state["n"], 1.0))
        sa = state["mu"] + bonus - lam * (jnp.arange(k) != state["prev"])
        if not optimistic_init:
            # round-robin warm-up: play each arm once first
            tt = state["t"].astype(jnp.int32)
            rr = jnp.mod(tt, k)
            untried = state["n"] < 1.0
            sa = jnp.where(jnp.any(untried), jnp.where(untried, 1e9 - jnp.arange(k) * 1.0, -1e9), sa)
            del rr
        feasible = jnp.ones((k,), bool)
        if qos_delta is not None:
            p_ref = jnp.where(
                state["pn"][default_arm] > 0, state["phat"][default_arm], jnp.inf
            )
            slowdown = 1.0 - state["phat"] / p_ref
            feasible = (state["pn"] < 1.0) | (slowdown <= qos_delta)
        return _masked_argmax(sa, feasible)

    def update(state, arm, obs: Obs):
        n = state["n"].at[arm].add(1.0)
        mu = state["mu"]
        if window_discount is not None:
            g = window_discount
            n = state["n"] * g
            n = n.at[arm].add(1.0)
            mu = mu * 1.0  # discounted mean via effective counts below
            mu = mu.at[arm].set(
                (state["mu"][arm] * state["n"][arm] * g + obs.reward) / n[arm]
            )
        else:
            mu = mu.at[arm].set(
                state["mu"][arm] + (obs.reward - state["mu"][arm]) / n[arm]
            )
        pn = state["pn"].at[arm].add(1.0)
        phat = state["phat"].at[arm].set(
            state["phat"][arm] + (obs.progress - state["phat"][arm]) / pn[arm]
        )
        return {
            "mu": mu,
            "n": n,
            "prev": jnp.asarray(arm, jnp.int32),
            "t": state["t"] + 1.0,
            "phat": phat,
            "pn": pn,
        }

    nm = name or (
        "EnergyUCB"
        + ("" if optimistic_init else "-noOptInit")
        + ("" if lam else "-noPenalty")
        + (f"-QoS{qos_delta}" if qos_delta is not None else "")
        + (f"-SW{window_discount}" if window_discount else "")
    )
    return Policy(nm, init, select, update)


# ---------------------------------------------------------------------------
# Baselines (§4.1)
# ---------------------------------------------------------------------------


def static_policy(arm: int, k: int = K_ARMS) -> Policy:
    def init(key):
        return {"t": jnp.float32(0.0)}

    def select(state, key):
        return jnp.int32(arm)

    def update(state, a, obs):
        return {"t": state["t"] + 1.0}

    return Policy(f"Static-{arm}", init, select, update)


def rr_freq(k: int = K_ARMS) -> Policy:
    def init(key):
        return {"t": jnp.int32(0)}

    def select(state, key):
        return jnp.mod(state["t"], k).astype(jnp.int32)

    def update(state, a, obs):
        return {"t": state["t"] + 1}

    return Policy("RRFreq", init, select, update)


def eps_greedy(k: int = K_ARMS, eps: float = 0.05, mu_init: float = 0.0) -> Policy:
    def init(key):
        return {
            "mu": jnp.full((k,), mu_init, jnp.float32),
            "n": jnp.zeros((k,), jnp.float32),
            "t": jnp.float32(0.0),
        }

    def select(state, key):
        k1, k2 = jax.random.split(key)
        explore = jax.random.bernoulli(k1, eps)
        rand_arm = jax.random.randint(k2, (), 0, k)
        return jnp.where(explore, rand_arm, jnp.argmax(state["mu"])).astype(jnp.int32)

    def update(state, arm, obs):
        n = state["n"].at[arm].add(1.0)
        mu = state["mu"].at[arm].set(
            state["mu"][arm] + (obs.reward - state["mu"][arm]) / n[arm]
        )
        return {"mu": mu, "n": n, "t": state["t"] + 1.0}

    return Policy(f"eps-greedy", init, select, update)


def energy_ts(k: int = K_ARMS, sigma0: float = 0.5, mu_init: float = 0.0) -> Policy:
    """Gaussian Thompson sampling over per-arm mean rewards."""

    def init(key):
        return {
            "mu": jnp.full((k,), mu_init, jnp.float32),
            "n": jnp.zeros((k,), jnp.float32),
        }

    def select(state, key):
        std = sigma0 / jnp.sqrt(state["n"] + 1.0)
        theta = state["mu"] + std * jax.random.normal(key, (k,))
        return jnp.argmax(theta).astype(jnp.int32)

    def update(state, arm, obs):
        n = state["n"].at[arm].add(1.0)
        mu = state["mu"].at[arm].set(
            state["mu"][arm] + (obs.reward - state["mu"][arm]) / n[arm]
        )
        return {"mu": mu, "n": n}

    return Policy("EnergyTS", init, select, update)
