"""Evaluation metrics (§4.1): saved energy vs. the f_max default, and
energy regret vs. the best static frequency. ``summarize_sweep`` is the
batched counterpart for run_sweep's (n_configs, n_repeats) outputs."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.calibration import DEFAULT_ARM
from repro.core.simulator import EnvParams, static_energy_kj


def saved_energy_kj(params: EnvParams, method_energy_kj: float) -> float:
    return static_energy_kj(params, DEFAULT_ARM) - float(method_energy_kj)


def energy_regret_kj(params: EnvParams, method_energy_kj: float) -> float:
    best = min(static_energy_kj(params, i) for i in range(len(params.freqs)))
    return float(method_energy_kj) - best


def best_static_arm(params: EnvParams) -> int:
    es = [static_energy_kj(params, i) for i in range(len(params.freqs))]
    return int(np.argmin(es))


def summarize(params: EnvParams, energies: np.ndarray) -> Dict[str, float]:
    e = float(np.mean(energies))
    return {
        "energy_kj": e,
        "energy_std": float(np.std(energies)),
        "saved_energy_kj": saved_energy_kj(params, e),
        "energy_regret_kj": energy_regret_kj(params, e),
    }


def summarize_sweep(params: EnvParams, energies: np.ndarray) -> List[Dict[str, float]]:
    """Row-wise summarize for a batched sweep: ``energies`` is
    (n_configs, n_repeats) from rollout.run_sweep; one summary per
    config row."""
    return [summarize(params, row) for row in np.atleast_2d(np.asarray(energies))]
