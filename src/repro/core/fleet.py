"""Fleet-scale control plane (beyond-paper; DESIGN.md §7).

The paper runs one controller per GPU on one node. At Aurora scale that
is 10,620 nodes x 6 GPUs = 63,720 controllers; at TPU-pod scale, one per
chip. The episode loops (independent vmapped controllers, and the
coordinated gang that shares one controller across a synchronous
data-parallel job) live in the unified rollout engine
(repro.core.rollout.RolloutSpec); this module re-exports
``run_fleet_episode`` and owns the step-at-a-time control plane:

- :class:`Fleet` holds struct-of-arrays controller state for N nodes and
  advances the whole fleet per decision interval. ``step`` is the real
  deployment path: at each interval boundary it applies the previous
  interval's observations (update) and picks every node's next arm
  (select) in ONE fused Pallas launch (kernels/fleet_ucb.fleet_step)
  when the policy is kernel-compatible — which, since the nonstationary
  lanes landed, is the whole EnergyUCB family: QoS budgets
  (``qos_delta``/``default_arm`` lanes, sentinel ``qos_delta < 0`` =
  unconstrained), sliding-window discounting (``gamma`` lane, sentinel
  ``>= 1`` = stationary), and the round-robin warm-up ablation
  (``optimistic`` lane, sentinel ``>= 0.5`` = optimistic init) — falling
  back to vmapped policy fns for non-UCB families. Hyperparameters are
  per-controller data, so a fleet can sweep alpha x lambda (and mix QoS
  budgets, window discounts, and warm-up variants) across its own
  nodes in one launch. Factored (core x uncore) ladders
  (policies.factored_energy_ucb) are part of the family: the policy's
  static ``k_unc`` rides kernel dispatch (``Fleet.k_unc``) and the
  ``lam_unc`` per-controller lane prices uncore moves (sentinel < 0 =
  one shared penalty), over the SAME flat (N, K) state at
  ``K = k_core * k_unc``. Fleets beyond one chip's VMEM pass ``mesh=``
  to shard the (N, K) state over the mesh's data axis
  (repro.parallel.fleet.make_sharded_fleet_step).

repro-lint holds this module to the lane contract (RPL003: every
``PolicyParams`` field registered in repro/analysis/lanes.py must be
classified by ``_params_axes``, sliced by ``slice_policy_lanes``, and
forwarded by ``Fleet.step``/``episode_trace``/``episode_sim``) and to
scatter-free parity arithmetic (RPL001).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policies import (
    UCB_FNS,
    Policy,
    PolicyParams,
    ucb_family_k_unc,
)
from repro.core.rollout import _row_where, run_fleet_episode  # noqa: F401
from repro.core.simulator import Obs
from repro.kernels import ops

PyTree = Any


def kernel_compatible(policy: Policy) -> bool:
    """True when the fused SA-UCB kernel implements this policy exactly.
    Since the nonstationary lanes landed that is the ENTIRE EnergyUCB
    family: QoS budgets (sentinel ``qos_delta < 0`` = unconstrained),
    sliding windows (sentinel ``gamma >= 1`` = stationary, discounting
    reward and progress statistics and shrinking stale means to the
    prior at select time), and the round-robin warm-up ablation
    (sentinel ``optimistic >= 0.5`` = optimistic init) all ride as
    kernel lanes, so mixed fleets share one launch. Every hyperparameter
    may be scalar or a per-controller (N,) lane (``prior_mu`` is (K,)
    per arm, or (N, K) per node); only non-UCB function sets — and
    config-stacked params with extra batch axes — take the vmapped
    path. Factored ladders (policies.factored_ucb_fns) are in the
    family too: their static ``k_unc`` becomes a kernel shape static
    and ``lam_unc`` rides as one more per-controller lane."""
    if ucb_family_k_unc(policy.fns) is None:
        return False
    p: PolicyParams = policy.params
    return all(
        jnp.ndim(leaf) <= (2 if name == "prior_mu" else 1)
        for name, leaf in zip(p._fields, p)
    )


def slice_policy_lanes(policy: Policy, lo: int, hi: int, n: int) -> Policy:
    """The stripe [lo, hi) of a policy whose hyperparameters carry
    per-controller (N,) lanes — the policy-side half of striping a fleet
    across controller processes (repro.parallel.distributed). Exactly
    the leaves :func:`_params_axes` vmaps over the node axis slice
    rowwise (the classification lives there, once); scalars and the
    (K,) prior_mu pass through, so a host's stripe Fleet sees the same
    lane values the full fleet's rows [lo:hi) would. Non-EnergyUCB
    params have no node lanes and return unchanged."""
    axes = _params_axes(policy, n)
    if axes is None:
        return policy
    p = policy.params
    return policy.with_params(type(p)(
        *(leaf[lo:hi] if ax == 0 else leaf for leaf, ax in zip(p, axes))
    ))


def _params_axes(policy: Policy, n: int):
    """vmap in_axes for the params pytree: per-controller (N,) lanes of
    alpha/lam/qos_delta/gamma/optimistic/default_arm map over axis 0,
    everything else broadcasts. Only the EnergyUCB family supports
    per-node lanes (prior_mu is (K,) per-arm, never confused with a
    node axis; a (N, K) prior maps rowwise)."""
    p = policy.params
    if not isinstance(p, PolicyParams):
        return None
    ax = lambda leaf: 0 if (jnp.ndim(leaf) == 1 and leaf.shape[0] == n) else None
    return PolicyParams(
        alpha=ax(p.alpha), lam=ax(p.lam), qos_delta=ax(p.qos_delta),
        gamma=ax(p.gamma), optimistic=ax(p.optimistic),
        prior_mu=0 if jnp.ndim(p.prior_mu) == 2 else None,
        prior_n=ax(p.prior_n), default_arm=ax(p.default_arm),
        lam_unc=ax(p.lam_unc),
    )


@functools.lru_cache(maxsize=None)
def _vmapped_fns(fns, pax):
    """Module-level cache so every Fleet over the same function set (and
    params-axes layout) shares one set of jitted vmapped callables — and
    therefore one trace per shape signature across instances."""
    return (
        jax.jit(jax.vmap(fns.init, in_axes=(pax, 0))),
        jax.jit(jax.vmap(fns.select, in_axes=(pax, 0, 0))),
        jax.jit(jax.vmap(fns.update, in_axes=(pax, 0, 0, 0))),
    )


class Fleet:
    """N independent controllers, advanced in lockstep.

    ``init/select/update`` are the vmapped policy fns (params passed as
    data, so every Fleet shares one trace per function set). ``step`` is
    the fused per-interval path; it dispatches to the Pallas kernel when
    the policy is kernel-compatible and a TPU is present (or
    ``interpret=True`` forces the kernel in interpret mode, which the
    parity tests use).
    """

    def __init__(self, policy: Policy, n: int, use_kernel: Optional[bool] = None,
                 interpret: bool = False, mesh=None, mesh_axis: str = "data"):
        self.policy = policy
        self.n = n
        self.interpret = interpret
        self.k_unc = ucb_family_k_unc(policy.fns) or 1
        self._init, self._select, self._update = _vmapped_fns(
            policy.fns, _params_axes(policy, n)
        )
        if use_kernel is None:
            use_kernel = kernel_compatible(policy) and (
                ops.pallas_available() or interpret
            )
        elif use_kernel and not kernel_compatible(policy):
            raise ValueError(
                f"policy {policy.name!r} is not kernel-exact "
                "(non-UCB families and config-stacked params must use "
                "the vmapped path)"
            )
        self.use_kernel = use_kernel
        self._sharded_step = None
        if mesh is not None:
            # fleets beyond one chip's VMEM: shard the (N, K) controller
            # state over the mesh's data axis (pure row parallelism)
            if not self.use_kernel:
                reason = (
                    "the policy is not kernel-exact"
                    if not kernel_compatible(policy)
                    else "no TPU is present (pass interpret=True to force "
                         "interpret mode)"
                )
                raise ValueError(
                    f"sharded fleet state requires the fused kernel path, "
                    f"but {reason}"
                )
            from repro.parallel.fleet import make_sharded_fleet_step

            self._sharded_step = make_sharded_fleet_step(
                mesh, axis=mesh_axis, interpret=interpret, k_unc=self.k_unc
            )

    @property
    def params(self) -> PyTree:
        return self.policy.params

    def init(self, key) -> PyTree:
        return self._init(self.params, jax.random.split(key, self.n))

    def select(self, states: PyTree, key) -> jax.Array:
        return self._select(self.params, states, jax.random.split(key, self.n))

    def update(self, states: PyTree, arms: jax.Array, obs: Obs) -> PyTree:
        return self._update(self.params, states, arms, obs)

    def step(
        self, states: PyTree, arms: jax.Array, obs: Obs, key=None
    ) -> Tuple[PyTree, jax.Array]:
        """One decision interval for the whole fleet: fold in the
        observations each node collected running ``arms`` (frozen where
        the node's job finished), then select every node's next arm.
        Returns (new_states, next_arms)."""
        if self.use_kernel:
            p: PolicyParams = self.params
            step_fn = (self._sharded_step if self._sharded_step is not None
                       else functools.partial(ops.fleet_step,
                                              k_unc=self.k_unc,
                                              interpret=self.interpret))
            mu, n, phat, pn, prev, t, nxt = step_fn(
                states["mu"], states["n"], states["phat"], states["pn"],
                states["prev"], states["t"], arms, obs.reward, obs.progress,
                obs.active, p.alpha, p.lam, p.qos_delta, p.default_arm,
                p.gamma, p.optimistic, p.prior_mu, p.lam_unc,
            )
            return (
                {"mu": mu, "n": n, "phat": phat, "pn": pn, "prev": prev, "t": t},
                nxt,
            )
        if key is None:
            # a fixed default key would freeze the explore/exploit draws
            # of stochastic policies across every interval
            raise ValueError(
                "Fleet.step needs a per-interval key on the vmapped path "
                "(only the fused UCB kernel is key-free)"
            )
        updated = self._update(self.params, states, arms, obs)
        states = _row_where(obs.active, updated, states)
        return states, self._select(self.params, states,
                                    jax.random.split(key, self.n))

    # -- episode scan: T intervals per dispatch ------------------------
    def _episode_eligible(self) -> None:
        if not kernel_compatible(self.policy):
            raise ValueError(
                f"policy {self.policy.name!r} is not kernel-exact; the "
                "episode scan only covers the fused-UCB family (stream "
                "interval by interval instead)"
            )
        if self._sharded_step is not None:
            raise ValueError(
                "mesh-sharded fleets stream interval by interval (the "
                "episode scan does not shard its T-axis grid yet)"
            )

    def episode_trace(self, states: PyTree, arm: jax.Array,
                      reward, progress, active):
        """T fused decision intervals in ONE dispatch, observations
        precomputed as (T, N) columns (kernels.episode_scan trace-fed
        mode; Pallas megakernel on TPU / interpret, XLA lax.scan over
        the same math elsewhere). NOTE: ``states`` may be donated —
        callers replace their state with the returned one. Returns
        ``(new_states, next_arm, arms_run)``."""
        self._episode_eligible()
        p: PolicyParams = self.params
        (mu, n, phat, pn, prev, t, nxt), arms = ops.episode_scan_trace(
            states["mu"], states["n"], states["phat"], states["pn"],
            states["prev"], states["t"], arm, reward, progress, active,
            p.alpha, p.lam, p.qos_delta, p.default_arm, p.gamma,
            p.optimistic, p.prior_mu, p.lam_unc, k_unc=self.k_unc,
            interpret=self.interpret,
        )
        return (
            {"mu": mu, "n": n, "phat": phat, "pn": pn, "prev": prev, "t": t},
            nxt, arms,
        )

    def episode_sim(self, states: PyTree, arm: jax.Array, env_rows, z,
                    scan_env, *, t_start: int = 0, drift_every: int = 0,
                    counter_obs: bool = True):
        """T fused env+controller intervals in ONE dispatch — the
        sim-fused episode scan over a SimBackend-style environment
        (``env_rows``/``z``/``scan_env`` from the backend's episode
        surface). Same donation caveat as :meth:`episode_trace`.
        Returns ``(new_states, next_arm, env_rows, arms_run)``."""
        self._episode_eligible()
        p: PolicyParams = self.params
        (mu, n, phat, pn, prev, t, nxt), env2, arms = ops.episode_scan_sim(
            states["mu"], states["n"], states["phat"], states["pn"],
            states["prev"], states["t"], arm, env_rows, z, scan_env,
            p.alpha, p.lam, p.qos_delta, p.default_arm, p.gamma,
            p.optimistic, p.prior_mu, p.lam_unc, k_unc=self.k_unc,
            t_start=t_start,
            drift_every=drift_every, counter_obs=counter_obs,
            interpret=self.interpret,
        )
        return (
            {"mu": mu, "n": n, "phat": phat, "pn": pn, "prev": prev, "t": t},
            nxt, env2, arms,
        )
