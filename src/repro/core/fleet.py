"""Fleet-scale control plane (beyond-paper; DESIGN.md §7).

The paper runs one controller per GPU on one node. At Aurora scale that
is 10,620 nodes x 6 GPUs = 63,720 controllers; at TPU-pod scale, one per
chip. Two modes:

- independent: vmap'ed per-node controllers (exactly the paper's
  semantics, batched). State is a struct-of-arrays pytree; one fused
  update advances the whole fleet (see also kernels/fleet_ucb.py for
  the Pallas TPU kernel of the select step).

- coordinated: synchronous data-parallel training couples the fleet —
  the slowest chip gates the step, so per-chip exploration straggles
  everyone. One shared controller acts for the whole gang; per-chip
  rewards are averaged (a pmean inside the step on real hardware),
  which also cuts reward variance by ~1/N.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policies import Policy
from repro.core.simulator import EnvParams, Obs, env_init, env_step

PyTree = Any


class Fleet:
    """N independent controllers, advanced in lockstep via vmap."""

    def __init__(self, policy: Policy, n: int):
        self.policy = policy
        self.n = n
        self._init = jax.jit(jax.vmap(policy.init))
        self._select = jax.jit(jax.vmap(policy.select))
        self._update = jax.jit(jax.vmap(policy.update))

    def init(self, key) -> PyTree:
        return self._init(jax.random.split(key, self.n))

    def select(self, states: PyTree, key) -> jax.Array:
        return self._select(states, jax.random.split(key, self.n))

    def update(self, states: PyTree, arms: jax.Array, obs: Obs) -> PyTree:
        return self._update(states, arms, obs)


def run_fleet_episode(
    policy: Policy,
    params: EnvParams,
    key: jax.Array,
    n_nodes: int,
    max_steps: int,
    coordinated: bool = False,
) -> Dict[str, jax.Array]:
    """Simulate n_nodes identical nodes running the same job.

    independent: each node explores on its own (paper semantics).
    coordinated: one controller; the gang's reward = mean over nodes;
    the *step time* is gated by the slowest node, so with independent
    per-node arms the gang pays max-over-nodes time (straggler effect) —
    this is what the coordinated mode removes.
    """

    def indep(key):
        k0, kr = jax.random.split(key)
        pstates = jax.vmap(policy.init)(jax.random.split(k0, n_nodes))
        estates = jax.vmap(lambda _: env_init(params))(jnp.arange(n_nodes))

        def step(carry, k):
            pstates, estates, gang_time = carry
            ks = jax.random.split(k, 2 * n_nodes).reshape(2, n_nodes)
            arms = jax.vmap(policy.select)(pstates, ks[0])
            estates2, obs = jax.vmap(lambda e, a, kk: env_step(params, e, a, kk))(
                estates, arms, ks[1]
            )
            pstates2 = jax.vmap(policy.update)(pstates, arms, obs)
            active = obs.active
            sel = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(
                    active.reshape((-1,) + (1,) * (a.ndim - 1)), a, b
                ), new, old,
            )
            pstates = sel(pstates2, pstates)
            estates = sel(estates2, estates)
            # synchronous step: gang advances at the slowest node's pace
            step_t = jnp.where(
                jnp.any(active), jnp.max(params.t_rel[arms] * params.dt_s), 0.0
            )
            return (pstates, estates, gang_time + step_t), None

        (pstates, estates, gang_time), _ = jax.lax.scan(
            step, (pstates, estates, jnp.float32(0.0)),
            jax.random.split(kr, max_steps),
        )
        return {
            "energy_kj": jnp.sum(estates.energy_kj),
            "gang_time_s": gang_time,
            "switches": jnp.sum(estates.switches),
        }

    def coord(key):
        k0, kr = jax.random.split(key)
        pstate = policy.init(k0)
        estates = jax.vmap(lambda _: env_init(params))(jnp.arange(n_nodes))

        def step(carry, k):
            pstate, estates, gang_time = carry
            k_sel, k_env = jax.random.split(k)
            arm = policy.select(pstate, k_sel)
            arms = jnp.full((n_nodes,), arm)
            estates2, obs = jax.vmap(lambda e, a, kk: env_step(params, e, a, kk))(
                estates, arms, jax.random.split(k_env, n_nodes)
            )
            active = obs.active
            # coordinated reward: fleet-mean (pmean on real hardware)
            mean_obs = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32)), obs)
            pstate2 = policy.update(pstate, arm, mean_obs)
            any_active = jnp.any(active)
            pstate = jax.tree.map(
                lambda a, b: jnp.where(any_active, a, b), pstate2, pstate
            )
            estates = jax.tree.map(
                lambda a, b: jnp.where(
                    active.reshape((-1,) + (1,) * (a.ndim - 1)), a, b
                ), estates2, estates,
            )
            step_t = jnp.where(any_active, params.t_rel[arm] * params.dt_s, 0.0)
            return (pstate, estates, gang_time + step_t), None

        (pstate, estates, gang_time), _ = jax.lax.scan(
            step, (pstate, estates, jnp.float32(0.0)),
            jax.random.split(kr, max_steps),
        )
        return {
            "energy_kj": jnp.sum(estates.energy_kj),
            "gang_time_s": gang_time,
            "switches": jnp.sum(estates.switches),
        }

    fn = coord if coordinated else indep
    return jax.jit(fn)(key)
