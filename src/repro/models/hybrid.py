"""Zamba2-style hybrid: a Mamba2 backbone with ONE weight-shared
full-attention block applied every ``attn_every`` layers.
[arXiv:2411.15242]

Simplifications vs. the released checkpoint (recorded in DESIGN.md):
the shared block is a standard pre-norm attention+MLP block on the
current hidden state (Zamba2 additionally concatenates the embedding
stream and applies per-application LoRA deltas). The weight-sharing,
placement cadence, and per-application KV caches are faithful.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayoutConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import (
    _ATTN_AXES,
    _MLP_AXES,
    _attn_shapes,
    _embed,
    _init_from_shapes,
    _mlp_shapes,
    _unembed,
    attn_block,
    mlp_block,
)
from repro.parallel.sharding import Sharder

PyTree = Any


def n_attn_applications(cfg: ArchConfig) -> int:
    return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every


def hybrid_init(cfg: ArchConfig, layout: LayoutConfig, key) -> PyTree:
    dtype = jnp.dtype(layout.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    base = S.ssm_init(cfg, layout, k1)
    shared_shapes = _attn_shapes(cfg, 1, dtype) | _mlp_shapes(cfg, 1, cfg.d_ff, dtype)
    shared = _init_from_shapes(k2, shared_shapes)
    base["shared_attn"] = {k: v[0] for k, v in shared.items()}  # unstacked
    return base


def hybrid_logical_axes(cfg: ArchConfig) -> PyTree:
    ax = S.ssm_logical_axes(cfg)
    shared = {**_ATTN_AXES, **_MLP_AXES}
    ax["shared_attn"] = {k: tuple(v[1:]) for k, v in shared.items()}  # drop "layers"
    return ax


def hybrid_cache_zero(cfg: ArchConfig, batch_size: int, cache_len: int):
    na = n_attn_applications(cfg)
    kv = jnp.zeros((na, batch_size, cache_len, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16)
    return {"ssm": S.ssm_state_zero(cfg, batch_size), "k": kv, "v": kv}


def hybrid_cache_logical_axes(cfg, layout):
    per = {
        "hd": ("cache_batch", None, None, "head_dim"),
        "heads": ("cache_batch", None, "heads", None),
        "seq": ("cache_batch", "seq", None, None),
    }[layout.kv_cache_shard]
    return {
        "ssm": S.ssm_cache_logical_axes(cfg, layout),
        "k": ("layers",) + per,
        "v": ("layers",) + per,
    }


def _hybrid_stack(cfg, layout, sharder, params, x, *, mode, cache=None,
                  cache_index=None, positions=None):
    na = n_attn_applications(cfg)
    shared_w = params["shared_attn"]

    def body(carry, xs):
        x, kcache, vcache, i = carry
        w, ssm_st = xs

        def with_attn(args):
            x, kc, vc = args
            j = i // cfg.attn_every
            if mode == "decode":
                ck = jax.lax.dynamic_index_in_dim(kc, j, axis=0, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(vc, j, axis=0, keepdims=False)
                xo, (nk, nv) = attn_block(
                    cfg, layout, sharder, shared_w, x, positions,
                    mode="decode", cache=(ck, cv), cache_index=cache_index,
                )
                kc = jax.lax.dynamic_update_index_in_dim(kc, nk, j, axis=0)
                vc = jax.lax.dynamic_update_index_in_dim(vc, nv, j, axis=0)
            else:
                xo, new = attn_block(
                    cfg, layout, sharder, shared_w, x, positions, mode=mode
                )
                if mode == "prefill":
                    kc = jax.lax.dynamic_update_index_in_dim(
                        kc, new[0].astype(kc.dtype), j, axis=0
                    )
                    vc = jax.lax.dynamic_update_index_in_dim(
                        vc, new[1].astype(vc.dtype), j, axis=0
                    )
            xo = mlp_block(cfg, layout, sharder, shared_w, xo)
            return xo, kc, vc

        x, kcache, vcache = jax.lax.cond(
            i % cfg.attn_every == 0, with_attn, lambda a: a, (x, kcache, vcache)
        )
        st = None if mode != "decode" else ssm_st
        x, new_ssm = S.mamba2_block(cfg, sharder, w, x, mode=mode, state=st)
        return (x, kcache, vcache, i + 1), new_ssm

    body = L.remat_wrap(body, layout.remat)
    if cache is None:
        if mode == "train":
            # dummy loop-invariant carries (never read)
            kcache = vcache = jnp.zeros((), jnp.bfloat16)
        else:
            seq = x.shape[1]
            kcache = jnp.zeros(
                (na, x.shape[0], seq, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16
            )
            vcache = kcache
        ssm_xs = None
    else:
        kcache, vcache = cache["k"], cache["v"]
        ssm_xs = (
            (cache["ssm"][0].astype(jnp.bfloat16), cache["ssm"][1])
            if mode == "decode" else None
        )
    (x, kcache, vcache, _), ssm_states = jax.lax.scan(
        body, (x, kcache, vcache, jnp.int32(0)), (params["layers"], ssm_xs)
    )
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"ssm": ssm_states, "k": kcache, "v": vcache}
    return x, new_cache


def hybrid_loss(cfg, layout, sharder, params, batch):
    x = _embed(cfg, params, batch["tokens"], sharder)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x, _ = _hybrid_stack(cfg, layout, sharder, params, x, mode="train",
                         positions=positions)
    logits = _unembed(cfg, layout, params, x, sharder)
    return L.softmax_xent(logits, batch["labels"])


def hybrid_prefill(cfg, layout, sharder, params, batch):
    x = _embed(cfg, params, batch["tokens"], sharder)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x, cache = _hybrid_stack(cfg, layout, sharder, params, x, mode="prefill",
                             positions=positions)
    logits = _unembed(cfg, layout, params, x[:, -1:], sharder)
    return logits[:, 0], cache


def hybrid_decode(cfg, layout, sharder, params, cache, batch):
    token, index = batch["token"], batch["index"]
    x = _embed(cfg, params, token[:, None], sharder)
    positions = jnp.full((x.shape[0], 1), index, jnp.int32)
    x, new_cache = _hybrid_stack(
        cfg, layout, sharder, params, x, mode="decode", cache=cache,
        cache_index=index, positions=positions,
    )
    logits = _unembed(cfg, layout, params, x, sharder)
    return logits[:, 0], new_cache
