"""Shared layer primitives: norms, RoPE, attention (dense + chunked-flash
XLA paths + Pallas dispatch), MLPs, embeddings.

Attention shapes: q (B, Sq, H, HD); k, v (B, Skv, KV, HD); GQA via
H = KV * G. The chunked path is an online-softmax scan over KV blocks —
the XLA-everywhere equivalent of flash attention (no S^2 buffer) used by
the dry-run; on real TPU the Pallas kernel (repro.kernels) takes over.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with a hand-written VJP that keeps x in ITS OWN dtype on
    both passes: stock AD consumes an f32 upcast of x in the backward,
    and XLA's float-normalization then stores the scan-AD checkpoint
    stack in f32 — a +31.5 GB image of the whole residual stream on the
    405B train cell (measured; EXPERIMENTS.md §Perf). All reductions
    still accumulate in f32; only elementwise math stays in x.dtype."""
    return _rms_fwd(x, scale, eps)[0]


def full_rank(v, ndim):
    # explicit trailing-axes broadcast: the test suite runs with
    # jax_numpy_rank_promotion="raise", so a (D,) param never broadcasts
    # implicitly against a (..., D) activation
    return v.reshape((1,) * (ndim - v.ndim) + v.shape)


def _rms_inv(x, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return jax.lax.rsqrt(var + eps)


def _rms_fwd(x, scale, eps):
    inv = _rms_inv(x, eps)
    out = x * inv.astype(x.dtype) * full_rank(scale.astype(x.dtype), x.ndim)
    return out, (x, scale)


def _rms_bwd(eps, res, g):
    x, scale = res
    inv = _rms_inv(x, eps).astype(x.dtype)  # cheap recompute, (…,1)
    xhat = x * inv
    red_axes = tuple(range(x.ndim - len(scale.shape)))
    dscale = jnp.sum(
        (g * xhat).astype(jnp.float32), axis=red_axes
    ).astype(scale.dtype).reshape(scale.shape)
    gs = g * full_rank(scale.astype(g.dtype), g.ndim)
    m = jnp.mean(
        (gs * xhat).astype(jnp.float32), axis=-1, keepdims=True
    ).astype(x.dtype)
    dx = inv * (gs - xhat * m)
    return dx.astype(x.dtype), dscale


rms_norm.defvjp(_rms_fwd, _rms_bwd)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * full_rank(freqs, 3)  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    scale: float,
    q_offset=0,
    kv_valid_len: Optional[jax.Array] = None,
    logits_dtype=jnp.float32,
) -> jax.Array:
    b, sq, h, hd = q.shape
    _, skv, kv, _ = k.shape
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=logits_dtype
    ).astype(jnp.float32) * scale
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        qi = jnp.arange(sq) + q_offset
        mask = qi[:, None] >= jnp.arange(skv)[None, :]
    if kv_valid_len is not None:
        valid = jnp.arange(skv)[None, :] < kv_valid_len[:, None]  # (B, Skv)
        logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


def _chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    scale: float,
    chunk_kv: int,
    q_offset=0,
) -> jax.Array:
    """Online-softmax scan over KV blocks; O(S * chunk) memory."""
    b, sq, h, hd = q.shape
    _, skv, kv, _ = k.shape
    g = h // kv
    cb = min(chunk_kv, skv)
    nb = skv // cb
    assert skv % cb == 0, f"kv len {skv} not divisible by chunk {cb}"
    qg = q.reshape(b, sq, kv, g, hd)
    q_idx = jnp.arange(sq) + q_offset

    def block(carry, i):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, i * cb, cb, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * cb, cb, axis=1)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, ks).astype(jnp.float32) * scale
        if causal:
            kv_idx = i * cb + jnp.arange(cb)
            mask = q_idx[:, None] >= kv_idx[None, :]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None].astype(acc.dtype) + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v.dtype), vs
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, hd), v.dtype)
    (m, l, acc), _ = jax.lax.scan(block, (m0, l0, a0), jnp.arange(nb))
    l = jnp.maximum(l, 1e-20)
    out = acc / l[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    impl: str = "chunked",
    chunk_kv: int = 512,
    chunk_q: int = 0,
    q_offset=0,
    kv_valid_len: Optional[jax.Array] = None,
    logits_dtype=jnp.float32,
) -> jax.Array:
    scale = 1.0 / (q.shape[-1] ** 0.5)
    if impl == "pallas":
        from repro.kernels import ops as kops

        if kops.pallas_available() and causal and kv_valid_len is None:
            return kops.flash_attention(q, k, v, causal=True)
        impl = "chunked"
    if impl == "dense" or q.shape[1] == 1 or kv_valid_len is not None:
        return _dense_attention(
            q, k, v, causal=causal, scale=scale, q_offset=q_offset,
            kv_valid_len=kv_valid_len, logits_dtype=logits_dtype,
        )
    if chunk_q and q.shape[1] > chunk_q:
        b, sq, h, hd = q.shape
        nq = sq // chunk_q
        assert sq % chunk_q == 0

        def one(i):
            qs = jax.lax.dynamic_slice_in_dim(q, i * chunk_q, chunk_q, axis=1)
            return _chunked_attention(
                qs, k, v, causal=causal, scale=scale, chunk_kv=chunk_kv,
                q_offset=q_offset + i * chunk_q,
            )

        out = jax.lax.map(one, jnp.arange(nq))  # (nq, B, cq, H, HD)
        return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)
    return _chunked_attention(
        q, k, v, causal=causal, scale=scale, chunk_kv=chunk_kv, q_offset=q_offset
    )


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------


def mlp_gated(x, w1, w3, w2):
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, w1)) * jnp.einsum(
        "...d,df->...f", x, w3
    )
    return jnp.einsum("...f,fd->...d", h, w2)


def mlp_classic(x, w1, w2):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w1))
    return jnp.einsum("...f,fd->...d", h, w2)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_xent(
    logits: jax.Array, labels: jax.Array, z_loss: float = 0.0
) -> jax.Array:
    """Cross-entropy with label mask (labels < 0 ignored); one-hot dot so a
    vocab-sharded logits tensor never round-trips through a gather."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1], dtype=jnp.float32)
    ll = jnp.sum(logits * onehot, axis=-1)
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    w = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def trunc_normal(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    """Embedding/unembedding table init, std 1/sqrt(D): O(1) logits when
    tied (and when untied, since the contraction is over D either way)."""
    std = 1.0 / (d_model ** 0.5)
    return (
        jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d_model), jnp.float32)
        * std
    ).astype(dtype)


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.remat(fn)
    if policy == "dots":
        return jax.remat(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    raise ValueError(f"unknown remat policy {policy}")
