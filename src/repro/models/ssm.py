"""Mamba2 (SSD — state-space duality) blocks and LM. [arXiv:2405.21060]

The chunked SSD algorithm: within-chunk quadratic term (a Q x Q masked
decay kernel per head) + inter-chunk state recurrence carried by a
lax.scan over chunks. The same core serves training, prefill (returns
final states), and single-token decode (constant-size state), which is
what makes mamba2/zamba2 the two long_500k-capable archs.

Sharding: SSD heads -> "model" (TP); B/C projections are per-group
(g=1) and replicated across head shards.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayoutConfig
from repro.models import layers as L
from repro.parallel.sharding import Sharder

PyTree = Any


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def ssm_block_shapes(cfg: ArchConfig, n: int, dtype):
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = DI + 2 * N
    return {
        "ln": ((n, D), dtype),
        "in_proj": ((n, D, 2 * DI + 2 * N + H), dtype),
        "conv_w": ((n, cfg.ssm_conv, conv_ch), dtype),
        "conv_b": ((n, conv_ch), dtype),
        "A_log": ((n, H), jnp.float32),
        "D_skip": ((n, H), jnp.float32),
        "dt_bias": ((n, H), jnp.float32),
        "gnorm": ((n, DI), dtype),
        "out_proj": ((n, DI, D), dtype),
    }


SSM_AXES = {
    "ln": ("layers", None),
    "in_proj": ("layers", "embed_fsdp", "tp"),
    "conv_w": ("layers", None, "tp"),
    "conv_b": ("layers", "tp"),
    "A_log": ("layers", "ssm_heads"),
    "D_skip": ("layers", "ssm_heads"),
    "dt_bias": ("layers", "ssm_heads"),
    "gnorm": ("layers", "tp"),
    "out_proj": ("layers", "tp", "embed_fsdp"),
}


def ssm_init(cfg: ArchConfig, layout: LayoutConfig, key) -> PyTree:
    dtype = jnp.dtype(layout.param_dtype)
    D, V = cfg.d_model, cfg.padded_vocab
    shapes = ssm_block_shapes(cfg, cfg.num_layers, dtype)
    ks = jax.random.split(key, len(shapes) + 3)
    layers = {}
    for k_, (name, (shape, dt)) in zip(ks, sorted(shapes.items())):
        if name in ("ln", "gnorm"):
            layers[name] = jnp.ones(shape, dt)
        elif name == "A_log":
            layers[name] = jnp.log(
                jax.random.uniform(k_, shape, jnp.float32, 1.0, 16.0)
            )
        elif name == "dt_bias":
            # softplus^-1 of dt in [1e-3, 1e-1]
            dt0 = jnp.exp(
                jax.random.uniform(k_, shape, jnp.float32)
                * (jnp.log(1e-1) - jnp.log(1e-3))
                + jnp.log(1e-3)
            )
            layers[name] = dt0 + jnp.log(-jnp.expm1(-dt0))
        elif name == "D_skip":
            layers[name] = jnp.ones(shape, jnp.float32)
        elif name == "conv_b":
            layers[name] = jnp.zeros(shape, dt)
        else:
            layers[name] = L.trunc_normal(k_, shape, dt)
    return {
        "emb": L.embed_init(ks[-1], V, D, dtype),
        "unemb": L.embed_init(ks[-2], V, D, dtype),
        "final_norm": jnp.ones((D,), dtype),
        "layers": layers,
    }


def ssm_logical_axes(cfg: ArchConfig) -> PyTree:
    return {
        "emb": ("vocab", "embed_fsdp"),
        "unemb": ("vocab", "embed_fsdp"),
        "final_norm": (None,),
        "layers": dict(SSM_AXES),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., Q) -> (..., Q, Q); out[i,j] = sum_{j<k<=i} x_k, -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    xh: jax.Array,  # (B, S, H, P) post-conv inputs
    dt: jax.Array,  # (B, S, H) fp32, post-softplus
    A: jax.Array,  # (H,) fp32, negative
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N) fp32
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} % chunk {Q} != 0"
    NC = S // Q
    dtype = xh.dtype

    xd = (xh.astype(jnp.float32) * dt[..., None]).astype(dtype)  # (B,S,H,P)
    dA = dt * L.full_rank(A, dt.ndim)  # (B,S,H) fp32, negative

    rc = lambda t: t.reshape(Bsz, NC, Q, *t.shape[2:])
    xc, dAc, Bc, Cc = rc(xd), rc(dA), rc(Bm), rc(Cm)

    dA_h = dAc.transpose(0, 1, 3, 2)  # (B,NC,H,Q)
    cs = jnp.cumsum(dA_h, axis=-1)  # (B,NC,H,Q)
    Ldec = jnp.exp(_segsum(dA_h)).astype(dtype)  # (B,NC,H,Q,Q)

    # intra-chunk (diagonal blocks)
    G = jnp.einsum("bcin,bcjn->bcij", Cc.astype(dtype), Bc.astype(dtype))
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", G, Ldec, xc)

    # chunk -> end-of-chunk states
    decay_states = jnp.exp(cs[:, :, :, -1:] - cs)  # (B,NC,H,Q)
    states = jnp.einsum(
        "bcjn,bchj,bcjhp->bchpn",
        Bc.astype(jnp.float32),
        decay_states,
        xc.astype(jnp.float32),
    )  # fp32 (B,NC,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cs[:, :, :, -1])  # (B,NC,H)
    s0 = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(s, inp):
        st_c, dec = inp  # (B,H,P,N), (B,H)
        s_new = s * dec[..., None, None] + st_c
        return s_new, s  # emit state *entering* the chunk

    final_state, prev_states = jax.lax.scan(
        step,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,NC,H,P,N)

    state_decay_out = jnp.exp(cs).transpose(0, 1, 3, 2)  # (B,NC,Q,H)
    y_off = jnp.einsum(
        "bcin,bchpn,bcih->bcihp",
        Cc.astype(jnp.float32),
        prev_states,
        state_decay_out.astype(jnp.float32),
    ).astype(dtype)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final_state


def ssd_decode_step(
    x_t: jax.Array,  # (B, H, P) post-conv single token
    dt_t: jax.Array,  # (B, H) fp32
    A: jax.Array,  # (H,)
    B_t: jax.Array,  # (B, N)
    C_t: jax.Array,  # (B, N)
    state: jax.Array,  # (B, H, P, N) fp32
) -> Tuple[jax.Array, jax.Array]:
    dA = jnp.exp(dt_t * L.full_rank(A, dt_t.ndim))  # (B,H)
    xd = x_t.astype(jnp.float32) * dt_t[..., None]  # (B,H,P)
    new_state = state * dA[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xd, B_t.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# mamba2 block (conv + ssd + gated norm)
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,CH); w: (K,CH)."""
    K = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(K):
        shift = K - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * L.full_rank(w[i], xi.ndim)
    return jax.nn.silu(out + L.full_rank(b, out.ndim))


def mamba2_block(
    cfg: ArchConfig,
    sharder: Sharder,
    w: Dict[str, jax.Array],
    x: jax.Array,  # (B,S,D)
    *,
    mode: str = "train",
    state: Optional[Tuple[jax.Array, jax.Array]] = None,  # (conv_state, ssm_state)
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    B_, S, D = x.shape
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = L.rms_norm(x, w["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", h, w["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [DI, 2 * DI + 2 * N], axis=-1)
    new_state = None
    if mode == "decode":
        conv_state, ssm_state = state
        # roll conv buffer, append xbc_t
        conv_state = jnp.concatenate(
            [conv_state[:, 1:], xbc.astype(conv_state.dtype)], axis=1
        )  # (B,K,CH)
        xbc_t = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", conv_state, w["conv_w"])
            + L.full_rank(w["conv_b"], 2)
        )
        xs, Bm, Cm = jnp.split(xbc_t, [DI, DI + N], axis=-1)
        dt = jax.nn.softplus(
            dt_raw[:, 0].astype(jnp.float32) + L.full_rank(w["dt_bias"], 2)
        )
        A = -jnp.exp(w["A_log"])
        xr = xs.reshape(B_, H, P)
        y, ssm_state = ssd_decode_step(xr, dt, A, Bm, Cm, ssm_state)
        y = y + xr * w["D_skip"].astype(xr.dtype)[None, :, None]
        y = y.reshape(B_, 1, DI)
        new_state = (conv_state, ssm_state)
    else:
        xbc = _causal_conv(xbc, w["conv_w"], w["conv_b"])
        xs, Bm, Cm = jnp.split(xbc, [DI, DI + N], axis=-1)
        xs = sharder.act(xs, "batch", None, "tp")
        dt = jax.nn.softplus(
            dt_raw.astype(jnp.float32) + L.full_rank(w["dt_bias"], dt_raw.ndim)
        )
        A = -jnp.exp(w["A_log"])
        xh = xs.reshape(B_, S, H, P)
        y, final = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
        y = y + xh * w["D_skip"].astype(xh.dtype)[None, None, :, None]
        y = y.reshape(B_, S, DI)
        if mode == "prefill":
            # conv buffer = last K raw (pre-activation) xbc inputs
            K = cfg.ssm_conv
            raw_xbc = proj[..., DI : 2 * DI + 2 * N]
            if S < K:  # short prompt: left-pad with zeros
                raw_xbc = jnp.pad(raw_xbc, ((0, 0), (K - S, 0), (0, 0)))
            conv_state = raw_xbc[:, -K:].astype(jnp.bfloat16)
            new_state = (conv_state, final)
    y = L.rms_norm(
        y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
        w["gnorm"],
        cfg.norm_eps,
    )
    out = x + jnp.einsum("bsk,kd->bsd", y, w["out_proj"])
    return sharder.act(out, "batch", "seq", None), new_state


# ---------------------------------------------------------------------------
# full mamba2 LM
# ---------------------------------------------------------------------------


def ssm_state_zero(cfg: ArchConfig, batch_size: int, dtype=jnp.float32):
    Lz = cfg.num_layers
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return (
        jnp.zeros((Lz, batch_size, cfg.ssm_conv, conv_ch), jnp.bfloat16),
        jnp.zeros(
            (Lz, batch_size, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
    )


def ssm_cache_logical_axes(cfg, layout):
    return (
        ("layers", "cache_batch", None, "tp"),
        ("layers", "cache_batch", "ssm_heads", None, None),
    )


def _ssm_stack(cfg, layout, sharder, params, x, *, mode, state=None):
    def body(carry, xs):
        x = carry
        w, st = xs
        x, new_st = mamba2_block(cfg, sharder, w, x, mode=mode, state=st)
        return x, new_st

    body = L.remat_wrap(body, layout.remat)
    if mode == "decode":
        st = (state[0].astype(jnp.bfloat16), state[1])
        x, new_state = jax.lax.scan(body, x, (params["layers"], st))
    else:
        x, new_state = jax.lax.scan(body, x, (params["layers"], None))
    return x, new_state


def ssm_loss(cfg, layout, sharder, params, batch):
    from repro.models.transformer import _embed, _unembed

    x = _embed(cfg, params, batch["tokens"], sharder)
    x, _ = _ssm_stack(cfg, layout, sharder, params, x, mode="train")
    logits = _unembed(cfg, layout, params, x, sharder)
    return L.softmax_xent(logits, batch["labels"])


def ssm_prefill(cfg, layout, sharder, params, batch):
    from repro.models.transformer import _embed, _unembed

    x = _embed(cfg, params, batch["tokens"], sharder)
    x, cache = _ssm_stack(cfg, layout, sharder, params, x, mode="prefill")
    logits = _unembed(cfg, layout, params, x[:, -1:], sharder)
    return logits[:, 0], cache


def ssm_decode(cfg, layout, sharder, params, cache, batch):
    from repro.models.transformer import _embed, _unembed

    x = _embed(cfg, params, batch["token"][:, None], sharder)
    x, new_cache = _ssm_stack(cfg, layout, sharder, params, x, mode="decode", state=cache)
    logits = _unembed(cfg, layout, params, x, sharder)
    return logits[:, 0], new_cache
