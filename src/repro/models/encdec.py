"""Encoder-decoder backbone (seamless-m4t-large-v2). The speech/vision
frontend is a STUB per the brief: the encoder consumes precomputed frame
embeddings (B, S_enc, D). Decoder = causal self-attn + cross-attn.

Serving: ``prefill`` encodes the frames, precomputes per-layer cross
K/V from the encoder memory, and prefixes the decoder self-attn cache;
``decode`` consumes one target token per step.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayoutConfig
from repro.models import layers as L
from repro.models.transformer import (
    _ATTN_AXES,
    _MLP_AXES,
    _attn_shapes,
    _init_from_shapes,
    _mlp_shapes,
    _project_qkv,
    _unembed,
    attn_block,
    mlp_block,
)
from repro.parallel.sharding import Sharder

PyTree = Any

_CROSS_AXES = {
    "xln": ("layers", None),
    "xwq": ("layers", "embed_fsdp", "tp"),
    "xwk": ("layers", "embed_fsdp", "tp"),
    "xwv": ("layers", "embed_fsdp", "tp"),
    "xwo": ("layers", "tp", "embed_fsdp"),
}


def _cross_shapes(cfg: ArchConfig, n: int, dtype):
    D, H, KV, HD = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "xln": ((n, D), dtype),
        "xwq": ((n, D, H * HD), dtype),
        "xwk": ((n, D, KV * HD), dtype),
        "xwv": ((n, D, KV * HD), dtype),
        "xwo": ((n, H * HD, D), dtype),
    }


def encdec_init(cfg: ArchConfig, layout: LayoutConfig, key) -> PyTree:
    dtype = jnp.dtype(layout.param_dtype)
    D, V = cfg.d_model, cfg.padded_vocab
    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc = _init_from_shapes(
        k1, _attn_shapes(cfg, cfg.enc_layers, dtype)
        | _mlp_shapes(cfg, cfg.enc_layers, cfg.d_ff, dtype)
    )
    dec = _init_from_shapes(
        k2, _attn_shapes(cfg, cfg.dec_layers, dtype)
        | _mlp_shapes(cfg, cfg.dec_layers, cfg.d_ff, dtype)
        | _cross_shapes(cfg, cfg.dec_layers, dtype)
    )
    return {
        "emb": L.embed_init(k3, V, D, dtype),
        "unemb": L.embed_init(k4, V, D, dtype),
        "enc_norm": jnp.ones((D,), dtype),
        "final_norm": jnp.ones((D,), dtype),
        "enc_layers": enc,
        "dec_layers": dec,
    }


def encdec_logical_axes(cfg: ArchConfig) -> PyTree:
    return {
        "emb": ("vocab", "embed_fsdp"),
        "unemb": ("vocab", "embed_fsdp"),
        "enc_norm": (None,),
        "final_norm": (None,),
        "enc_layers": {**_ATTN_AXES, **_MLP_AXES},
        "dec_layers": {**_ATTN_AXES, **_MLP_AXES, **_CROSS_AXES},
    }


def _cross_attn_block(cfg, layout, sharder, w, x, memory_kv, positions_q):
    """x: (B,Sd,D); memory_kv: (k,v) each (B,Se,KV,HD)."""
    h = L.rms_norm(x, w["xln"], cfg.norm_eps)
    b, s = h.shape[:2]
    q = jnp.einsum("bsd,dh->bsh", h, w["xwq"]).reshape(
        b, s, cfg.num_heads, cfg.head_dim
    )
    q = sharder.act(q, "batch", None, "heads", None)
    mk, mv = memory_kv
    o = L.attention(
        q, mk, mv, causal=False, impl=layout.attn_impl,
        chunk_kv=min(layout.attn_chunk_kv, mk.shape[1]),
    )
    o = o.reshape(b, s, cfg.num_heads * cfg.head_dim)
    x = x + jnp.einsum("bsh,hd->bsd", o, w["xwo"])
    return sharder.act(x, "batch", "seq", None)


def _memory_kv(cfg, w, memory):
    """Project encoder memory to per-layer cross K/V. memory: (B,Se,D)."""
    b, s = memory.shape[:2]
    mk = jnp.einsum("bsd,dh->bsh", memory, w["xwk"]).reshape(
        b, s, cfg.num_kv_heads, cfg.head_dim
    )
    mv = jnp.einsum("bsd,dh->bsh", memory, w["xwv"]).reshape(
        b, s, cfg.num_kv_heads, cfg.head_dim
    )
    return mk, mv


def _encode(cfg, layout, sharder, params, frames):
    """frames: (B, Se, D) stub embeddings -> encoder memory (B,Se,D)."""
    x = sharder.act(frames.astype(jnp.dtype(layout.param_dtype)), "batch", "seq", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]

    def body(x, w):
        h = L.rms_norm(x, w["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, w, h)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        q = sharder.act(q, "batch", None, "heads", None)
        o = L.attention(
            q, k, v, causal=False, impl=layout.attn_impl,
            chunk_kv=layout.attn_chunk_kv, chunk_q=layout.attn_chunk_q,
        )
        o = o.reshape(x.shape[0], x.shape[1], cfg.num_heads * cfg.head_dim)
        x = x + jnp.einsum("bsh,hd->bsd", o, w["wo"])
        x = sharder.act(x, "batch", "seq", None)
        x = mlp_block(cfg, layout, sharder, w, x)
        return x, None

    body = L.remat_wrap(body, layout.remat)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_stack(cfg, layout, sharder, params, x, memory, *, mode,
                   cache=None, cache_index=None, positions=None):
    """memory: (B,Se,D) for train/prefill; cache carries (self_k, self_v,
    cross_k, cross_v) stacks at decode."""

    def body(carry, xs):
        x, cache_index = carry
        if mode == "decode":
            w, (ck, cv, mk, mv) = xs
            x, (nk, nv) = attn_block(cfg, layout, sharder, w, x, positions,
                                     mode="decode", cache=(ck, cv),
                                     cache_index=cache_index)
            x = _cross_attn_block(cfg, layout, sharder, w, x, (mk, mv), positions)
            x = mlp_block(cfg, layout, sharder, w, x)
            return (x, cache_index), (nk, nv)
        w = xs
        x, kv = attn_block(cfg, layout, sharder, w, x, positions, mode=mode)
        memory_kv = _memory_kv(cfg, w, memory)
        x = _cross_attn_block(cfg, layout, sharder, w, x, memory_kv, positions)
        x = mlp_block(cfg, layout, sharder, w, x)
        out = (kv, memory_kv) if mode == "prefill" else None
        return (x, cache_index), out

    body = L.remat_wrap(body, layout.remat)
    xs = (params["dec_layers"], cache) if mode == "decode" else params["dec_layers"]
    (x, _), ys = jax.lax.scan(body, (x, cache_index), xs)
    return x, ys


def encdec_loss(cfg, layout, sharder, params, batch):
    memory = _encode(cfg, layout, sharder, params, batch["frames"])
    x = jnp.take(params["emb"], batch["tokens"], axis=0)
    x = sharder.act(x, "batch", "seq", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x, _ = _decoder_stack(cfg, layout, sharder, params, x, memory,
                          mode="train", positions=positions)
    logits = _unembed(cfg, layout, params, x, sharder)
    return L.softmax_xent(logits, batch["labels"])


def encdec_prefill(cfg, layout, sharder, params, batch):
    memory = _encode(cfg, layout, sharder, params, batch["frames"])
    x = jnp.take(params["emb"], batch["tokens"], axis=0)
    x = sharder.act(x, "batch", "seq", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x, ys = _decoder_stack(cfg, layout, sharder, params, x, memory,
                           mode="prefill", positions=positions)
    (k, v), (mk, mv) = ys
    logits = _unembed(cfg, layout, params, x[:, -1:], sharder)
    return logits[:, 0], (k, v, mk, mv)


def encdec_decode(cfg, layout, sharder, params, cache, batch):
    token, index = batch["token"], batch["index"]
    x = jnp.take(params["emb"], token[:, None], axis=0)
    positions = jnp.full((x.shape[0], 1), index, jnp.int32)
    (ck, cv, mk, mv) = cache
    x, new_kv = _decoder_stack(
        cfg, layout, sharder, params, x, None, mode="decode",
        cache=(ck, cv, mk, mv), cache_index=index, positions=positions,
    )
    logits = _unembed(cfg, layout, params, x, sharder)
    return logits[:, 0], (new_kv[0], new_kv[1], mk, mv)


def encdec_cache_zero(cfg: ArchConfig, batch_size: int, cache_len: int):
    KV, HD, Ld = cfg.num_kv_heads, cfg.head_dim, cfg.dec_layers
    Se = cfg.decode_enc_len
    z = lambda s: jnp.zeros((Ld, batch_size, s, KV, HD), jnp.bfloat16)
    return (z(cache_len), z(cache_len), z(Se), z(Se))


def encdec_cache_logical_axes(cfg, layout):
    per = {
        "hd": ("cache_batch", None, None, "head_dim"),
        "heads": ("cache_batch", None, "heads", None),
        "seq": ("cache_batch", "seq", None, None),
    }[layout.kv_cache_shard]
    one = ("layers",) + per
    return (one, one, one, one)
