"""Unified model API: ``build_model(cfg, layout, sharder)`` returns a
ModelBundle of pure functions shared by the trainer, the serving engine,
and the dry-run launcher.

Batch formats
  train   : {"tokens": (B,S) i32, "labels": (B,S) i32}
            vlm adds {"img_emb": (B,P,D)}; encdec swaps in
            {"frames": (B,Se,D)} and tokens/labels are decoder-side.
  prefill : same minus labels -> (last_logits, cache)
  decode  : {"token": (B,) i32, "index": () i32} -> (logits, cache)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayoutConfig
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import ssm as SM
from repro.models import transformer as TF
from repro.parallel.sharding import Sharder

PyTree = Any


@dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    layout: LayoutConfig
    init: Callable[[jax.Array], PyTree]
    loss: Callable[[PyTree, PyTree], jax.Array]
    prefill: Callable[[PyTree, PyTree], Any]
    decode: Callable[[PyTree, PyTree, PyTree], Any]
    init_cache: Callable[[int, int], PyTree]
    logical_axes: Callable[[], PyTree]
    cache_logical_axes: Callable[[], PyTree]


def build_model(
    cfg: ArchConfig,
    layout: Optional[LayoutConfig] = None,
    sharder: Optional[Sharder] = None,
) -> ModelBundle:
    layout = layout or cfg.layout
    sharder = sharder or Sharder(None, seq_parallel=layout.seq_parallel)

    if cfg.family in ("dense", "moe", "vlm"):
        init = functools.partial(TF.transformer_init, cfg, layout)
        loss = functools.partial(TF.transformer_loss, cfg, layout, sharder)
        prefill = functools.partial(TF.transformer_prefill, cfg, layout, sharder)
        decode = functools.partial(TF.transformer_decode, cfg, layout, sharder)
        init_cache = functools.partial(TF._cache_zero, cfg, layout)
        log_ax = functools.partial(TF.transformer_logical_axes, cfg)
        cache_ax = functools.partial(TF.cache_logical_axes, cfg, layout)
    elif cfg.family == "ssm":
        init = functools.partial(SM.ssm_init, cfg, layout)
        loss = functools.partial(SM.ssm_loss, cfg, layout, sharder)
        prefill = functools.partial(SM.ssm_prefill, cfg, layout, sharder)
        decode = functools.partial(SM.ssm_decode, cfg, layout, sharder)
        init_cache = lambda b, s: SM.ssm_state_zero(cfg, b)
        log_ax = functools.partial(SM.ssm_logical_axes, cfg)
        cache_ax = functools.partial(SM.ssm_cache_logical_axes, cfg, layout)
    elif cfg.family == "hybrid":
        init = functools.partial(HY.hybrid_init, cfg, layout)
        loss = functools.partial(HY.hybrid_loss, cfg, layout, sharder)
        prefill = functools.partial(HY.hybrid_prefill, cfg, layout, sharder)
        decode = functools.partial(HY.hybrid_decode, cfg, layout, sharder)
        init_cache = functools.partial(HY.hybrid_cache_zero, cfg)
        log_ax = functools.partial(HY.hybrid_logical_axes, cfg)
        cache_ax = functools.partial(HY.hybrid_cache_logical_axes, cfg, layout)
    elif cfg.family == "encdec":
        init = functools.partial(ED.encdec_init, cfg, layout)
        loss = functools.partial(ED.encdec_loss, cfg, layout, sharder)
        prefill = functools.partial(ED.encdec_prefill, cfg, layout, sharder)
        decode = functools.partial(ED.encdec_decode, cfg, layout, sharder)
        init_cache = functools.partial(ED.encdec_cache_zero, cfg)
        log_ax = functools.partial(ED.encdec_logical_axes, cfg)
        cache_ax = functools.partial(ED.encdec_cache_logical_axes, cfg, layout)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")

    def logical_axes_pruned():
        shapes = jax.eval_shape(init, jax.random.key(0))
        return TF.prune_axes_to_params(log_ax(), shapes)

    return ModelBundle(
        cfg=cfg,
        layout=layout,
        init=init,
        loss=loss,
        prefill=prefill,
        decode=decode,
        init_cache=init_cache,
        logical_axes=logical_axes_pruned,
        cache_logical_axes=cache_ax,
    )
