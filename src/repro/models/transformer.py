"""Decoder-only transformer stack: dense, interleaved-MoE, and VLM
(prefix patch embeddings) variants. Covers 7 of the 10 assigned archs.

Layout: per-layer weights are stacked on a leading "layers" dim and the
stack is traversed with jax.lax.scan (compact HLO, O(1) compile in depth),
with configurable remat. MoE stacks scan over "super-layers" of
``moe_interleave`` sublayers (the last one MoE) so interleaved patterns
(llama4) need no control flow.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayoutConfig
from repro.models import layers as L
from repro.models.moe import moe_logical_axes, moe_mlp_block, moe_params_init
from repro.parallel.sharding import Sharder

PyTree = Any


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _attn_shapes(cfg: ArchConfig, n: int, dtype) -> Dict[str, Any]:
    D, H, KV, HD = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    sh = {
        "ln1": ((n, D), dtype),
        "wq": ((n, D, H * HD), dtype),
        "wk": ((n, D, KV * HD), dtype),
        "wv": ((n, D, KV * HD), dtype),
        "wo": ((n, H * HD, D), dtype),
    }
    if cfg.qkv_bias:
        sh |= {"bq": ((n, H * HD), dtype), "bk": ((n, KV * HD), dtype),
               "bv": ((n, KV * HD), dtype)}
    if cfg.qk_norm:
        sh |= {"qnorm": ((n, HD), dtype), "knorm": ((n, HD), dtype)}
    return sh


def _mlp_shapes(cfg: ArchConfig, n: int, d_ff: int, dtype) -> Dict[str, Any]:
    D = cfg.d_model
    sh = {"ln2": ((n, D), dtype), "w1": ((n, D, d_ff), dtype),
          "w2": ((n, d_ff, D), dtype)}
    if cfg.mlp_gated:
        sh["w3"] = ((n, D, d_ff), dtype)
    return sh


_ATTN_AXES = {
    "ln1": ("layers", None),
    "wq": ("layers", "embed_fsdp", "tp"),
    "wk": ("layers", "embed_fsdp", "tp"),
    "wv": ("layers", "embed_fsdp", "tp"),
    "wo": ("layers", "tp", "embed_fsdp"),
    "bq": ("layers", "tp"),
    "bk": ("layers", "tp"),
    "bv": ("layers", "tp"),
    "qnorm": ("layers", None),
    "knorm": ("layers", None),
}
_MLP_AXES = {
    "ln2": ("layers", None),
    "w1": ("layers", "embed_fsdp", "tp"),
    "w2": ("layers", "tp", "embed_fsdp"),
    "w3": ("layers", "embed_fsdp", "tp"),
}


def _init_from_shapes(key, shapes: Dict[str, Any]) -> Dict[str, jax.Array]:
    out = {}
    keys = jax.random.split(key, len(shapes))
    for k_, (name, (shape, dtype)) in zip(keys, sorted(shapes.items())):
        if name.startswith(("ln", "qnorm", "knorm")) or "norm" in name:
            out[name] = jnp.ones(shape, dtype)
        elif name.startswith("b"):
            out[name] = jnp.zeros(shape, dtype)
        else:
            out[name] = L.trunc_normal(k_, shape, dtype)
    return out


def transformer_init(cfg: ArchConfig, layout: LayoutConfig, key) -> PyTree:
    dtype = jnp.dtype(layout.param_dtype)
    D, V = cfg.d_model, cfg.padded_vocab
    k_emb, k_unemb, k_layers, k_moe = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "emb": L.embed_init(k_emb, V, D, dtype),
        "final_norm": jnp.ones((D,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unemb"] = L.embed_init(k_unemb, V, D, dtype)
    if cfg.moe_num_experts:
        n_super = cfg.num_layers // cfg.moe_interleave
        nd = cfg.moe_interleave - 1
        if nd:
            sh = _attn_shapes(cfg, n_super * nd, dtype) | _mlp_shapes(
                cfg, n_super * nd, cfg.dense_d_ff or cfg.d_ff, dtype
            )
            params["dense_layers"] = _init_from_shapes(k_layers, sh)
        sh = _attn_shapes(cfg, n_super, dtype)
        moe = _init_from_shapes(jax.random.fold_in(k_layers, 1), sh)
        moe |= moe_params_init(cfg, n_super, dtype, k_moe)
        params["moe_layers"] = moe
    else:
        sh = _attn_shapes(cfg, cfg.num_layers, dtype) | _mlp_shapes(
            cfg, cfg.num_layers, cfg.d_ff, dtype
        )
        params["layers"] = _init_from_shapes(k_layers, sh)
    return params


def transformer_logical_axes(cfg: ArchConfig) -> PyTree:
    ax: Dict[str, Any] = {
        "emb": ("vocab", "embed_fsdp"),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        ax["unemb"] = ("vocab", "embed_fsdp")
    block = dict(_ATTN_AXES)
    if cfg.moe_num_experts:
        if cfg.moe_interleave > 1:
            ax["dense_layers"] = {**block, **_MLP_AXES}
        ax["moe_layers"] = {**block, **moe_logical_axes(cfg)}
    else:
        ax["layers"] = {**block, **_MLP_AXES}
    return ax


def prune_axes_to_params(axes: PyTree, params: PyTree) -> PyTree:
    """Drop logical-axis entries with no matching param leaf (bias/qk_norm
    options make the param set config-dependent)."""
    if isinstance(params, dict):
        return {k: prune_axes_to_params(axes[k], v) for k, v in params.items()}
    return axes


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _project_qkv(cfg, w, x):
    q = jnp.einsum("bsd,dh->bsh", x, w["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, w["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, w["wv"])
    if cfg.qkv_bias:
        q = q + L.full_rank(w["bq"], q.ndim)
        k = k + L.full_rank(w["bk"], k.ndim)
        v = v + L.full_rank(w["bv"], v.ndim)
    b, s = x.shape[:2]
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, w["qnorm"], cfg.norm_eps)
        k = L.rms_norm(k, w["knorm"], cfg.norm_eps)
    return q, k, v


def attn_block(
    cfg: ArchConfig,
    layout: LayoutConfig,
    sharder: Sharder,
    w: Dict[str, jax.Array],
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str = "train",  # train | prefill | decode
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    h = L.rms_norm(x, w["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, w, h)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = sharder.act(q, "batch", None, "heads", None)
    new_cache = None
    if mode == "decode":
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_index, axis=1)
        new_cache = (ck, cv)
        if layout.kv_cache_shard == "hd":
            # match q's sharding to the head_dim-sharded cache: the QK
            # contraction becomes partial sums + an O(B x S_cache) logits
            # all-reduce instead of all-gathering the whole cache.
            q = sharder.act(q, "batch", None, None, "head_dim")
        valid = jnp.full((x.shape[0],), cache_index + 1, jnp.int32)
        ldt = jnp.bfloat16 if layout.decode_logits_bf16 else jnp.float32
        o = L.attention(q, ck, cv, causal=False, impl="dense",
                        kv_valid_len=valid, logits_dtype=ldt)
    else:
        if mode == "prefill":
            new_cache = (k, v)  # cache stores KV heads (pre-repeat)
        # Repeat KV to the full head count for the compute: under TP each
        # shard then holds exactly its q-heads' KV (same per-device bytes
        # as replicated GQA heads) and every attention tensor stays 4D
        # with a clean heads->model sharding — this is what lets
        # sequence-parallel residuals coexist with TP attention without
        # SPMD "involuntary full rematerialization" conflicts.
        g = cfg.num_heads // max(cfg.num_kv_heads, 1)
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
            k = sharder.act(k, "batch", None, "heads", None)
            v = sharder.act(v, "batch", None, "heads", None)
        o = L.attention(
            q, k, v, causal=True, impl=layout.attn_impl,
            chunk_kv=layout.attn_chunk_kv, chunk_q=layout.attn_chunk_q,
        )
    o = o.reshape(x.shape[0], x.shape[1], cfg.num_heads * cfg.head_dim)
    x = x + jnp.einsum("bsh,hd->bsd", o, w["wo"])
    return sharder.act(x, "batch", "seq", None), new_cache


def mlp_block(cfg, layout, sharder, w, x, d_ff_override=None):
    h = L.rms_norm(x, w["ln2"], cfg.norm_eps)
    if cfg.mlp_gated:
        y = L.mlp_gated(h, w["w1"], w["w3"], w["w2"])
    else:
        y = L.mlp_classic(h, w["w1"], w["w2"])
    return sharder.act(x + y, "batch", "seq", None)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _split_layer(tree: Dict[str, jax.Array], idx=None):
    return tree if idx is None else {k: v[idx] for k, v in tree.items()}


def _embed(cfg, params, tokens, sharder):
    x = jnp.take(params["emb"], tokens, axis=0)
    return sharder.act(x.astype(jnp.bfloat16) if params["emb"].dtype == jnp.bfloat16 else x,
                       "batch", "seq", None)


def _unembed(cfg, layout, params, x, sharder):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["emb"] if cfg.tie_embeddings else params["unemb"]
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    if layout.logits_fp32:
        logits = logits.astype(jnp.float32)
    return sharder.act(logits, "batch", None, "vocab")


@jax.custom_vjp
def _pin(tree):
    return jax.lax.optimization_barrier(tree)


def _pin_fwd(tree):
    return jax.lax.optimization_barrier(tree), None


def _pin_bwd(_, ct):
    # float0 cotangents (int leaves: positions / cache_index) carry no
    # data for XLA to sink; barrier the rest leaf-wise
    return (
        jax.tree.map(
            lambda x: x
            if getattr(x, "dtype", None) == jax.dtypes.float0
            else jax.lax.optimization_barrier(x),
            ct,
        ),
    )


# optimization_barrier has no differentiation rule (jax 0.4.x), but it is
# semantically the identity: give it one, pinning the cotangents on the
# way back for the same sink-prevention in the bwd scan.
_pin.defvjp(_pin_fwd, _pin_bwd)


def _stack_body(cfg, layout, sharder, mode):
    """Returns the scan body over (super-)layers."""
    nd = cfg.moe_interleave - 1 if cfg.moe_num_experts else 0

    def body(carry, xs):
        # pin the layer inputs inside the loop: without the barrier XLA
        # sinks loop-invariant elementwise ops out of the (scan-AD) while
        # loop, e.g. convert(slice(stack)) -> slice(convert(stack)),
        # materializing an f32 copy of the WHOLE residual-checkpoint
        # stack (+31.5 GB measured on the 405B cell, EXPERIMENTS.md §Perf).
        carry, xs = _pin((carry, xs))
        x, positions, cache_index = carry
        aux = jnp.zeros((), jnp.float32)
        if cfg.moe_num_experts:
            dense_w, moe_w, layer_cache = xs
            new_caches = []
            for j in range(nd):
                wj = {k: v[j] for k, v in dense_w.items()}
                cj = None if layer_cache is None else jax.tree.map(lambda c: c[j], layer_cache[0])
                x, nc = attn_block(cfg, layout, sharder, wj, x, positions,
                                   mode=mode, cache=cj, cache_index=cache_index)
                x = mlp_block(cfg, layout, sharder, wj, x)
                new_caches.append(nc)
            cm = None if layer_cache is None else layer_cache[1]
            x, nc_moe = attn_block(cfg, layout, sharder, moe_w, x, positions,
                                   mode=mode, cache=cm, cache_index=cache_index)
            x, moe_aux = moe_mlp_block(cfg, layout, sharder, moe_w, x)
            aux = aux + moe_aux
            if mode == "train":
                out_cache = None
            else:
                dense_c = (
                    jax.tree.map(lambda *cs: jnp.stack(cs), *new_caches)
                    if nd else None
                )
                out_cache = (dense_c, nc_moe)
        else:
            w, layer_cache = xs
            x, out_cache = attn_block(cfg, layout, sharder, w, x, positions,
                                      mode=mode, cache=layer_cache,
                                      cache_index=cache_index)
            x = mlp_block(cfg, layout, sharder, w, x)
        return (x, positions, cache_index), (out_cache, aux)

    return body


def _run_stack(cfg, layout, sharder, params, x, positions, *, mode,
               cache=None, cache_index=None):
    body = _stack_body(cfg, layout, sharder, mode)

    def scan_body(carry, xs):
        return L.remat_wrap(body, layout.remat)(carry, xs)

    if cfg.moe_num_experts:
        n_super = cfg.num_layers // cfg.moe_interleave
        nd = cfg.moe_interleave - 1
        dense = params.get("dense_layers")
        dense_stacked = (
            jax.tree.map(lambda a: a.reshape(n_super, nd, *a.shape[1:]), dense)
            if nd else {}
        )
        xs = (dense_stacked, params["moe_layers"], cache)
    else:
        xs = (params["layers"], cache)

    # group-remat: checkpoint the residual every G layers instead of every
    # layer — activation-checkpoint memory / G at the cost of recomputing
    # G layers per group in bwd (same total recompute as remat="full").
    G = max(1, int(layout.remat_group))
    n_scan = cfg.num_layers // cfg.moe_interleave if cfg.moe_num_experts else cfg.num_layers
    if mode == "train" and layout.scan_layers and G > 1 and n_scan % G == 0:
        gxs = jax.tree.map(lambda a: a.reshape(n_scan // G, G, *a.shape[1:]), xs)

        def group_body(carry, g):
            aux = jnp.zeros((), jnp.float32)
            for j in range(G):
                xj = jax.tree.map(lambda a: a[j], g)
                # nested remat: the group recompute itself re-checkpoints
                # per layer, so bwd never holds G layers of intermediates
                carry, (_, a) = L.remat_wrap(body, layout.remat)(carry, xj)
                aux = aux + a
            return carry, (None, aux)

        gbody = L.remat_wrap(group_body, layout.remat)
        carry, (_, aux) = jax.lax.scan(gbody, (x, positions, cache_index), gxs)
        return carry[0], None, jnp.sum(aux)

    if not layout.scan_layers:
        n = cfg.num_layers // cfg.moe_interleave if cfg.moe_num_experts else cfg.num_layers
        caches, aux_sum = [], jnp.zeros((), jnp.float32)
        carry = (x, positions, cache_index)
        for i in range(n):
            xi = jax.tree.map(lambda a: a[i], xs)
            carry, (c, a) = scan_body(carry, xi)
            caches.append(c)
            aux_sum = aux_sum + a
        x = carry[0]
        new_cache = (
            None if caches[0] is None
            else jax.tree.map(lambda *cs: jnp.stack(cs), *caches)
        )
        return x, new_cache, aux_sum
    carry, (new_cache, aux) = jax.lax.scan(scan_body, (x, positions, cache_index), xs)
    return carry[0], new_cache, jnp.sum(aux)


def _prep_inputs(cfg, params, batch, sharder):
    """Embed tokens; VLM prepends stub patch embeddings."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens, sharder)
    if cfg.family == "vlm" and "img_emb" in batch:
        img = batch["img_emb"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
        x = sharder.act(x, "batch", "seq", None)
    return x


def transformer_loss(cfg, layout, sharder, params, batch):
    x = _prep_inputs(cfg, params, batch, sharder)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x, _, aux = _run_stack(cfg, layout, sharder, params, x, positions, mode="train")
    logits = _unembed(cfg, layout, params, x, sharder)
    loss = L.softmax_xent(logits, batch["labels"])
    return loss + aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def _cache_zero(cfg, layout, batch_size, cache_len, dtype=jnp.bfloat16):
    KV, HD = cfg.num_kv_heads, cfg.head_dim
    z = lambda *lead: jnp.zeros((*lead, batch_size, cache_len, KV, HD), dtype)
    if cfg.moe_num_experts:
        n_super = cfg.num_layers // cfg.moe_interleave
        nd = cfg.moe_interleave - 1
        dense = (z(n_super, nd), z(n_super, nd)) if nd else None
        return (dense, (z(n_super), z(n_super)))
    return (z(cfg.num_layers), z(cfg.num_layers))


def cache_logical_axes(cfg, layout):
    mode = layout.kv_cache_shard
    per = {
        "hd": ("cache_batch", None, None, "head_dim"),
        "heads": ("cache_batch", None, "heads", None),
        "seq": ("cache_batch", "seq", None, None),
    }[mode]
    if cfg.moe_num_experts:
        nd = cfg.moe_interleave - 1
        dense = (("layers", None) + per, ("layers", None) + per) if nd else None
        moe = (("layers",) + per, ("layers",) + per)
        return (dense, moe)
    return (("layers",) + per, ("layers",) + per)


def transformer_prefill(cfg, layout, sharder, params, batch):
    x = _prep_inputs(cfg, params, batch, sharder)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x, cache, _ = _run_stack(cfg, layout, sharder, params, x, positions, mode="prefill")
    logits = _unembed(cfg, layout, params, x[:, -1:], sharder)
    return logits[:, 0], cache


def transformer_decode(cfg, layout, sharder, params, cache, batch):
    token, index = batch["token"], batch["index"]
    x = _embed(cfg, params, token[:, None], sharder)
    positions = jnp.full((x.shape[0], 1), index, jnp.int32)
    x, new_cache, _ = _run_stack(
        cfg, layout, sharder, params, x, positions, mode="decode",
        cache=cache, cache_index=index,
    )
    logits = _unembed(cfg, layout, params, x, sharder)
    return logits[:, 0], new_cache
