"""Mixture-of-experts MLP sublayer (GShard-style capacity dispatch).

Top-k routing is decomposed into k sequential top-1 dispatch slots, each
with per-slot capacity C = ceil(S * cf / E). This keeps the transient
dispatch tensor at (B, S, E, C_slot) instead of (B, S, E, k*C_slot),
which matters for high-k configs (granite: k=8, E=32). A per-expert
running count carries across slots so total capacity is enforced.

Sharding: experts -> "model" (expert parallelism); the (B, E, C, D)
dispatched activations are constrained to ("batch", "experts", ...), so
GSPMD materializes the token shuffle as an all-to-all on the model axis.
Aux losses: GShard load-balance loss + router z-loss.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Any


def moe_params_init(cfg, n: int, dtype, key) -> Dict[str, jax.Array]:
    D, E, F = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 8)
    p = {
        "ln2": jnp.ones((n, D), dtype),
        "router": L.trunc_normal(ks[0], (n, D, E), jnp.float32),
        "we1": L.trunc_normal(ks[1], (n, E, D, F), dtype),
        "we2": L.trunc_normal(ks[2], (n, E, F, D), dtype),
    }
    if cfg.mlp_gated:
        p["we3"] = L.trunc_normal(ks[3], (n, E, D, F), dtype)
    if cfg.moe_shared_expert:
        p["ws1"] = L.trunc_normal(ks[4], (n, D, F), dtype)
        p["ws2"] = L.trunc_normal(ks[5], (n, F, D), dtype)
        if cfg.mlp_gated:
            p["ws3"] = L.trunc_normal(ks[6], (n, D, F), dtype)
    return p


def moe_logical_axes(cfg) -> Dict[str, Tuple]:
    return {
        "ln2": ("layers", None),
        "router": ("layers", "embed_fsdp", None),
        "we1": ("layers", "experts", "embed_fsdp", None),
        "we2": ("layers", "experts", None, "embed_fsdp"),
        "we3": ("layers", "experts", "embed_fsdp", None),
        "ws1": ("layers", "embed_fsdp", "tp"),
        "ws2": ("layers", "tp", "embed_fsdp"),
        "ws3": ("layers", "embed_fsdp", "tp"),
    }


def slot_capacity(cfg, seq_len: int, layout=None) -> int:
    cf = cfg.moe_capacity_factor
    if layout is not None and getattr(layout, "moe_capacity_override", 0.0):
        cf = layout.moe_capacity_override
    c = math.ceil(seq_len * cf / cfg.moe_num_experts)
    return max(4, min(seq_len, int(c)))


def moe_mlp_block(cfg, layout, sharder, w, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (B, S, D), aux loss scalar."""
    B, S, D = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    C = slot_capacity(cfg, S, layout)
    h = L.rms_norm(x, w["ln2"], cfg.norm_eps)

    router_logits = jnp.einsum(
        "bsd,de->bse", h.astype(jnp.float32), w["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)  # (B,S,E) fp32

    # aux losses (GShard load-balance + z-loss)
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), E, dtype=jnp.float32)
    ce = jnp.mean(top1, axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(router_logits, axis=-1)))
    aux = 0.01 * lb_loss + 1e-3 * z_loss

    def slot(carry, _):
        out, masked_probs, counts = carry
        gate = jnp.max(masked_probs, axis=-1)  # (B,S)
        idx = jnp.argmax(masked_probs, axis=-1)  # (B,S)
        oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (B,S,E)
        # position of each token within its expert buffer this slot
        pos_in_e = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]  # (B,S,E)
        pos = jnp.sum(pos_in_e * oh, axis=-1)  # (B,S)
        keep = (pos < C).astype(jnp.float32)
        disp = (oh * keep[..., None])[..., None] * jax.nn.one_hot(
            jnp.minimum(pos, C - 1).astype(jnp.int32), C, dtype=jnp.float32
        )[:, :, None, :]  # (B,S,E,C)
        disp = disp.astype(h.dtype)
        xe = jnp.einsum("bsec,bsd->becd", disp, h)
        xe = sharder.act(xe, "batch", "experts", None, None)
        if cfg.mlp_gated:
            ye = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w["we1"])) * jnp.einsum(
                "becd,edf->becf", xe, w["we3"]
            )
        else:
            ye = jax.nn.gelu(jnp.einsum("becd,edf->becf", xe, w["we1"]))
        ye = jnp.einsum("becf,efd->becd", ye, w["we2"])
        ye = sharder.act(ye, "batch", "experts", None, None)
        combine = disp * gate[:, :, None, None].astype(disp.dtype)
        out = out + jnp.einsum("bsec,becd->bsd", combine, ye)
        # mask out chosen expert for next slot; update counts
        masked_probs = masked_probs * (1.0 - oh)
        counts = counts + jnp.sum(oh * keep[..., None], axis=1)
        return (out, masked_probs, counts), None

    out0 = jnp.zeros_like(x)
    counts0 = jnp.zeros((B, E), jnp.float32)
    (out, _, _), _ = jax.lax.scan(slot, (out0, probs, counts0), None, length=K)

    if cfg.moe_shared_expert:
        if cfg.mlp_gated:
            out = out + L.mlp_gated(h, w["ws1"], w["ws3"], w["ws2"])
        else:
            out = out + L.mlp_classic(h, w["ws1"], w["ws2"])
    return sharder.act(x + out, "batch", "seq", None), aux
