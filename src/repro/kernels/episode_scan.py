"""Multi-interval episode megakernel: T decision intervals per launch.

The per-interval ``fleet_step`` kernel (kernels.fleet_ucb) already fuses
update-then-select into one launch, but an episode still pays one launch
(or one XLA scatter soup) per decision interval even though the (N, K)
controller state is tiny. This module scans a WHOLE episode inside one
``pallas_call``: grid = (N / BLOCK_N, T) with T as the innermost
(sequential) axis, the controller state — mu/n/phat/pn/prev/t plus the
carried next-arm — and every per-controller lane (alpha, lambda,
qos_delta, default_arm, gamma, optimistic, prior_mu) resident in VMEM
across the whole scan. State is carried in OUTPUT refs whose index map
is constant along the T axis (the revisiting-block pattern: the block
stays in VMEM while t advances and is flushed to HBM once per
controller stripe), initialized from the input refs at t == 0.

Two modes:

- **trace-fed** (:func:`episode_scan_trace`): per-interval observation
  columns (reward / progress / active, each (T, N)) stream in through
  ``(1, BLOCK_N)`` grid blocks — the offline-evaluation path for
  ``TraceReplayBackend`` recordings (obs columns are derived once,
  vectorized, from the counter trace).
- **sim-fused** (:func:`episode_scan_sim`): SimBackend's ``env_step``,
  counter accumulation, reward normalization AND the drift-phase
  schedule (keyed by GLOBAL interval index, computed in-kernel from
  static ``t_start``/``drift_every``) run inside the kernel; only the
  raw standard-normal draws stream in as (T, N) columns (they are the
  one thing that cannot be computed in-kernel without replicating the
  counter-based RNG — SimBackend precomputes them in one vectorized op,
  bit-identical to its streaming draws). ``counter_obs=True`` derives
  the observation from counter DELTAS exactly as the streaming
  EnergyController does (scan == stream arm-for-arm);
  ``counter_obs=False`` uses the env's direct observation, matching the
  rollout engine (run_sweep / run_fleet_episode).

Both modes call :func:`repro.kernels.fleet_ucb.fleet_step_math` — THE
one copy of the fused-step arithmetic — so fused-vs-scanned bit-parity
holds by construction, and both have an XLA ``lax.scan`` fallback over
the same math (:func:`xla_episode_trace` / :func:`xla_episode_sim`) for
CPU/GPU hosts and kernel-ineligible shapes; the fallback donates the
scanned state buffers, and callers hoist lane broadcasting/padding to
once per episode (kernels.ops).

VMEM budget at BLOCK_N = 1024, K = 9, f32: five resident (BLOCK_N, K)
mats (mu/n/phat/pn/prior) ~ 184 KiB, ~23 (BLOCK_N,) rows ~ 92 KiB, the
double-buffered (1, BLOCK_N) stream blocks ~ 8 KiB each, and the (P, K)
phase tables are noise — comfortably inside one core's ~16 MiB VMEM
independent of T, which is the whole point: T scales for free.

Validated in interpret mode against kernels.ref.ref_episode_scan on
ragged N / ragged T with mixed stationary/SW/QoS/warm-up lanes
(tests/test_episode_scan.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.calibration import (
    DEFAULT_ARM,
    SWITCH_ENERGY_J,
    SWITCH_LATENCY_S,
)
from repro.kernels.fleet_ucb import _pad, fleet_step_math


class ScanEnv(NamedTuple):
    """Per-phase env tables in kernel-consumable form: (P, K) rows per
    arm plus a (P, 6) scalar table [dt_s, noise_energy, noise_util,
    early_noise, early_tau, reward_scale], P = number of drift phases
    (1 when the workload is stationary). Build with
    :func:`make_scan_env` (or ``SimBackend.episode_env``)."""

    e_tab: jax.Array  # (P, K) e_interval_kj
    p_tab: jax.Array  # (P, K) noise-free progress per interval
    uc_tab: jax.Array  # (P, K) core-active fraction
    uu_tab: jax.Array  # (P, K) copy-engine-active fraction
    scal: jax.Array  # (P, 6) per-phase scalars (layout above)


class EnvRows(NamedTuple):
    """(N,) per-node env + counter carry: EnvState's fields plus the
    SimBackend active-time accumulators, i.e. everything the streaming
    backend's ``read_counters`` is derived from."""

    remaining: jax.Array  # f32 job fraction left
    prev_arm: jax.Array  # i32 last actuated arm (env's switch detector)
    t: jax.Array  # i32 active-step counter
    energy_kj: jax.Array  # f32 cumulative energy
    time_s: jax.Array  # f32 cumulative wall time
    switches: jax.Array  # i32 cumulative switch count
    core_s: jax.Array  # f32 cumulative core-active seconds
    uncore_s: jax.Array  # f32 cumulative copy-engine-active seconds


def env_rows_init(n: int) -> EnvRows:
    """Fresh-job env rows for an N-node fleet (mirrors ``env_init`` +
    zeroed active-time accumulators)."""
    z = jnp.zeros((n,), jnp.float32)
    return EnvRows(
        remaining=jnp.ones((n,), jnp.float32),
        prev_arm=jnp.full((n,), DEFAULT_ARM, jnp.int32),
        t=jnp.zeros((n,), jnp.int32),
        energy_kj=z,
        time_s=z,
        switches=jnp.zeros((n,), jnp.int32),
        core_s=z,
        uncore_s=z,
    )


def make_scan_env(phases: Sequence) -> ScanEnv:
    """Stack per-phase :class:`~repro.core.simulator.EnvParams` into the
    kernel-consumable :class:`ScanEnv` tables. Raises on per-node
    stacked params (those fleets keep the streaming path)."""
    for p in phases:
        if jnp.ndim(p.dt_s) != 0:
            raise ValueError(
                "episode scan needs EnvParams shared across the fleet; "
                "per-node stacked params take the streaming path"
            )
    tab = lambda f: jnp.stack([jnp.asarray(getattr(p, f), jnp.float32)
                               for p in phases])
    scal = jnp.stack([
        jnp.stack([jnp.asarray(v, jnp.float32) for v in (
            p.dt_s, p.noise_energy, p.noise_util, p.early_noise,
            p.early_tau, p.reward_scale)])
        for p in phases
    ])
    return ScanEnv(e_tab=tab("e_interval_kj"), p_tab=tab("progress"),
                   uc_tab=tab("uc"), uu_tab=tab("uu"), scal=scal)


def phase_rows(env: ScanEnv, idx, t_start: int, drift_every: int):
    """The active phase's (K,) table rows + (6,) scalar row for global
    interval ``t_start + idx`` — a one-hot sum over the P phases (exact:
    one term is the value, the rest are zero), so the drift schedule is
    branch-free and identical in-kernel and in the XLA fallback."""
    p = env.e_tab.shape[0]
    if p > 1:
        ph = ((t_start + idx) // drift_every) % p
    else:
        ph = 0
    ph_f = (jax.lax.broadcasted_iota(jnp.int32, (p, 1), 0) == ph).astype(
        jnp.float32
    )
    pick = lambda tab: jnp.sum(tab * ph_f, axis=0)
    return (pick(env.e_tab), pick(env.p_tab), pick(env.uc_tab),
            pick(env.uu_tab), pick(env.scal))


def sim_env_obs(env: EnvRows, arm, z_e, z_uc, z_uu, z_p,
                e_row, p_row, uc_row, uu_row, scal_row, rs0, *,
                counter_obs: bool):
    """One simulated decision interval on (BN,)-shaped rows: exactly the
    expression trees of ``simulator.env_step`` + SimBackend's counter
    accumulation, followed by the observation derivation. THE one copy
    of the scanned env arithmetic — the Pallas kernel, the XLA fallback
    and the ref oracle all call this, so the three stay bit-identical.

    ``counter_obs=True`` mirrors the streaming EnergyController: the
    observation comes from counter deltas (``derive_obs``'s expressions,
    including its rounding — e.g. ``uc * d_t / d_t`` is NOT ``uc`` in
    float) and the reward normalizer is the phase-0 ``rs0``, so a
    scanned episode reproduces the streaming loop arm-for-arm.
    ``counter_obs=False`` uses the env's direct observation (the rollout
    engine's convention; the normalizer is the active phase's).

    Returns ``(env2, reward, progress, active_f32)``.
    """
    dt_s = scal_row[0]
    noise_e, noise_u = scal_row[1], scal_row[2]
    early_n, early_tau, rs = scal_row[3], scal_row[4], scal_row[5]
    k = e_row.shape[0]
    arms = jax.lax.broadcasted_iota(jnp.int32, (arm.shape[0], k), 1)
    onehot = (arms == arm[:, None]).astype(jnp.float32)
    # one-hot gathers from the (K,) phase row: value-exact vs indexing
    gath = lambda row: jnp.sum(row[None, :] * onehot, axis=1)

    active = env.remaining > 0.0
    switched = (arm != env.prev_arm) & active
    early = 1.0 + early_n * jnp.exp(-env.t.astype(jnp.float32) / early_tau)
    n_e = 1.0 + noise_e * early * z_e
    n_uc = 1.0 + noise_u * early * z_uc
    n_uu = 1.0 + noise_u * early * z_uu
    n_p = 1.0 + noise_u * z_p

    e_kj = gath(e_row) * jnp.maximum(n_e, 0.05)
    e_kj = e_kj + switched * (SWITCH_ENERGY_J / 1e3)
    uc = jnp.clip(gath(uc_row) * jnp.maximum(n_uc, 0.05), 1e-3, 1.0)
    uu = jnp.clip(gath(uu_row) * jnp.maximum(n_uu, 0.05), 1e-3, 1.0)
    eff = 1.0 - switched * (SWITCH_LATENCY_S / dt_s)
    prog = gath(p_row) * jnp.maximum(n_p, 0.0) * eff

    remaining2 = jnp.maximum(env.remaining - prog * active, 0.0)
    prev2 = jnp.where(active, arm, env.prev_arm)
    t2 = env.t + active.astype(jnp.int32)
    energy2 = env.energy_kj + e_kj * active
    time2 = env.time_s + (dt_s + switched * SWITCH_LATENCY_S) * active
    switches2 = env.switches + switched.astype(jnp.int32)
    # active-time counters integrate over the REALIZED wall delta (the
    # post-hoc difference, with its float rounding — the streaming
    # _sim_advance does exactly this)
    d_t = time2 - env.time_s
    core2 = env.core_s + uc * d_t
    uncore2 = env.uncore_s + uu * d_t
    env2 = EnvRows(remaining2, prev2, t2, energy2, time2, switches2,
                   core2, uncore2)
    if counter_obs:
        # derive_obs on the carried counters, expression for expression
        # (read_counters scales energy at READ time, so delta the scaled
        # values; busy fractions divide the integrated seconds back out)
        d_e = energy2 * 1e3 - env.energy_kj * 1e3
        safe_t = jnp.maximum(d_t, 1e-9)
        uc_o = jnp.clip((core2 - env.core_s) / safe_t, 1e-3, 1.0)
        uu_o = jnp.clip((uncore2 - env.uncore_s) / safe_t, 1e-3, 1.0)
        reward = -d_e * (uc_o / uu_o) / rs0
        progress_o = (1.0 - remaining2) - (1.0 - env.remaining)
        return env2, reward, progress_o, active.astype(jnp.float32)
    reward = -(e_kj * 1e3) * (uc / uu) / rs
    return env2, reward, prog, active.astype(jnp.float32)


# ---------------------------------------------------------------------------
# trace-fed megakernel
# ---------------------------------------------------------------------------

_STATE = ("mu", "n", "phat", "pn", "prev", "t", "arm")


def _episode_trace_kernel(
    mu0, n0, phat0, pn0, prev0, t0, arm0,
    alpha, lam, qos, defr, gamma, opt, prior, lam_unc,
    r_s, p_s, a_s,
    mu_o, n_o, phat_o, pn_o, prev_o, t_o, arm_o, arms_o,
    *, k, k_unc,
):
    carry = (mu_o, n_o, phat_o, pn_o, prev_o, t_o, arm_o)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        for o, i in zip(carry, (mu0, n0, phat0, pn0, prev0, t0, arm0)):
            o[...] = i[...]

    arm = arm_o[...]
    arms_o[...] = arm[None, :]  # the arm HELD ENTERING this interval
    out = fleet_step_math(
        mu_o[...], n_o[...], phat_o[...], pn_o[...], prev_o[...], t_o[...],
        arm, r_s[0, :], p_s[0, :], a_s[0, :],
        alpha[...], lam[...], qos[...], defr[...], gamma[...], opt[...],
        prior[...], lam_unc[...], k=k, k_unc=k_unc,
    )
    for o, v in zip(carry, out):
        o[...] = v


def _pad_cols(a, pad, fill=0):
    return jnp.concatenate(
        [a, jnp.full((a.shape[0], pad), fill, a.dtype)], 1
    )


def episode_scan_trace(
    mu, n, phat, pn, prev, t, arm,  # initial controller state + held arm
    reward, progress, active,  # (T, N) observation columns
    alpha, lam, qos, def_arm, gamma, optimistic, prior_mu,  # lanes
    lam_unc=None,  # (N,) uncore switching penalty; sentinel < 0 = shared
    *,
    k_unc: int = 1,
    block_n: int = 1024,
    interpret: bool = False,
):
    """T fused controller steps in ONE launch, observations streamed in.
    Returns ``((mu, n, phat, pn, prev, t, next_arm), arms_run)`` where
    ``arms_run[t]`` is the arm held entering interval t (so
    ``arms_run[0] == arm`` and the final selection is ``next_arm``)."""
    nn, k = mu.shape
    tt = reward.shape[0]
    if lam_unc is None:
        lam_unc = jnp.full((nn,), -1.0, jnp.float32)
    block_n = min(block_n, nn)
    pad = (-nn) % block_n
    if pad:  # padded controllers are inactive: state rides through frozen
        out, arms = episode_scan_trace(
            _pad(mu, pad), _pad(n, pad, 1), _pad(phat, pad), _pad(pn, pad, 1),
            _pad(prev, pad), _pad(t, pad, 2.0), _pad(arm, pad),
            _pad_cols(reward, pad), _pad_cols(progress, pad),
            _pad_cols(active, pad),
            _pad(alpha, pad), _pad(lam, pad), _pad(qos, pad, -1.0),
            _pad(def_arm, pad), _pad(gamma, pad, 1.0),
            _pad(optimistic, pad, 1.0), _pad(prior_mu, pad),
            _pad(lam_unc, pad, -1.0),
            k_unc=k_unc, block_n=block_n, interpret=interpret,
        )
        return tuple(o[:nn] for o in out), arms[:, :nn]
    kernel = functools.partial(_episode_trace_kernel, k=k, k_unc=k_unc)
    row = pl.BlockSpec((block_n,), lambda i, tb: (i,))
    mat = pl.BlockSpec((block_n, k), lambda i, tb: (i, 0))
    stream = pl.BlockSpec((1, block_n), lambda i, tb: (tb, i))
    f32, i32 = jnp.float32, jnp.int32
    *state, arms = pl.pallas_call(
        kernel,
        grid=(nn // block_n, tt),
        in_specs=[mat, mat, mat, mat, row, row, row,
                  row, row, row, row, row, row, mat, row,
                  stream, stream, stream],
        out_specs=(mat, mat, mat, mat, row, row, row, stream),
        out_shape=(
            jax.ShapeDtypeStruct((nn, k), f32),
            jax.ShapeDtypeStruct((nn, k), f32),
            jax.ShapeDtypeStruct((nn, k), f32),
            jax.ShapeDtypeStruct((nn, k), f32),
            jax.ShapeDtypeStruct((nn,), i32),
            jax.ShapeDtypeStruct((nn,), f32),
            jax.ShapeDtypeStruct((nn,), i32),
            jax.ShapeDtypeStruct((tt, nn), i32),
        ),
        interpret=interpret,
    )(mu, n, phat, pn, prev, t, arm,
      alpha, lam, qos, def_arm, gamma, optimistic, prior_mu, lam_unc,
      reward, progress, active)
    return tuple(state), arms


# ---------------------------------------------------------------------------
# sim-fused megakernel
# ---------------------------------------------------------------------------


def _episode_sim_kernel(
    mu0, n0, phat0, pn0, prev0, t0, arm0,
    alpha, lam, qos, defr, gamma, opt, prior, lam_unc,
    rem0, eprev0, et0, en0, tm0, sw0, cs0, us0,
    ze_s, zuc_s, zuu_s, zp_s,
    e_tab, p_tab, uc_tab, uu_tab, scal,
    mu_o, n_o, phat_o, pn_o, prev_o, t_o, arm_o,
    rem_o, eprev_o, et_o, en_o, tm_o, sw_o, cs_o, us_o,
    arms_o,
    *, k, k_unc, t_start, drift_every, counter_obs,
):
    carry = (mu_o, n_o, phat_o, pn_o, prev_o, t_o, arm_o)
    env_carry = (rem_o, eprev_o, et_o, en_o, tm_o, sw_o, cs_o, us_o)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        ins = (mu0, n0, phat0, pn0, prev0, t0, arm0,
               rem0, eprev0, et0, en0, tm0, sw0, cs0, us0)
        for o, i in zip(carry + env_carry, ins):
            o[...] = i[...]

    arm = arm_o[...]
    arms_o[...] = arm[None, :]
    senv = ScanEnv(e_tab[...], p_tab[...], uc_tab[...], uu_tab[...],
                   scal[...])
    e_row, p_row, uc_row, uu_row, scal_row = phase_rows(
        senv, pl.program_id(1), t_start, drift_every
    )
    env = EnvRows(*(o[...] for o in env_carry))
    env2, reward, prog, act = sim_env_obs(
        env, arm, ze_s[0, :], zuc_s[0, :], zuu_s[0, :], zp_s[0, :],
        e_row, p_row, uc_row, uu_row, scal_row, senv.scal[0, 5],
        counter_obs=counter_obs,
    )
    out = fleet_step_math(
        mu_o[...], n_o[...], phat_o[...], pn_o[...], prev_o[...], t_o[...],
        arm, reward, prog, act,
        alpha[...], lam[...], qos[...], defr[...], gamma[...], opt[...],
        prior[...], lam_unc[...], k=k, k_unc=k_unc,
    )
    for o, v in zip(carry + env_carry, out + tuple(env2)):
        o[...] = v


def _pad_env_rows(env: EnvRows, pad) -> EnvRows:
    # remaining pads with 0 => padded nodes are inactive and frozen
    return EnvRows(*(_pad(leaf, pad) for leaf in env))


def episode_scan_sim(
    mu, n, phat, pn, prev, t, arm,
    env_rows: EnvRows,  # (N,) env + counter carry (see env_rows_init)
    z: Tuple[jax.Array, jax.Array, jax.Array, jax.Array],  # 4x (T, N)
    scan_env: ScanEnv,
    alpha, lam, qos, def_arm, gamma, optimistic, prior_mu,
    lam_unc=None,  # (N,) uncore switching penalty; sentinel < 0 = shared
    *,
    k_unc: int = 1,
    t_start: int = 0,
    drift_every: int = 0,
    counter_obs: bool = True,
    block_n: int = 1024,
    interpret: bool = False,
):
    """T fused env+controller intervals in ONE launch (sim-fused mode):
    the environment, counters, observation derivation and drift-phase
    schedule all run in-kernel; only the raw normals ``z`` stream in.
    Returns ``((mu, n, phat, pn, prev, t, next_arm), env_rows, arms)``.
    """
    nn, k = mu.shape
    z_e, z_uc, z_uu, z_p = z
    tt = z_e.shape[0]
    if lam_unc is None:
        lam_unc = jnp.full((nn,), -1.0, jnp.float32)
    block_n = min(block_n, nn)
    pad = (-nn) % block_n
    if pad:
        out, env2, arms = episode_scan_sim(
            _pad(mu, pad), _pad(n, pad, 1), _pad(phat, pad), _pad(pn, pad, 1),
            _pad(prev, pad), _pad(t, pad, 2.0), _pad(arm, pad),
            _pad_env_rows(env_rows, pad),
            tuple(_pad_cols(a, pad) for a in z),
            scan_env,
            _pad(alpha, pad), _pad(lam, pad), _pad(qos, pad, -1.0),
            _pad(def_arm, pad), _pad(gamma, pad, 1.0),
            _pad(optimistic, pad, 1.0), _pad(prior_mu, pad),
            _pad(lam_unc, pad, -1.0),
            k_unc=k_unc, t_start=t_start, drift_every=drift_every,
            counter_obs=counter_obs, block_n=block_n, interpret=interpret,
        )
        return (tuple(o[:nn] for o in out),
                EnvRows(*(leaf[:nn] for leaf in env2)), arms[:, :nn])
    kernel = functools.partial(
        _episode_sim_kernel, k=k, k_unc=k_unc, t_start=int(t_start),
        drift_every=int(drift_every), counter_obs=bool(counter_obs),
    )
    p = scan_env.e_tab.shape[0]
    row = pl.BlockSpec((block_n,), lambda i, tb: (i,))
    mat = pl.BlockSpec((block_n, k), lambda i, tb: (i, 0))
    stream = pl.BlockSpec((1, block_n), lambda i, tb: (tb, i))
    tabk = pl.BlockSpec((p, k), lambda i, tb: (0, 0))
    tabs = pl.BlockSpec((p, 6), lambda i, tb: (0, 0))
    f32, i32 = jnp.float32, jnp.int32
    srow = lambda dt: jax.ShapeDtypeStruct((nn,), dt)
    smat = jax.ShapeDtypeStruct((nn, k), f32)
    *state, rem, eprev, et, en, tm, sw, cs, us, arms = pl.pallas_call(
        kernel,
        grid=(nn // block_n, tt),
        in_specs=[mat, mat, mat, mat, row, row, row,
                  row, row, row, row, row, row, mat, row,
                  row, row, row, row, row, row, row, row,
                  stream, stream, stream, stream,
                  tabk, tabk, tabk, tabk, tabs],
        out_specs=(mat, mat, mat, mat, row, row, row,
                   row, row, row, row, row, row, row, row, stream),
        out_shape=(
            smat, smat, smat, smat, srow(i32), srow(f32), srow(i32),
            srow(f32), srow(i32), srow(i32), srow(f32), srow(f32),
            srow(i32), srow(f32), srow(f32),
            jax.ShapeDtypeStruct((tt, nn), i32),
        ),
        interpret=interpret,
    )(mu, n, phat, pn, prev, t, arm,
      alpha, lam, qos, def_arm, gamma, optimistic, prior_mu, lam_unc,
      *env_rows, z_e, z_uc, z_uu, z_p, *scan_env)
    return (tuple(state), EnvRows(rem, eprev, et, en, tm, sw, cs, us), arms)


# ---------------------------------------------------------------------------
# XLA lax.scan fallback — same math, no Pallas (CPU/GPU hosts)
# ---------------------------------------------------------------------------

# the scanned state is dead after the call: donate it so XLA reuses the
# buffers instead of copying 17 arrays per episode (satellite: the
# fallback pads/broadcasts nothing per interval either — lanes are
# closed over once)
_STATE_ARGS = tuple(range(7))


@functools.partial(jax.jit, static_argnames=("k_unc",),
                   donate_argnums=_STATE_ARGS)
def xla_episode_trace(mu, n, phat, pn, prev, t, arm,
                      reward, progress, active,
                      alpha, lam, qos, def_arm, gamma, optimistic, prior_mu,
                      lam_unc=None, *, k_unc: int = 1):
    """lax.scan over ``fleet_step_math`` — the trace-fed fallback.
    Same return contract as :func:`episode_scan_trace`."""
    k = mu.shape[1]

    def step(carry, cols):
        r, p, a = cols
        out = fleet_step_math(
            *carry, r, p, a, alpha, lam, qos, def_arm, gamma, optimistic,
            prior_mu, lam_unc, k=k, k_unc=k_unc,
        )
        return out, carry[6]

    # NOTE: no scan unroll — unrolling lets XLA fuse across iterations,
    # which changes FMA contraction and costs the bitwise parity with
    # ref_episode_scan / repeated fleet_step that the tests pin
    final, arms = jax.lax.scan(
        step, (mu, n, phat, pn, prev, t, arm), (reward, progress, active)
    )
    return final, arms


# env_rows is NOT donated: SimBackend.env_rows() aliases the backend's
# live counter arrays (read_counters shares them), which must survive
# until absorb_episode swaps in the post-scan rows
@functools.partial(
    jax.jit,
    static_argnames=("t_start", "drift_every", "counter_obs", "k_unc"),
    donate_argnums=_STATE_ARGS,
)
def xla_episode_sim(mu, n, phat, pn, prev, t, arm,
                    env_rows: EnvRows, z, scan_env: ScanEnv,
                    alpha, lam, qos, def_arm, gamma, optimistic, prior_mu,
                    lam_unc=None, *, t_start: int = 0, drift_every: int = 0,
                    counter_obs: bool = True, k_unc: int = 1):
    """lax.scan over ``sim_env_obs`` + ``fleet_step_math`` — the
    sim-fused fallback. Same return contract as
    :func:`episode_scan_sim`."""
    k = mu.shape[1]
    z_e, z_uc, z_uu, z_p = z
    tt = z_e.shape[0]

    def step(carry, xs):
        state, env = carry
        idx, ze, zuc, zuu, zp = xs
        e_row, p_row, uc_row, uu_row, scal_row = phase_rows(
            scan_env, idx, t_start, drift_every
        )
        env2, r, p, a = sim_env_obs(
            env, state[6], ze, zuc, zuu, zp,
            e_row, p_row, uc_row, uu_row, scal_row, scan_env.scal[0, 5],
            counter_obs=counter_obs,
        )
        out = fleet_step_math(
            *state, r, p, a, alpha, lam, qos, def_arm, gamma, optimistic,
            prior_mu, lam_unc, k=k, k_unc=k_unc,
        )
        return (out, env2), state[6]

    (final, env2), arms = jax.lax.scan(
        step, ((mu, n, phat, pn, prev, t, arm), env_rows),
        (jnp.arange(tt, dtype=jnp.int32), z_e, z_uc, z_uu, z_p),
    )
    return final, env2, arms
