"""Jitted dispatch wrappers for the Pallas kernels.

On TPU the real kernels run; elsewhere (this CPU container) callers
either get interpret-mode execution (tests) or the XLA fallback paths in
repro.models.layers. ``layers.attention(impl="pallas")`` routes here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.constants import DEFAULT_ALPHA, DEFAULT_LAM
from repro.kernels import episode_scan as _ep
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.fleet_ucb import fleet_select as _fleet_select
from repro.kernels.fleet_ucb import fleet_step as _fleet_step
from repro.kernels.ssd_scan import chunk_scan as _chunk_scan


def pallas_available() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, interpret: bool = False):
    """q: (B, S, H, HD); k/v: (B, S, KV, HD) — model layout; kernel uses
    head-major layout internally."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    interp = interpret or not pallas_available()
    o = flash_attention_fwd(qt, kt, vt, causal=causal, interpret=interp)
    return o.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_scan(states, decay, init_state, *, interpret: bool = False):
    interp = interpret or not pallas_available()
    return _chunk_scan(states, decay, init_state, interpret=interp)


def _per_controller(x, n):
    """Hyperparams-as-data: scalars broadcast to a (N,) lane, (N,) arrays
    pass through (a fleet may sweep alpha/lam across its nodes)."""
    return jnp.broadcast_to(jnp.asarray(x, jnp.float32), (n,))


@functools.partial(jax.jit, static_argnames=("interpret", "k_unc"))
def fleet_select(mu, n, prev, t, alpha=DEFAULT_ALPHA, lam=DEFAULT_LAM,
                 lam_unc=-1.0, *, k_unc: int = 1, interpret: bool = False):
    interp = interpret or not pallas_available()
    nn = mu.shape[0]
    return _fleet_select(
        mu, n, prev, t, _per_controller(alpha, nn), _per_controller(lam, nn),
        _per_controller(lam_unc, nn), k_unc=k_unc,
        interpret=interp,
    )


@functools.partial(jax.jit, static_argnames=("interpret", "k_unc"))
def fleet_step(mu, n, phat, pn, prev, t, arm, reward, progress, active,
               alpha=DEFAULT_ALPHA, lam=DEFAULT_LAM, qos_delta=-1.0,
               default_arm=None, gamma=1.0, optimistic=1.0, prior_mu=None,
               lam_unc=-1.0, *, k_unc: int = 1, interpret: bool = False):
    """Fused per-interval fleet controller step (update then select,
    restricted to each controller's QoS feasible set; the ``qos_delta``
    sentinel < 0 disables the constraint per controller, so mixed
    constrained/unconstrained fleets share one launch). ``default_arm``
    is the QoS reference and defaults to the top-of-ladder f_max arm
    (K-1), matching the policy convention. Nonstationary variants ride
    the same launch: per-controller ``gamma`` (sentinel >= 1 =
    stationary) discounts the reward and progress statistics and shrinks
    stale means toward ``prior_mu`` at select time, and ``optimistic``
    (sentinel >= 0.5 = optimistic init) flags the round-robin warm-up
    ablation. Factored ladders (static ``k_unc > 1``) decompose each arm
    as ``(core, unc) = divmod(arm, k_unc)`` and charge per-dimension
    switching penalties ``lam``/``lam_unc``; the per-controller sentinel
    ``lam_unc < 0`` keeps the single shared penalty. Returns
    (mu, n, phat, pn, prev, t, next_arm)."""
    interp = interpret or not pallas_available()
    nn, k = mu.shape
    if default_arm is None:
        default_arm = k - 1
    if prior_mu is None:
        prior_mu = 0.0
    return _fleet_step(
        mu, n, phat, pn, prev, t,
        jnp.asarray(arm, jnp.int32),
        jnp.asarray(reward, jnp.float32),
        jnp.asarray(progress, jnp.float32),
        jnp.asarray(active, jnp.float32),
        _per_controller(alpha, nn), _per_controller(lam, nn),
        _per_controller(qos_delta, nn),
        jnp.broadcast_to(jnp.asarray(default_arm, jnp.int32), (nn,)),
        _per_controller(gamma, nn), _per_controller(optimistic, nn),
        jnp.broadcast_to(jnp.asarray(prior_mu, jnp.float32), (nn, k)),
        _per_controller(lam_unc, nn), k_unc=k_unc,
        interpret=interp,
    )


# --------------------------------------------------------------------------
# episode scan: T intervals per dispatch
# --------------------------------------------------------------------------

_pl_episode_trace = jax.jit(
    _ep.episode_scan_trace, static_argnames=("k_unc", "block_n", "interpret")
)
_pl_episode_sim = jax.jit(
    _ep.episode_scan_sim,
    static_argnames=("t_start", "drift_every", "counter_obs", "k_unc",
                     "block_n", "interpret"),
)


def _episode_lanes(nn, k, alpha, lam, qos_delta, default_arm, gamma,
                   optimistic, prior_mu, lam_unc):
    """Broadcast the per-controller lanes ONCE per episode (the per-step
    ``fleet_step`` wrapper re-broadcasts them every interval; the scan
    amortizes that and the ragged-N padding over the whole episode)."""
    if default_arm is None:
        default_arm = k - 1
    if prior_mu is None:
        prior_mu = 0.0
    return (
        _per_controller(alpha, nn), _per_controller(lam, nn),
        _per_controller(qos_delta, nn),
        jnp.broadcast_to(jnp.asarray(default_arm, jnp.int32), (nn,)),
        _per_controller(gamma, nn), _per_controller(optimistic, nn),
        jnp.broadcast_to(jnp.asarray(prior_mu, jnp.float32), (nn, k)),
        _per_controller(lam_unc, nn),
    )


def episode_scan_trace(mu, n, phat, pn, prev, t, arm,
                       reward, progress, active,
                       alpha=DEFAULT_ALPHA, lam=DEFAULT_LAM, qos_delta=-1.0,
                       default_arm=None, gamma=1.0, optimistic=1.0,
                       prior_mu=None, lam_unc=-1.0, *, k_unc: int = 1,
                       interpret: bool = False, block_n: int = 1024):
    """T fused controller steps in one dispatch, trace-fed: per-interval
    observation columns ``reward/progress/active`` are (T, N). Routes to
    the Pallas megakernel on TPU (or with ``interpret=True``), else to
    the XLA lax.scan fallback over the same math. NOTE: the fallback
    DONATES the six state arrays and ``arm`` — pass state you no longer
    need (callers replace their state with the returned one). Returns
    ``((mu, n, phat, pn, prev, t, next_arm), arms)`` with ``arms[t]``
    the arm held entering interval t."""
    nn, k = mu.shape
    lanes = _episode_lanes(nn, k, alpha, lam, qos_delta, default_arm, gamma,
                           optimistic, prior_mu, lam_unc)
    obs = (jnp.asarray(reward, jnp.float32),
           jnp.asarray(progress, jnp.float32),
           jnp.asarray(active, jnp.float32))
    arm = jnp.asarray(arm, jnp.int32)
    if pallas_available() or interpret:
        return _pl_episode_trace(
            mu, n, phat, pn, prev, t, arm, *obs, *lanes, k_unc=k_unc,
            block_n=block_n, interpret=interpret or not pallas_available(),
        )
    return _ep.xla_episode_trace(mu, n, phat, pn, prev, t, arm, *obs, *lanes,
                                 k_unc=k_unc)


def episode_scan_sim(mu, n, phat, pn, prev, t, arm, env_rows, z, scan_env,
                     alpha=DEFAULT_ALPHA, lam=DEFAULT_LAM, qos_delta=-1.0,
                     default_arm=None, gamma=1.0, optimistic=1.0,
                     prior_mu=None, lam_unc=-1.0, *, k_unc: int = 1,
                     t_start: int = 0,
                     drift_every: int = 0, counter_obs: bool = True,
                     interpret: bool = False, block_n: int = 1024):
    """T fused env+controller intervals in one dispatch, sim-fused: the
    SimBackend env step, counters, observation derivation and drift
    schedule run inside the scan; ``z`` is the 4-tuple of (T, N) raw
    normal streams (``SimBackend.episode_noise``), ``env_rows`` /
    ``scan_env`` come from ``SimBackend.env_rows()`` /
    ``episode_env()``. Dispatch mirrors :func:`episode_scan_trace`
    (fallback donates the state; env rows are NOT donated — SimBackend
    keeps reading its live counter arrays until absorb). Returns
    ``((mu, n, phat, pn, prev, t, next_arm), env_rows, arms)``."""
    nn, k = mu.shape
    p = scan_env.e_tab.shape[0]
    if p > 1 and drift_every <= 0:
        raise ValueError("drifting ScanEnv needs drift_every > 0")
    # the schedule is periodic: fold t_start so chunked runs re-use at
    # most P*drift_every compiled variants (and stationary runs one)
    t_start = int(t_start) % (drift_every * p) if p > 1 else 0
    lanes = _episode_lanes(nn, k, alpha, lam, qos_delta, default_arm, gamma,
                           optimistic, prior_mu, lam_unc)
    arm = jnp.asarray(arm, jnp.int32)
    if pallas_available() or interpret:
        return _pl_episode_sim(
            mu, n, phat, pn, prev, t, arm, env_rows, z, scan_env, *lanes,
            k_unc=k_unc, t_start=t_start, drift_every=int(drift_every),
            counter_obs=bool(counter_obs), block_n=block_n,
            interpret=interpret or not pallas_available(),
        )
    return _ep.xla_episode_sim(
        mu, n, phat, pn, prev, t, arm, env_rows, z, scan_env, *lanes,
        k_unc=k_unc, t_start=t_start, drift_every=int(drift_every),
        counter_obs=bool(counter_obs),
    )
