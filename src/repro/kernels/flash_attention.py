"""Causal GQA flash-attention forward kernel (Pallas, TPU target).

TPU-native design (not a CUDA port): the grid is (batch, q_head,
q_block); each program streams the KV sequence in VMEM-resident chunks
with an online-softmax accumulator held in VREGs/VMEM scratch. GQA is
expressed in the BlockSpec index_map (q head h reads kv head h // group)
— no materialized head broadcast. Block shapes keep the MXU fed:
(BLOCK_Q x HD) @ (HD x BLOCK_K) with HD, BLOCK_* multiples of the
128-lane register tiling.

Causality is exploited structurally: kv chunks strictly above the
diagonal are skipped by bounding the fori_loop, and only the diagonal
chunk applies an element mask.

Validated in interpret mode against kernels.ref.ref_attention (CPU
container); engaged on real TPUs via kernels.ops.flash_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, scale,
                      seq_len, causal):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (BQ, HD)
    hd = q.shape[-1]

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, hd), jnp.float32)

    q_start = qi * block_q
    # causal: last kv chunk that can contribute
    hi = (
        (q_start + block_q + block_k - 1) // block_k
        if causal
        else seq_len // block_k
    )
    n_chunks = seq_len // block_k

    def body(ki, carry):
        m, l, acc = carry
        ks = k_ref[0, 0, pl.dslice(ki * block_k, block_k), :]
        vs = v_ref[0, 0, pl.dslice(ki * block_k, block_k), :]
        s = jnp.dot(q, ks.astype(jnp.float32).T)  # (BQ, BK)
        if causal:
            q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            k_idx = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(q_idx >= k_idx, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p.astype(vs.dtype), vs
        ).astype(jnp.float32)
        return m_new, l_new, acc_new

    hi = jnp.minimum(hi, n_chunks) if causal else n_chunks
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-20)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, S, HD)
    k: jax.Array,  # (B, KV, S, HD)
    v: jax.Array,  # (B, KV, S, HD)
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_fwd_kernel,
        block_q=block_q,
        block_k=block_k,
        scale=scale,
        seq_len=s,
        causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, s // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda b_, h_, i: (b_, h_ // g, 0, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda b_, h_, i: (b_, h_ // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, i: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
