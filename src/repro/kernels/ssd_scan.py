"""Mamba2 SSD inter-chunk state recurrence kernel (Pallas, TPU target).

The chunked SSD algorithm splits into (a) intra-chunk matmuls — dense
MXU work XLA already schedules well — and (b) a strictly sequential
inter-chunk recurrence over NC chunk states:

    state <- state * decay_c + chunk_state_c ;  emit state (pre-update)

(b) is latency-bound, not FLOP-bound: the TPU-native choice is one
program per (batch, head) holding the running (P, N) state in VMEM
scratch and streaming chunk states through, instead of XLA's generic
while-loop with HBM round-trips per chunk. P x N tiles are
(64..128 x 64..128) — register-tiling aligned.

Validated in interpret mode against kernels.ref.ref_chunk_scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chunk_scan_kernel(states_ref, decay_ref, init_ref, prev_ref, final_ref, *, nc):
    # states_ref: (1, 1, NC, P, N); decay_ref: (1, 1, NC); init_ref: (1, 1, P, N)
    state0 = init_ref[0, 0].astype(jnp.float32)  # (P, N)

    def body(c, state):
        prev_ref[0, 0, c] = state.astype(prev_ref.dtype)
        dec = decay_ref[0, 0, c]
        st_c = states_ref[0, 0, c].astype(jnp.float32)
        return state * dec + st_c

    state = jax.lax.fori_loop(0, nc, body, state0)
    final_ref[0, 0] = state.astype(final_ref.dtype)


def chunk_scan(
    states: jax.Array,  # (B, H, NC, P, N) per-chunk contributions
    decay: jax.Array,  # (B, H, NC) chunk decays
    init_state: jax.Array,  # (B, H, P, N)
    *,
    interpret: bool = False,
):
    """Returns (prev_states (B,H,NC,P,N) — state entering each chunk —
    and final_state (B,H,P,N))."""
    b, h, nc, p, n = states.shape
    kernel = functools.partial(_chunk_scan_kernel, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, nc, p, n), lambda b_, h_: (b_, h_, 0, 0, 0)),
            pl.BlockSpec((1, 1, nc), lambda b_, h_: (b_, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, nc, p, n), lambda b_, h_: (b_, h_, 0, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, p, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(states, decay, init_state)
