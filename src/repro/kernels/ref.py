"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal=True):
    """q: (B,H,S,HD); k/v: (B,KV,S,HD). Dense softmax attention."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    logits = logits / (hd ** 0.5)
    if causal:
        mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def ref_chunk_scan(states, decay, init_state):
    """states: (B,H,NC,P,N); decay: (B,H,NC); init: (B,H,P,N).
    prev[c] = state entering chunk c; final = state after last chunk."""

    def scan_one(init, st, dec):  # (P,N), (NC,P,N), (NC,)
        def step(s, inp):
            st_c, d = inp
            return s * d + st_c, s

        final, prev = jax.lax.scan(step, init, (st, dec))
        return final, prev

    f = jax.vmap(jax.vmap(scan_one))
    final, prev = f(
        init_state.astype(jnp.float32),
        states.astype(jnp.float32),
        decay.astype(jnp.float32),
    )
    return prev, final


def ref_fleet_select(mu, n, prev, t, *, alpha=0.2, lam=0.05):
    t = jnp.maximum(t, 2.0)
    bonus = alpha * jnp.sqrt(jnp.log(t)[:, None] / jnp.maximum(n, 1.0))
    k = mu.shape[1]
    arms = jnp.arange(k)[None, :]
    sa = mu + bonus - lam * (arms != prev[:, None]).astype(mu.dtype)
    return jnp.argmax(sa, axis=1).astype(jnp.int32)
