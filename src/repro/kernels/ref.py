"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.constants import DEFAULT_ALPHA, DEFAULT_LAM
from repro.kernels.episode_scan import EnvRows, ScanEnv, phase_rows, sim_env_obs


def ref_attention(q, k, v, *, causal=True):
    """q: (B,H,S,HD); k/v: (B,KV,S,HD). Dense softmax attention."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    logits = logits / (hd ** 0.5)
    if causal:
        mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def ref_chunk_scan(states, decay, init_state):
    """states: (B,H,NC,P,N); decay: (B,H,NC); init: (B,H,P,N).
    prev[c] = state entering chunk c; final = state after last chunk."""

    def scan_one(init, st, dec):  # (P,N), (NC,P,N), (NC,)
        def step(s, inp):
            st_c, d = inp
            return s * d + st_c, s

        final, prev = jax.lax.scan(step, init, (st, dec))
        return final, prev

    f = jax.vmap(jax.vmap(scan_one))
    final, prev = f(
        init_state.astype(jnp.float32),
        states.astype(jnp.float32),
        decay.astype(jnp.float32),
    )
    return prev, final


def _ref_switch_penalty(arms, prev, lam, lam_unc, dtype, k_unc):
    """Mirror of fleet_ucb._switch_penalty: scalar ladders (static
    ``k_unc == 1``) keep the verbatim single-penalty expression; factored
    ladders charge each (core, unc) = divmod(arm, k_unc) dimension that
    moved, with sentinel ``lam_unc < 0`` = one shared penalty."""
    if k_unc == 1:
        return lam[:, None] * (arms != prev[:, None]).astype(dtype)
    shared = lam[:, None] * (arms != prev[:, None]).astype(dtype)
    core_moved = (arms // k_unc) != (prev[:, None] // k_unc)
    unc_moved = (arms % k_unc) != (prev[:, None] % k_unc)
    split = (lam[:, None] * core_moved.astype(dtype)
             + lam_unc[:, None] * unc_moved.astype(dtype))
    return jnp.where(lam_unc[:, None] < 0.0, shared, split)


def _ref_ucb_bonus(cnt, tt, alpha, k_unc):
    """Mirror of fleet_ucb._ucb_bonus: joint per-arm bonus on scalar
    ladders (static ``k_unc == 1``), per-dimension bonuses over the
    marginal pull counts on factored ladders."""
    lt = jnp.log(tt)[:, None]
    if k_unc == 1:
        return alpha[:, None] * jnp.sqrt(lt / jnp.maximum(cnt, 1.0))
    nn, k = cnt.shape
    m = cnt.reshape(nn, k // k_unc, k_unc)
    b_core = alpha[:, None] * jnp.sqrt(lt / jnp.maximum(m.sum(2), 1.0))
    b_unc = alpha[:, None] * jnp.sqrt(lt / jnp.maximum(m.sum(1), 1.0))
    return (b_core[:, :, None] + b_unc[:, None, :]).reshape(nn, k)


def _ref_sa_scores(mu, n, prev, t, alpha, lam, lam_unc=None, *, k_unc=1):
    tt = jnp.maximum(t + 1.0, 2.0)  # the policy's select-time lookahead
    bonus = _ref_ucb_bonus(n, tt, alpha, k_unc)
    arms = jnp.arange(mu.shape[1])[None, :]
    return mu + bonus - _ref_switch_penalty(arms, prev, lam, lam_unc,
                                            mu.dtype, k_unc)


def ref_fleet_select(mu, n, prev, t, *, alpha=DEFAULT_ALPHA, lam=DEFAULT_LAM,
                     lam_unc=None, k_unc=1):
    alpha = jnp.broadcast_to(jnp.float32(alpha), mu.shape[:1])
    lam = jnp.broadcast_to(jnp.float32(lam), mu.shape[:1])
    lam_unc = (None if lam_unc is None
               else jnp.broadcast_to(jnp.float32(lam_unc), mu.shape[:1]))
    sa = _ref_sa_scores(mu, n, prev, t, alpha, lam, lam_unc, k_unc=k_unc)
    return jnp.argmax(sa, axis=1).astype(jnp.int32)


def ref_fleet_step(mu, n, phat, pn, prev, t, arm, reward, progress, active,
                   alpha, lam, qos=None, default_arm=None, gamma=None,
                   optimistic=None, prior_mu=None, lam_unc=None, *, k_unc=1):
    """Fused update-then-select oracle for kernels.fleet_ucb.fleet_step:
    apply the interval's observation as a one-hot running-mean update
    (frozen where inactive), then pick the next SA-UCB arm from each
    controller's QoS feasible set. ``qos=None`` (or the per-controller
    sentinel ``qos < 0``) is the unconstrained lane; until the reference
    arm has a progress sample, every arm stays feasible. ``gamma`` (per
    controller; sentinel >= 1 = stationary) discounts the reward AND
    progress effective counts and shrinks stale means back to
    ``prior_mu`` at select time (w0 = 0.25, mirroring ucb_select);
    ``optimistic`` (sentinel >= 0.5 = optimistic init) selects the
    round-robin warm-up ablation while any arm is untried."""
    act = active.astype(mu.dtype)
    nn, k = mu.shape
    g = (jnp.ones((nn,), mu.dtype) if gamma is None
         else jnp.broadcast_to(jnp.asarray(gamma, mu.dtype), (nn,)))
    opt = (jnp.ones((nn,), mu.dtype) if optimistic is None
           else jnp.broadcast_to(jnp.asarray(optimistic, mu.dtype), (nn,)))
    prior = (jnp.zeros((nn, k), mu.dtype) if prior_mu is None
             else jnp.broadcast_to(jnp.asarray(prior_mu, mu.dtype), (nn, k)))
    lu = (None if lam_unc is None
          else jnp.broadcast_to(jnp.asarray(lam_unc, mu.dtype), (nn,)))
    onehot = (jnp.arange(k)[None, :] == arm[:, None]).astype(mu.dtype) * act[:, None]
    # decay-then-increment: the incremental mean over decayed counts IS
    # the discounted mean, so gamma only ever touches the counts (the
    # kernel mirrors this exactly)
    sw = (g < 1.0) & (act > 0.5)
    n2 = jnp.where(sw[:, None], n * g[:, None], n) + onehot
    mu2 = mu + onehot * (reward[:, None] - mu) / jnp.maximum(n2, 1.0)
    pn2 = jnp.where(sw[:, None], pn * g[:, None], pn) + onehot
    phat2 = phat + onehot * (progress[:, None] - phat) / jnp.maximum(pn2, 1.0)
    prev2 = jnp.where(act > 0.5, arm, prev).astype(jnp.int32)
    t2 = t + act
    w0 = 0.25
    shrunk = (n2 * mu2 + w0 * prior) / (n2 + w0)
    mu_eff = jnp.where((g < 1.0)[:, None], shrunk, mu2)
    sa = _ref_sa_scores(mu_eff, n2, prev2, t2, alpha, lam, lu, k_unc=k_unc)
    untried = n2 < 1.0
    warm = jnp.where(untried, 1e9 - jnp.arange(k)[None, :].astype(mu.dtype),
                     -1e9)
    rr = (opt < 0.5) & jnp.any(untried, axis=1)
    sa = jnp.where(rr[:, None], warm, sa)
    if qos is None:
        nxt = jnp.argmax(sa, axis=1).astype(jnp.int32)
        return mu2, n2, phat2, pn2, prev2, t2, nxt
    nn = mu.shape[0]
    q = jnp.broadcast_to(jnp.asarray(qos, jnp.float32), (nn,))
    da = jnp.broadcast_to(
        jnp.asarray(k - 1 if default_arm is None else default_arm, jnp.int32),
        (nn,),
    )
    pn_ref = jnp.take_along_axis(pn2, da[:, None], axis=1)[:, 0]
    phat_ref = jnp.take_along_axis(phat2, da[:, None], axis=1)[:, 0]
    p_ref = jnp.where(pn_ref > 0, phat_ref, jnp.inf)
    slowdown = 1.0 - phat2 / p_ref[:, None]
    feasible = (
        (q[:, None] < 0.0)
        | (pn_ref[:, None] < 1.0)
        | (pn2 < 1.0)
        | (slowdown <= q[:, None])
    )
    neg = jnp.finfo(sa.dtype).min
    masked = jnp.where(feasible, sa, neg)
    nxt = jnp.where(
        jnp.any(feasible, axis=1), jnp.argmax(masked, axis=1),
        jnp.argmax(sa, axis=1),
    ).astype(jnp.int32)
    return mu2, n2, phat2, pn2, prev2, t2, nxt


def ref_episode_scan(mu, n, phat, pn, prev, t, arm, reward, progress, active,
                     alpha, lam, qos=None, default_arm=None, gamma=None,
                     optimistic=None, prior_mu=None, lam_unc=None, *,
                     k_unc=1):
    """Oracle for kernels.episode_scan's trace-fed mode: a lax.scan of
    :func:`ref_fleet_step` over the T observation columns. Shares the
    per-step arithmetic expressions with the single-step oracle (the
    scan adds no new math), so the megakernel's episode output must be
    bit-identical to T repeated fused steps. Returns
    ``((mu, n, phat, pn, prev, t, next_arm), arms)`` with ``arms[t]``
    the arm held entering interval t."""

    def step(carry, cols):
        r, p, a = cols
        out = ref_fleet_step(
            carry[0], carry[1], carry[2], carry[3], carry[4], carry[5],
            carry[6], r, p, a, alpha, lam, qos=qos,
            default_arm=default_arm, gamma=gamma, optimistic=optimistic,
            prior_mu=prior_mu, lam_unc=lam_unc, k_unc=k_unc,
        )
        return out, carry[6]

    final, arms = jax.lax.scan(
        step, (mu, n, phat, pn, prev, t, arm), (reward, progress, active)
    )
    return final, arms


def ref_episode_scan_sim(mu, n, phat, pn, prev, t, arm,
                         env_rows: EnvRows, z, scan_env: ScanEnv,
                         alpha, lam, qos=None, default_arm=None, gamma=None,
                         optimistic=None, prior_mu=None, lam_unc=None, *,
                         t_start=0, drift_every=0, counter_obs=True, k_unc=1):
    """Oracle for kernels.episode_scan's sim-fused mode: per interval,
    derive the observation with the shared env helper
    (:func:`~repro.kernels.episode_scan.sim_env_obs` — THE one copy of
    the scanned env math; its independent cross-check is the
    live-streaming-vs-scanned parity tests, not this oracle), then apply
    :func:`ref_fleet_step`. Returns
    ``((mu, n, phat, pn, prev, t, next_arm), env_rows, arms)``."""
    z_e, z_uc, z_uu, z_p = z
    tt = z_e.shape[0]

    def step(carry, xs):
        state, env = carry
        idx, ze, zuc, zuu, zp = xs
        e_row, p_row, uc_row, uu_row, scal_row = phase_rows(
            scan_env, idx, t_start, drift_every
        )
        env2, r, p, a = sim_env_obs(
            env, state[6], ze, zuc, zuu, zp,
            e_row, p_row, uc_row, uu_row, scal_row, scan_env.scal[0, 5],
            counter_obs=counter_obs,
        )
        out = ref_fleet_step(
            state[0], state[1], state[2], state[3], state[4], state[5],
            state[6], r, p, a, alpha, lam, qos=qos,
            default_arm=default_arm, gamma=gamma, optimistic=optimistic,
            prior_mu=prior_mu, lam_unc=lam_unc, k_unc=k_unc,
        )
        return (out, env2), state[6]

    (final, env2), arms = jax.lax.scan(
        step, ((mu, n, phat, pn, prev, t, arm), env_rows),
        (jnp.arange(tt, dtype=jnp.int32), z_e, z_uc, z_uu, z_p),
    )
    return final, env2, arms
