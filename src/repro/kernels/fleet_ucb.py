"""Fused fleet SA-UCB select kernel (Pallas, TPU target).

The fleet control plane (repro.core.fleet) advances tens of thousands
of controllers per step (Aurora scale: 63,720). The select step is a
bandwidth-trivial but latency-sensitive fused op:

    SA-UCB[n, i] = mu[n,i] + alpha*sqrt(ln t_n / max(1, cnt[n,i]))
                   - lambda * 1{i != prev_n}
    arm[n] = argmax_i SA-UCB[n, i]

One program handles a BLOCK_N-controller stripe with all K arms resident
in VMEM; the argmax is computed via a max+iota-select (K is small, so
the reduction stays in registers). This keeps the whole fleet decision
at microseconds/step instead of a host-side loop.

Validated in interpret mode against kernels.ref.ref_fleet_select.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fleet_kernel(mu_ref, n_ref, prev_ref, t_ref, arm_ref, *, alpha, lam, k):
    mu = mu_ref[...]  # (BN, K)
    cnt = n_ref[...]
    prev = prev_ref[...]  # (BN,)
    t = jnp.maximum(t_ref[...], 2.0)  # (BN,)
    bonus = alpha * jnp.sqrt(jnp.log(t)[:, None] / jnp.maximum(cnt, 1.0))
    arms = jax.lax.broadcasted_iota(jnp.int32, mu.shape, 1)
    sa = mu + bonus - lam * (arms != prev[:, None]).astype(mu.dtype)
    best = jnp.max(sa, axis=1, keepdims=True)
    first_best = jnp.min(jnp.where(sa >= best, arms, k), axis=1)
    arm_ref[...] = first_best.astype(jnp.int32)


def fleet_select(
    mu: jax.Array,  # (N, K) empirical means
    n: jax.Array,  # (N, K) pull counts
    prev: jax.Array,  # (N,) previous arm
    t: jax.Array,  # (N,) step counters
    *,
    alpha: float = 0.2,
    lam: float = 0.05,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    nn, k = mu.shape
    block_n = min(block_n, nn)
    pad = (-nn) % block_n
    if pad:  # ragged fleets: pad to a whole stripe, slice after
        zp = lambda a, fill=0: jnp.concatenate(
            [a, jnp.full((pad, *a.shape[1:]), fill, a.dtype)], 0
        )
        out = fleet_select(
            zp(mu), zp(n, 1), zp(prev), zp(t, 2.0),
            alpha=alpha, lam=lam, block_n=block_n, interpret=interpret,
        )
        return out[:nn]
    kernel = functools.partial(_fleet_kernel, alpha=alpha, lam=lam, k=k)
    return pl.pallas_call(
        kernel,
        grid=(nn // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nn,), jnp.int32),
        interpret=interpret,
    )(mu, n, prev, t)
