"""Fused fleet SA-UCB kernels (Pallas, TPU target).

The fleet control plane (repro.core.fleet) advances tens of thousands
of controllers per decision interval (Aurora scale: 63,720). Two
kernels, both bandwidth-trivial but latency-sensitive:

- ``fleet_select``: the standalone SA-UCB argmax

      SA-UCB[n, i] = mu[n,i] + alpha_n*sqrt(ln t_n / max(1, cnt[n,i]))
                     - lambda_n * 1{i != prev_n}
      arm[n] = argmax_i SA-UCB[n, i]

- ``fleet_step``: the full per-interval controller step fused into one
  launch. At a decision boundary each controller holds the observation
  (reward, progress, active) from the interval that just ended for the
  arm it had selected; the kernel applies the mu/n/phat/pn running-mean
  update, advances prev/t, and selects the next arm from the updated
  state — update-then-select, one kernel instead of two plus the XLA
  scatter soup in between. The update half carries the nonstationary
  lane: rows with ``gamma < 1`` decay every arm's effective count
  (``n <- n * gamma`` before the new sample folds in) so the estimates
  track drifting workloads — reward AND progress statistics, so the QoS
  feasible set re-learns slowdowns after a phase change too. The select
  half carries the QoS feasible-set lane (§3.3): arms whose estimated
  slowdown vs the reference arm exceeds the per-controller ``qos``
  budget are masked out of the argmax, with untried arms (and every arm
  while the reference arm has no progress samples) staying feasible —
  optimism under uncertainty. Sliding-window rows additionally score a
  shrunk-to-prior mean (stale arms decay back to "untried"), and
  ``optimistic < 0.5`` rows run the round-robin warm-up ablation.

Hyperparameters ride as per-controller (N,) arrays (hyperparams-as-data:
a fleet can sweep alpha x lambda across its nodes, and mix QoS budgets —
sentinel ``qos < 0`` = unconstrained — sliding windows — sentinel
``gamma >= 1`` = stationary — and warm-up variants — sentinel
``optimistic >= 0.5`` = optimistic init — in the same launch; sentinel
lanes are bit-exact with the un-flagged kernel). One program handles a
BLOCK_N-controller stripe with all K arms resident in VMEM; K is small
so the argmax/one-hot/feasibility reductions stay in registers.

Factored action spaces (core x uncore ladders) flatten to the same
(N, K) state with ``K = k_core * k_unc`` and a STATIC ``k_unc``: flat
arm ``i`` decomposes as ``(i // k_unc, i % k_unc)`` and the switching
cost becomes ``lam * 1[core moved] + lam_unc * 1[uncore moved]`` via the
per-controller ``lam_unc`` lane (sentinel ``lam_unc < 0`` = one shared
penalty on any move). ``k_unc == 1`` compiles the VERBATIM scalar-ladder
expressions, so scalar fleets are bit-exact with the pre-factored
kernel, and mixed scalar/factored fleets share one launch through the
sentinel lane.

Validated in interpret mode against kernels.ref.ref_fleet_select /
ref_fleet_step on ragged fleet sizes (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _switch_penalty(arms, prev, lam, lam_unc, dtype, k_unc):
    """(BN, K) switching cost. Scalar ladders (``k_unc == 1``, a Python
    static) keep the single-penalty expression VERBATIM — the factored
    refactor must be bit-exact for every pre-existing fleet. Factored
    ladders decompose the flat index as (core, unc) = divmod(i, k_unc)
    and charge each dimension that moved; sentinel ``lam_unc < 0`` is a
    per-controller lane meaning "one shared penalty on any move" (how
    legacy checkpoints with no uncore lane replay inside a factored
    fleet)."""
    if k_unc == 1:
        return lam[:, None] * (arms != prev[:, None]).astype(dtype)
    shared = lam[:, None] * (arms != prev[:, None]).astype(dtype)
    core_moved = (arms // k_unc) != (prev[:, None] // k_unc)
    unc_moved = (arms % k_unc) != (prev[:, None] % k_unc)
    split = (lam[:, None] * core_moved.astype(dtype)
             + lam_unc[:, None] * unc_moved.astype(dtype))
    return jnp.where(lam_unc[:, None] < 0.0, shared, split)


def _ucb_bonus(cnt, tt, alpha, k_unc):
    """(BN, K) exploration bonus. Scalar ladders keep the per-arm joint
    bonus VERBATIM. Factored ladders use per-dimension bonuses over the
    MARGINAL pull counts (core marginal = sum over uncore settings and
    vice versa — exact sums of integer-valued float32 counts, so the
    reduction order cannot perturb bits): a core frequency explored
    under any uncore setting discounts that core's bonus everywhere,
    so the controller explores ~K_core + K_unc dimensions instead of
    K_core * K_unc joint cells."""
    lt = jnp.log(tt)[:, None]
    if k_unc == 1:
        return alpha[:, None] * jnp.sqrt(lt / jnp.maximum(cnt, 1.0))
    nn, k = cnt.shape
    m = cnt.reshape(nn, k // k_unc, k_unc)
    b_core = alpha[:, None] * jnp.sqrt(lt / jnp.maximum(m.sum(2), 1.0))
    b_unc = alpha[:, None] * jnp.sqrt(lt / jnp.maximum(m.sum(1), 1.0))
    return (b_core[:, :, None] + b_unc[:, None, :]).reshape(nn, k)


def _sa_scores(mu, cnt, prev, t, alpha, lam, lam_unc=None, *, k_unc=1):
    """(BN, K) SA-UCB scores; t is the post-update step counter and gets
    the same +1 lookahead the policy's select applies."""
    tt = jnp.maximum(t + 1.0, 2.0)
    bonus = _ucb_bonus(cnt, tt, alpha, k_unc)
    arms = jax.lax.broadcasted_iota(jnp.int32, mu.shape, 1)
    return mu + bonus - _switch_penalty(arms, prev, lam, lam_unc,
                                        mu.dtype, k_unc)


def _first_argmax(sa, k):
    arms = jax.lax.broadcasted_iota(jnp.int32, sa.shape, 1)
    best = jnp.max(sa, axis=1, keepdims=True)
    return jnp.min(jnp.where(sa >= best, arms, k), axis=1).astype(jnp.int32)


def _qos_feasible(phat, pn, qos, def_arm, arms):
    """(BN, K) QoS feasible mask {i : 1 - phat_i/phat[def] <= qos}.

    Mirrors policies.ucb_select bit-for-bit: the reference progress is
    the default (f_max) arm's estimate; until that arm has >= 1 progress
    sample — and for every still-untried arm — feasibility defaults to
    True (optimism under uncertainty), and sentinel ``qos < 0`` turns the
    constraint off for that controller entirely."""
    def_onehot = (arms == def_arm[:, None]).astype(phat.dtype)
    pn_ref = jnp.sum(pn * def_onehot, axis=1)
    phat_ref = jnp.sum(phat * def_onehot, axis=1)
    p_ref = jnp.where(pn_ref > 0, phat_ref, jnp.inf)
    slowdown = 1.0 - phat / p_ref[:, None]
    return (
        (qos[:, None] < 0.0)
        | (pn_ref[:, None] < 1.0)
        | (pn < 1.0)
        | (slowdown <= qos[:, None])
    )


def _feasible_argmax(sa, feasible, k):
    """policies._masked_argmax, rowwise: argmax over the feasible set,
    falling back to the unmasked argmax when nothing is feasible."""
    neg = jnp.finfo(sa.dtype).min
    masked = jnp.where(feasible, sa, neg)
    # float reduce instead of a bool jnp.any: TPU-safe either way
    has_f = jnp.max(jnp.where(feasible, 1.0, 0.0), axis=1) > 0.5
    return jnp.where(has_f, _first_argmax(masked, k), _first_argmax(sa, k))


def _fleet_select_kernel(mu_ref, n_ref, prev_ref, t_ref, alpha_ref, lam_ref,
                         lam_unc_ref, arm_ref, *, k, k_unc):
    sa = _sa_scores(
        mu_ref[...], n_ref[...], prev_ref[...], t_ref[...],
        alpha_ref[...], lam_ref[...], lam_unc_ref[...], k_unc=k_unc,
    )
    arm_ref[...] = _first_argmax(sa, k)


def fleet_step_math(
    mu, cnt, phat, pn, prev, t, arm, reward, prog, act,
    alpha, lam, qos, def_arm, g, opt, prior, lam_unc=None, *, k, k_unc=1,
):
    """The per-interval update-then-select dataflow on (BN, K)/(BN,)
    values — THE one copy of the fused-step arithmetic. Both the
    per-interval ``fleet_step`` kernel and the multi-interval episode
    megakernel (kernels.episode_scan) call this, so fused-vs-scanned
    bit-parity holds by construction: each scan iteration evaluates the
    identical expression tree a standalone ``fleet_step`` launch would.
    Returns (mu, n, phat, pn, prev, t, next_arm)."""
    arms = jax.lax.broadcasted_iota(jnp.int32, mu.shape, 1)
    # --- update: running means via a one-hot scatter (K stays in VMEM).
    # Sliding-window rows (gamma < 1) decay EVERY arm's effective count
    # by gamma before the new sample folds in; the incremental mean
    # mu + (r - mu)/(n*g + 1) IS the discounted mean (mu*n*g + r) /
    # (n*g + 1), so one expression — the policy's exact dataflow —
    # serves both lanes and gamma only ever touches the counts.
    # Inactive rows are frozen, so the decay is gated on the active
    # mask; stationary rows select the undecayed counts, staying
    # bit-exact with the undiscounted kernel.
    sw = (g < 1.0) & (act > 0.5)  # (BN,) discount applies this interval
    onehot = (arms == arm[:, None]).astype(mu.dtype) * act[:, None]
    r_col = reward[:, None]
    n2 = jnp.where(sw[:, None], cnt * g[:, None], cnt) + onehot
    mu2 = mu + onehot * (r_col - mu) / jnp.maximum(n2, 1.0)
    # progress statistics discount under gamma < 1 too (stale slowdown
    # estimates must not pin the QoS feasible set after a phase change)
    p_col = prog[:, None]
    pn2 = jnp.where(sw[:, None], pn * g[:, None], pn) + onehot
    phat2 = phat + onehot * (p_col - phat) / jnp.maximum(pn2, 1.0)
    prev2 = jnp.where(act > 0.5, arm, prev).astype(jnp.int32)
    t2 = t + act
    # --- select the next arm from the freshly updated state. Sliding-
    # window rows score a shrunk-to-prior mean (w0 = 0.25, mirroring
    # ucb_select's sliding-window optimism: stale arms decay back to
    # "untried", not "bad forever"); round-robin warm-up rows
    # (optimistic < 0.5) sweep untried arms in arm order first; and the
    # QoS feasible set restricts the argmax per controller.
    w0 = 0.25
    shrunk = (n2 * mu2 + w0 * prior) / (n2 + w0)
    mu_eff = jnp.where((g < 1.0)[:, None], shrunk, mu2)
    sa = _sa_scores(mu_eff, n2, prev2, t2, alpha, lam, lam_unc, k_unc=k_unc)
    untried = n2 < 1.0
    warm = jnp.where(untried, 1e9 - arms.astype(mu.dtype), -1e9)
    any_untried = jnp.max(jnp.where(untried, 1.0, 0.0), axis=1) > 0.5
    rr = (opt < 0.5) & any_untried
    sa = jnp.where(rr[:, None], warm, sa)
    feasible = _qos_feasible(phat2, pn2, qos, def_arm, arms)
    return mu2, n2, phat2, pn2, prev2, t2, _feasible_argmax(sa, feasible, k)


def _fleet_step_kernel(
    mu_ref, n_ref, phat_ref, pn_ref, prev_ref, t_ref,
    arm_ref, r_ref, prog_ref, act_ref, alpha_ref, lam_ref, qos_ref, def_ref,
    gamma_ref, opt_ref, prior_ref, lam_unc_ref,
    mu_o, n_o, phat_o, pn_o, prev_o, t_o, next_o, *, k, k_unc,
):
    out = fleet_step_math(
        mu_ref[...], n_ref[...], phat_ref[...], pn_ref[...],
        prev_ref[...], t_ref[...], arm_ref[...], r_ref[...], prog_ref[...],
        act_ref[...], alpha_ref[...], lam_ref[...], qos_ref[...], def_ref[...],
        gamma_ref[...], opt_ref[...], prior_ref[...], lam_unc_ref[...],
        k=k, k_unc=k_unc,
    )
    for ref, val in zip((mu_o, n_o, phat_o, pn_o, prev_o, t_o, next_o), out):
        ref[...] = val


def _pad(a, pad, fill=0):
    return jnp.concatenate(
        [a, jnp.full((pad, *a.shape[1:]), fill, a.dtype)], 0
    )


def fleet_select(
    mu: jax.Array,  # (N, K) empirical means
    n: jax.Array,  # (N, K) pull counts
    prev: jax.Array,  # (N,) previous arm
    t: jax.Array,  # (N,) step counters
    alpha: jax.Array,  # (N,) per-controller exploration coefficient
    lam: jax.Array,  # (N,) per-controller (core) switching penalty
    lam_unc: jax.Array = None,  # (N,) uncore penalty; sentinel < 0 = shared
    *,
    k_unc: int = 1,  # static uncore-ladder width (K = k_core * k_unc)
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    nn, k = mu.shape
    if lam_unc is None:
        lam_unc = jnp.full((nn,), -1.0, jnp.float32)
    block_n = min(block_n, nn)
    pad = (-nn) % block_n
    if pad:  # ragged fleets: pad to a whole stripe, slice after
        out = fleet_select(
            _pad(mu, pad), _pad(n, pad, 1), _pad(prev, pad), _pad(t, pad, 2.0),
            _pad(alpha, pad), _pad(lam, pad), _pad(lam_unc, pad, -1.0),
            k_unc=k_unc, block_n=block_n, interpret=interpret,
        )
        return out[:nn]
    kernel = functools.partial(_fleet_select_kernel, k=k, k_unc=k_unc)
    row = pl.BlockSpec((block_n,), lambda i: (i,))
    mat = pl.BlockSpec((block_n, k), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(nn // block_n,),
        in_specs=[mat, mat, row, row, row, row, row],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((nn,), jnp.int32),
        interpret=interpret,
    )(mu, n, prev, t, alpha, lam, lam_unc)


def fleet_step(
    mu: jax.Array,  # (N, K) empirical mean rewards
    n: jax.Array,  # (N, K) pull counts
    phat: jax.Array,  # (N, K) mean progress estimates
    pn: jax.Array,  # (N, K) progress-sample counts
    prev: jax.Array,  # (N,) previous arm (int32)
    t: jax.Array,  # (N,) step counters (f32)
    arm: jax.Array,  # (N,) arm each controller just ran (int32)
    reward: jax.Array,  # (N,) observed interval reward
    progress: jax.Array,  # (N,) observed interval progress
    active: jax.Array,  # (N,) f32 0/1: controller's job still running
    alpha: jax.Array,  # (N,)
    lam: jax.Array,  # (N,)
    qos: jax.Array,  # (N,) slowdown budget; sentinel < 0 = unconstrained
    def_arm: jax.Array,  # (N,) int32 QoS reference (f_max) arm
    gamma: jax.Array,  # (N,) sliding-window discount; sentinel >= 1 = stationary
    optimistic: jax.Array,  # (N,) sentinel >= 0.5 = optimistic init, else warm-up
    prior_mu: jax.Array,  # (N, K) optimistic prior the shrink decays toward
    lam_unc: jax.Array = None,  # (N,) uncore penalty; sentinel < 0 = shared
    *,
    k_unc: int = 1,  # static uncore-ladder width (K = k_core * k_unc)
    block_n: int = 1024,
    interpret: bool = False,
):
    """Fused update+select. Returns (mu, n, phat, pn, prev, t, next_arm)."""
    nn, k = mu.shape
    if lam_unc is None:
        lam_unc = jnp.full((nn,), -1.0, jnp.float32)
    block_n = min(block_n, nn)
    pad = (-nn) % block_n
    if pad:  # padded controllers are inactive: state rides through frozen
        out = fleet_step(
            _pad(mu, pad), _pad(n, pad, 1), _pad(phat, pad), _pad(pn, pad, 1),
            _pad(prev, pad), _pad(t, pad, 2.0), _pad(arm, pad),
            _pad(reward, pad), _pad(progress, pad), _pad(active, pad),
            _pad(alpha, pad), _pad(lam, pad), _pad(qos, pad, -1.0),
            _pad(def_arm, pad), _pad(gamma, pad, 1.0),
            _pad(optimistic, pad, 1.0), _pad(prior_mu, pad),
            _pad(lam_unc, pad, -1.0),
            k_unc=k_unc, block_n=block_n, interpret=interpret,
        )
        return tuple(o[:nn] for o in out)
    kernel = functools.partial(_fleet_step_kernel, k=k, k_unc=k_unc)
    row = pl.BlockSpec((block_n,), lambda i: (i,))
    mat = pl.BlockSpec((block_n, k), lambda i: (i, 0))
    f32 = jnp.float32
    return pl.pallas_call(
        kernel,
        grid=(nn // block_n,),
        in_specs=[mat, mat, mat, mat, row, row, row, row, row, row, row, row,
                  row, row, row, row, mat, row],
        out_specs=(mat, mat, mat, mat, row, row, row),
        out_shape=(
            jax.ShapeDtypeStruct((nn, k), f32),
            jax.ShapeDtypeStruct((nn, k), f32),
            jax.ShapeDtypeStruct((nn, k), f32),
            jax.ShapeDtypeStruct((nn, k), f32),
            jax.ShapeDtypeStruct((nn,), jnp.int32),
            jax.ShapeDtypeStruct((nn,), f32),
            jax.ShapeDtypeStruct((nn,), jnp.int32),
        ),
        interpret=interpret,
    )(mu, n, phat, pn, prev, t, arm, reward, progress, active, alpha, lam,
      qos, def_arm, gamma, optimistic, prior_mu, lam_unc)
