"""Pallas kernels for the fleet-control hot paths (+ XLA fallbacks).

The paper's control plane is tiny math on huge batches — (N, K)
controller statistics for fleets of N GPUs over K frequency arms — so
the hot-path cost is launches and memory traffic, not FLOPs. Three
kernel families cover it:

- ``fleet_ucb`` — the per-interval fused update-then-select step (one
  launch per decision interval for the whole fleet, every EnergyUCB
  variant — QoS, sliding-window, warm-up — as per-controller lanes).
- ``episode_scan`` — the megakernel: T decision intervals per launch
  with the controller state resident in VMEM, trace-fed or sim-fused
  (the SimBackend environment stepped in-kernel). One launch per
  EPISODE instead of per interval.
- ``flash_attention`` / ``ssd_scan`` — the workload-side kernels the
  energy model's roofline cells are calibrated against.

Factored (core x uncore) ladders ride the SAME kernels: the flat arm
index ``i = core * k_unc + unc`` keeps every (N, K) state array and
trace format at ``K = k_core * k_unc``, and the static ``k_unc``
selects per-dimension UCB bonuses (marginal pull counts) and split
switching penalties (``lam``/``lam_unc`` lanes; per-controller
sentinel ``lam_unc < 0`` = one shared penalty). ``k_unc == 1`` is a
compile-time branch back to the scalar expressions verbatim, so the
degenerate case is bit-exact with the pre-factored kernels — there is
ONE copy of the controller arithmetic (``fleet_ucb.fleet_step_math``),
shared by the per-step kernel, the megakernel, the XLA fallbacks, and
mirrored only in the ``ref`` oracles.

``ops`` is the only entry point callers should use: it pads/broadcasts
per-controller lanes, jits, and dispatches Pallas-on-TPU /
interpret-mode-on-CPU (tests) / pure-XLA fallbacks (CPU production)
per call. ``ref`` holds the pure-jnp oracles every kernel is
bit-tested against (tests/test_kernels.py, tests/test_episode_scan.py,
tests/test_factored.py).

repro-lint guards this package statically (scripts/repro_lint.py):
RPL001 rejects one-sided ``.at[...]`` scatters (parity demands the
shared select+onehot expressions), RPL002 rejects ``unroll=`` on the
scan fallbacks and donation of the aliased ``env_rows`` operand, and
RPL003 holds every kernel/dispatcher/oracle signature here to the full
``PolicyParams`` lane set registered in repro/analysis/lanes.py.
"""
