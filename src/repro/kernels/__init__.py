"""Pallas kernels for the fleet-control hot paths (+ XLA fallbacks).

The paper's control plane is tiny math on huge batches — (N, K)
controller statistics for fleets of N GPUs over K frequency arms — so
the hot-path cost is launches and memory traffic, not FLOPs. Three
kernel families cover it:

- ``fleet_ucb`` — the per-interval fused update-then-select step (one
  launch per decision interval for the whole fleet, every EnergyUCB
  variant — QoS, sliding-window, warm-up — as per-controller lanes).
- ``episode_scan`` — the megakernel: T decision intervals per launch
  with the controller state resident in VMEM, trace-fed or sim-fused
  (the SimBackend environment stepped in-kernel). One launch per
  EPISODE instead of per interval.
- ``flash_attention`` / ``ssd_scan`` — the workload-side kernels the
  energy model's roofline cells are calibrated against.

``ops`` is the only entry point callers should use: it pads/broadcasts
per-controller lanes, jits, and dispatches Pallas-on-TPU /
interpret-mode-on-CPU (tests) / pure-XLA fallbacks (CPU production)
per call. ``ref`` holds the pure-jnp oracles every kernel is
bit-tested against (tests/test_kernels.py, tests/test_episode_scan.py).
"""
