"""Analytic roofline model per (arch x shape x mesh).

The container cannot measure wall time on TPU, and XLA's
``cost_analysis()`` counts while/scan bodies once (verified
empirically), so the three roofline terms are derived analytically from
the configs, cross-checked against the compiled artifact:

  compute   = exec_flops  / (chips * PEAK_FLOPS)
  memory    = hbm_bytes   / (chips * HBM_BW)
  collective= coll_bytes  / (chips * ICI_BW)   [HLO-parsed, trip-corrected]

Quantities are *global* (all chips) and divided by chip count, i.e.
perfectly-balanced SPMD is assumed (true for these shardings).

Approximations (documented, consistent across cells so the hillclimb
signal is real):
  - exec_flops = MODEL_FLOPS x remat factor (full remat recomputes the
    layer fwd once during bwd => 4/3 on layer flops).
  - hbm_bytes: weight reads per pass (TP-sharded working copy),
    activation checkpoint write+read, optimizer state r/w (train);
    KV/state cache read+write (decode); logits fp32 traffic.
  - collective term uses the HLO-extracted bytes (repro.roofline.hlo_parse),
    which is the *schedule actually compiled*, not a model.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.base import SHAPES, ArchConfig, LayoutConfig, ShapeConfig


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e-like"
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # B/s / chip
    ici_bw: float = 50e9  # B/s / link / chip
    hbm_per_chip: float = 16e9


HW = Hardware()


def _attn_flops(b, sq, skv, h, hd, causal):
    f = 4.0 * b * sq * skv * h * hd
    return f / 2 if causal else f


def _ssd_flops_per_token(cfg: ArchConfig) -> float:
    """Per-token fwd flops of one mamba2 block (excl. in/out proj)."""
    Q, N, H, P = cfg.ssm_chunk, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    DI = cfg.d_inner
    intra = 2.0 * Q * N + 2.0 * Q * H * P  # G kernel + y_diag (amortized /token)
    states = 4.0 * N * H * P  # states + y_off
    conv = 2.0 * cfg.ssm_conv * (DI + 2 * N)
    return intra + states + conv


def _fwd_flops(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Global forward flops, split into components."""
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    T = B * S if kind != "decode" else B  # tokens processed
    D, V = cfg.d_model, cfg.vocab_size
    H, HD = cfg.num_heads, cfg.head_dim

    comp: Dict[str, float] = {}
    # embedding lookup is gather (no flops); logits matmul:
    logit_tokens = T if kind == "train" else B
    comp["logits"] = 2.0 * logit_tokens * D * V

    def attn_total(n_attn_layers):
        if kind == "train":
            return n_attn_layers * _attn_flops(B, S, S, H, HD, True)
        if kind == "prefill":
            return n_attn_layers * _attn_flops(B, S, S, H, HD, True)
        return n_attn_layers * _attn_flops(B, 1, S, H, HD, False)

    if cfg.family in ("dense", "vlm"):
        n_mat = cfg.param_count() - V * D * (1 if cfg.tie_embeddings else 2)
        comp["matmul"] = 2.0 * n_mat * T
        comp["attn"] = attn_total(cfg.num_layers)
    elif cfg.family == "moe":
        n_act = cfg.active_param_count() - V * D * (1 if cfg.tie_embeddings else 2)
        comp["matmul"] = 2.0 * n_act * T
        comp["attn"] = attn_total(cfg.num_layers)
        # dispatch/combine einsums: 2 x (T x E x C_slot x D) x top_k slots
        C = max(4, min(S, math.ceil(S * cfg.moe_capacity_factor / cfg.moe_num_experts)))
        if kind == "decode":
            C = 1
        comp["moe_dispatch"] = (
            2 * 2.0 * T * cfg.moe_num_experts * C * D * cfg.moe_top_k * cfg.n_moe_layers()
        )
    elif cfg.family == "ssm":
        n_mat = cfg.param_count() - 2 * V * D
        comp["matmul"] = 2.0 * n_mat * T
        comp["ssd"] = cfg.num_layers * T * _ssd_flops_per_token(cfg)
        if kind == "decode":
            comp["ssd"] = cfg.num_layers * T * 4.0 * cfg.ssm_state * cfg.ssm_heads * cfg.ssm_head_dim
    elif cfg.family == "hybrid":
        from repro.models.hybrid import n_attn_applications

        n_mat = cfg.param_count() - 2 * V * D
        comp["matmul"] = 2.0 * n_mat * T
        comp["ssd"] = cfg.num_layers * T * _ssd_flops_per_token(cfg)
        if kind == "decode":
            comp["ssd"] = cfg.num_layers * T * 4.0 * cfg.ssm_state * cfg.ssm_heads * cfg.ssm_head_dim
        comp["attn"] = attn_total(n_attn_applications(cfg))
    elif cfg.family == "encdec":
        n_mat = cfg.param_count() - 2 * V * D
        comp["matmul"] = 2.0 * n_mat * T
        if kind == "decode":
            comp["attn"] = _attn_flops(B, 1, S, H, HD, False) * cfg.dec_layers
            comp["attn"] += _attn_flops(B, 1, cfg.decode_enc_len, H, HD, False) * cfg.dec_layers
            # encoder does not run at decode; subtract its matmuls
            enc_params = cfg.enc_layers * (
                cfg.d_model * cfg.num_heads * cfg.head_dim * 2
                + 2 * cfg.d_model * cfg.num_kv_heads * cfg.head_dim
                + (3 if cfg.mlp_gated else 2) * cfg.d_model * cfg.d_ff + 2 * cfg.d_model
            )
            comp["matmul"] = 2.0 * (n_mat - enc_params) * T
        else:
            comp["attn"] = _attn_flops(B, S, S, H, HD, False) * cfg.enc_layers
            comp["attn"] += _attn_flops(B, S, S, H, HD, True) * cfg.dec_layers
            comp["attn"] += _attn_flops(B, S, S, H, HD, False) * cfg.dec_layers  # cross
    return comp


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """The 'useful' MODEL_FLOPS convention: 6*N*D (train) / 2*N*D (fwd),
    N = active params, D = tokens; attention terms included."""
    comp = _fwd_flops(cfg, shape)
    fwd = sum(comp.values())
    return 3.0 * fwd if shape.kind == "train" else fwd


def exec_flops(cfg: ArchConfig, shape: ShapeConfig, layout: LayoutConfig) -> float:
    comp = _fwd_flops(cfg, shape)
    fwd = sum(comp.values())
    if shape.kind != "train":
        return fwd
    layer_fwd = fwd - comp.get("logits", 0.0)
    remat_extra = layer_fwd if layout.remat == "full" else 0.0
    return 3.0 * fwd + remat_extra


def hbm_bytes(cfg: ArchConfig, shape: ShapeConfig, layout: LayoutConfig,
              n_chips: int, tp: int) -> float:
    """Global HBM traffic per step (sum over chips)."""
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    pbytes = cfg.param_count() * 2.0  # bf16
    act_bytes_token = 2.0 * D
    kind = shape.kind
    if kind == "train":
        n_micro = max(1, (B // layout.microbatch) if layout.microbatch else 1)
        passes = 3 if layout.remat == "full" else 2
        # per pass every chip reads its TP shard of every weight, i.e. the
        # data-parallel group collectively reads (dp_degree x) full weights
        weights = pbytes * passes * n_micro * (n_chips / tp)
        opt_bytes = cfg.param_count() * (
            2.0 + 2 * {"float32": 4.0, "bfloat16": 2.0}[layout.opt_dtype] * 2 + 2.0
        )
        nl = cfg.num_layers
        acts = 2.0 * nl * B * S * act_bytes_token  # checkpoint write+read
        logits_b = 4.0 * B * S * cfg.vocab_size / max(1, n_micro) * n_micro
        return weights + opt_bytes + acts + logits_b
    if kind == "prefill":
        acts = 2.0 * cfg.num_layers * B * S * act_bytes_token
        cache = _cache_bytes(cfg, B, S)
        return pbytes + acts + cache
    # decode: read all weights (active for MoE) + cache r/w
    active = cfg.active_param_count() * 2.0
    cache = _cache_bytes(cfg, B, S) * 1.0  # read once (+ tiny update)
    return active + cache + 4.0 * B * cfg.vocab_size


def _cache_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    if cfg.family == "ssm":
        return 4.0 * cfg.num_layers * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
    if cfg.family == "hybrid":
        from repro.models.hybrid import n_attn_applications

        ssm = 4.0 * cfg.num_layers * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        kv = 2.0 * 2 * n_attn_applications(cfg) * B * S * cfg.num_kv_heads * cfg.head_dim
        return ssm + kv
    n_layers = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    kv = 2.0 * 2 * n_layers * B * S * cfg.num_kv_heads * cfg.head_dim
    if cfg.family == "encdec":
        kv += 2.0 * 2 * cfg.dec_layers * B * cfg.decode_enc_len * cfg.num_kv_heads * cfg.head_dim
    return kv


def roofline_terms(
    cfg: ArchConfig,
    shape_name: str,
    *,
    n_chips: int = 256,
    tp: int = 16,
    collective_bytes_per_dev: Optional[float] = None,
    hw: Hardware = HW,
) -> Dict[str, float]:
    shape = SHAPES[shape_name]
    layout = cfg.layout_for(shape_name)
    ef = exec_flops(cfg, shape, layout)
    mf = model_flops(cfg, shape)
    hb = hbm_bytes(cfg, shape, layout, n_chips, tp)
    t_compute = ef / (n_chips * hw.peak_flops)
    t_memory = hb / (n_chips * hw.hbm_bw)
    out = {
        "model_flops": mf,
        "exec_flops": ef,
        "hbm_bytes": hb,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
    }
    if collective_bytes_per_dev is not None:
        out["coll_bytes_per_dev"] = collective_bytes_per_dev
        out["t_collective_s"] = collective_bytes_per_dev / hw.ici_bw
    terms = {k: v for k, v in out.items() if k.startswith("t_")}
    out["bottleneck"] = max(terms, key=terms.get)[2:-2] if terms else "?"
    step = max(terms.values())
    out["step_time_bound_s"] = step
    # roofline fraction: how close the step is to its FUNDAMENTAL roof —
    # compute for train/prefill, memory-streaming for decode; collectives
    # are overhead to be engineered away, not a roof.
    hard_roof = max(t_compute, t_memory)
    out["roofline_fraction"] = hard_roof / step if step > 0 else 0.0
    out["compute_fraction"] = t_compute / step if step > 0 else 0.0
    out["mfu_bound"] = (mf / (n_chips * hw.peak_flops)) / step if step > 0 else 0.0
    return out


def analytic_cell(arch_cfg: ArchConfig, shape_name: str, **kw):
    return roofline_terms(arch_cfg, shape_name, **kw)
