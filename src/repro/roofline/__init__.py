from repro.roofline.hlo_parse import collective_bytes_from_hlo
from repro.roofline.analysis import analytic_cell, roofline_terms, HW

__all__ = ["collective_bytes_from_hlo", "analytic_cell", "roofline_terms", "HW"]
