"""HLO-text collective accounting with while-loop trip-count attribution.

``compiled.as_text()`` (post-SPMD-partitioning HLO) contains every
collective with explicit partitioned shapes, but ops inside a
``while`` body (lax.scan over layers / microbatches / kv-chunks) appear
ONCE. We reconstruct multipliers:

  1. split the module into named computations;
  2. find each ``while`` op, its body= and condition= computations;
  3. recover the trip count from the condition computation's comparison
     constant (scan lowers to a monotone counter vs. a constant bound);
  4. total bytes = sum over collectives of op_bytes x product of
     enclosing-while trip counts.

Byte size of a collective = bytes of its (tuple) output shape — the
payload actually moved per execution per device (all-reduce: payload in
= out; all-gather: output is the gathered buffer; reduce-scatter: use
input, i.e. max(in, out)).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->", re.M)
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(sig: str) -> int:
    """Bytes of a (possibly tuple) HLO shape string prefix."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of instruction lines."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith((" ", "\t")) and ("->" in line or stripped.startswith(("ENTRY", "%"))) and stripped.endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            current = m.group(1) if m else None
            comps.setdefault(current, [])
        elif current is not None and stripped != "}":
            comps[current].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Heuristic: largest s32/u32 constant in the condition computation.
    JAX scans lower to `compare(i, c)` with c = length."""
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"[su]32\[\]\s+constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _while_graph(comps: Dict[str, List[str]]):
    """For each computation, the (body, trip) pairs of whiles it contains."""
    out = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln or ln.startswith("while") or "= while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb:
                    trip = _trip_count(comps.get(mc.group(1), [])) if mc else 1
                    out[name].append((mb.group(1), trip))
    return out


def _multipliers(comps, entry: str) -> Dict[str, int]:
    """computation -> product of enclosing while trip counts (from entry)."""
    wg = _while_graph(comps)
    mult = {entry: 1}
    stack = [entry]
    # also follow plain calls/fusions so nested computations inherit
    call_re = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
    while stack:
        cur = stack.pop()
        m = mult[cur]
        for body, trip in wg.get(cur, []):
            nm = m * trip
            if mult.get(body, 0) < nm:
                mult[body] = nm
                stack.append(body)
        for ln in comps.get(cur, []):
            if " while(" in ln:
                continue
            for callee in call_re.findall(ln):
                if mult.get(callee, 0) < m:
                    mult[callee] = m
                    stack.append(callee)
    return mult


def _entry_name(hlo: str, comps) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m:
        return m.group(1)
    return next(iter(comps)) if comps else None


def collective_bytes_from_hlo(hlo: str) -> Dict[str, object]:
    """Returns {'per_kind': {kind: bytes}, 'total': int, 'count': int,
    'ops': [(kind, bytes, mult)]} — bytes are per-device per-step."""
    comps = split_computations(hlo)
    entry = _entry_name(hlo, comps)
    mult = _multipliers(comps, entry) if entry else {}
    per_kind: Dict[str, float] = defaultdict(float)
    ops = []
    count = 0
    for name, lines in comps.items():
        m = mult.get(name, 1)
        for ln in lines:
            for kind in _COLLECTIVES:
                # match `= shape kind(` — avoid all-reduce-start dupes
                if f" {kind}(" in ln and "-done" not in ln:
                    sig = ln.split("=", 1)[0] if "=" in ln else ln
                    # shape is on the RHS before the op name
                    rhs = ln.split("=", 1)[1] if "=" in ln else ln
                    sig = rhs.split(kind + "(")[0]
                    b = _shape_bytes(sig)
                    per_kind[kind] += b * m
                    ops.append((kind, b, m))
                    count += 1
                    break
    return {
        "per_kind": dict(per_kind),
        "total": int(sum(per_kind.values())),
        "count": count,
        "ops": ops,
    }
