"""Trainer: the fault-tolerant, energy-aware training loop.

Composition of the substrates:
  - jitted microbatched train step (repro.train.train_step)
  - deterministic restartable data pipeline (repro.train.data)
  - atomic/async checkpointing + restore-on-restart (repro.train.checkpoint)
  - EnergyUCB controller in the loop (repro.energy.EnergyController
    over any EnergyBackend) — one decision per step, real step
    executed, telemetry read back as counter deltas
  - fault injection + automatic restart (repro.train.fault)
  - straggler watch: flags steps whose wall time exceeds the trailing
    median by a configurable factor (on real fleets this feeds the
    coordinated controller / preemption logic).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.api import ModelBundle
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticTokens, make_pipeline
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = True
    seed: int = 0
    log_every: int = 10
    straggler_factor: float = 2.0


class Trainer:
    def __init__(
        self,
        bundle: ModelBundle,
        shape: ShapeConfig,
        opt_cfg: Optional[AdamWConfig] = None,
        tcfg: Optional[TrainerConfig] = None,
        controller=None,
        data: Optional[SyntheticTokens] = None,
    ):
        self.bundle = bundle
        self.shape = shape
        self.tcfg = tcfg or TrainerConfig()
        self.opt_cfg = opt_cfg or AdamWConfig(
            moment_dtype=bundle.layout.opt_dtype,
            total_steps=self.tcfg.total_steps,
            warmup_steps=max(1, self.tcfg.total_steps // 20),
        )
        self.energy = controller
        self.data = data or make_pipeline(bundle.cfg, shape, seed=self.tcfg.seed)
        self._step_fn = jax.jit(
            make_train_step(bundle, self.opt_cfg, bundle.layout), donate_argnums=(0, 1)
        )
        self.params = None
        self.opt_state = None
        self.step = 0
        self.metrics: List[Dict[str, float]] = []
        self.straggler_events: List[int] = []

    # ------------------------------------------------------------------
    def init_or_restore(self):
        key = jax.random.key(self.tcfg.seed)
        self.params = self.bundle.init(key)
        self.opt_state = adamw_init(self.opt_cfg, self.params)
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is not None:
            step, state, extra = ckpt.restore(
                self.tcfg.ckpt_dir, {"p": self.params, "o": self.opt_state}
            )
            self.params, self.opt_state = state["p"], state["o"]
            self.step = step
            self.data.restore(extra["data"])
        return self.step

    def save(self):
        fn = ckpt.async_save if self.tcfg.async_ckpt else ckpt.save
        fn(
            self.tcfg.ckpt_dir,
            self.step,
            {"p": self.params, "o": self.opt_state},
            extra={"data": self.data.state()},
        )

    # ------------------------------------------------------------------
    def run(self, fail_at: Optional[Callable[[int], bool]] = None) -> Dict[str, Any]:
        if self.params is None:
            self.init_or_restore()
        times: List[float] = []
        while self.step < self.tcfg.total_steps:
            if fail_at is not None and fail_at(self.step):
                raise RuntimeError(f"injected failure at step {self.step}")
            batch = self.data.batch_at(self.step)
            self.data.step = self.step + 1

            def work():
                nonlocal_metrics = {}
                self.params, self.opt_state, m = self._step_fn(
                    self.params, self.opt_state, batch
                )
                return m

            t0 = time.perf_counter()
            if self.energy is not None:
                out = self.energy.step(work)
                m = out["work"]
            else:
                m = work()
            wall = time.perf_counter() - t0
            times.append(wall)
            med = float(np.median(times[-20:]))
            if len(times) > 5 and wall > self.tcfg.straggler_factor * med:
                self.straggler_events.append(self.step)
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or self.step == 1:
                self.metrics.append(
                    {"step": self.step, "loss": float(m["loss"]),
                     "grad_norm": float(m["grad_norm"]), "wall_s": wall}
                )
            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
        self.save()
        ckpt.wait_for_saves(self.tcfg.ckpt_dir)
        out = {
            "final_step": self.step,
            "metrics": self.metrics,
            "stragglers": self.straggler_events,
        }
        if self.energy is not None:
            out["energy"] = self.energy.summary()
        return out


def run_with_restarts(make_trainer: Callable[[], Trainer],
                      fail_at_steps: List[int], max_restarts: int = 5):
    """Fault-tolerance driver: inject failures, restart from the latest
    checkpoint, continue to completion. Returns (result, n_restarts)."""
    fails = set(fail_at_steps)
    fired = set()
    restarts = 0
    while True:
        tr = make_trainer()
        tr.init_or_restore()

        def fail_at(step, _fired=fired, _fails=fails):
            return step in _fails and step not in _fired
        try:
            res = tr.run(fail_at=fail_at)
            return res, restarts
        except RuntimeError as e:
            if "injected failure" not in str(e) or restarts >= max_restarts:
                raise
            fired.add(tr.step)
            restarts += 1
