"""Deterministic, restartable synthetic token pipeline.

Batches are a pure function of (seed, step, host slice): any worker can
reconstruct any batch, so restart/elastic-rescale only needs the step
counter (carried in the checkpoint manifest). Tokens follow a Zipf-ish
marginal with short-range structure so losses move during the example
training runs (pure uniform tokens give a flat loss).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1


class SyntheticTokens:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.per_host = cfg.global_batch // cfg.host_count
        self.step = 0
        # fixed Zipf-ish unigram table + a bigram "successor" table for
        # learnable structure
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._succ = rng.integers(0, cfg.vocab_size, size=(cfg.vocab_size,), dtype=np.int64)

    # ------------------------------------------------------------------
    def state(self) -> Dict[str, Any]:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: Dict[str, Any]):
        assert state["seed"] == self.cfg.seed, "data seed mismatch on restore"
        self.step = int(state["step"])

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_index])
        )
        b = self.per_host
        toks = rng.choice(c.vocab_size, size=(b, c.seq_len + 1), p=self._probs)
        # every other position is the deterministic successor: learnable
        toks[:, 1::2] = self._succ[toks[:, 0:-1:2]]
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __next__(self) -> Dict[str, np.ndarray]:
        out = self.batch_at(self.step)
        self.step += 1
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self


def make_pipeline(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0,
                  host_index: int = 0, host_count: int = 1) -> SyntheticTokens:
    return SyntheticTokens(
        DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            seed=seed,
            host_index=host_index,
            host_count=host_count,
        )
    )
