"""Microbatched train step: grad-accumulation lax.scan over microbatches,
then one AdamW update. Accumulation dtype is configurable (bf16 for the
400B cells). The step fn is pure and jit/pjit-friendly; shardings are
applied by the caller (launcher / dry-run) via in_shardings +
with_sharding_constraint inside the model.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayoutConfig
from repro.models.api import ModelBundle
from repro.train.optimizer import AdamWConfig, adamw_update

PyTree = Any


def _microbatch(batch: PyTree, n_micro: int) -> PyTree:
    def r(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} % n_micro {n_micro}"
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    return jax.tree.map(r, batch)


def make_train_step(
    bundle: ModelBundle,
    opt_cfg: AdamWConfig,
    layout: LayoutConfig = None,
) -> Callable:
    layout = layout or bundle.layout

    def train_step(params, opt_state, batch):
        loss_fn = lambda p, b: bundle.loss(p, b)
        accum_dt = jnp.dtype(layout.grad_accum_dtype)

        mb = layout.microbatch
        gb = jax.tree.leaves(batch)[0].shape[0]
        n_micro = gb // mb if mb else 1
        if n_micro > 1:
            mbatch = _microbatch(batch, n_micro)

            def accum(carry, micro):
                g_acc, loss_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, micro)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dt), g_acc, g
                )
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dt), params)
            (grads, loss_sum), _ = jax.lax.scan(accum, (g0, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        new_params, new_opt, om = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(bundle: ModelBundle) -> Callable:
    def eval_step(params, batch):
        return bundle.loss(params, batch)

    return eval_step
