"""Checkpointing: atomic, async-capable, elastic.

- Leaves are gathered to host and written as .npy under a tmp dir, then
  atomically renamed to step_XXXXXXXX (a crash never leaves a partial
  checkpoint visible).
- ``restore`` accepts target shardings for a DIFFERENT mesh than the one
  that saved (elastic restart: N -> M chips): arrays are saved unsharded
  and re-placed per the new sharding.
- ``async_save`` runs serialization on a background thread so the train
  loop keeps stepping (double-buffered: we snapshot to host first).
- Data-pipeline state (step counter, rng) rides in the manifest so a
  restart is bit-identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _paths(tree: PyTree):
    # jax.tree.flatten_with_path only exists in newer JAX; tree_util has
    # carried the same API for every version this repo supports.
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in flat
    ]


def save(
    ckpt_dir: str,
    step: int,
    state: PyTree,
    extra: Optional[Dict[str, Any]] = None,
    keep_last: int = 3,
) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    leaves, treedef = _flatten(host)
    names = [f"leaf_{i:05d}.npy" for i in range(len(leaves))]
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    for n, leaf in zip(names, leaves):
        np.save(os.path.join(tmp, n), np.asarray(leaf))
    manifest = {
        "step": int(step),
        "leaves": names,
        "paths": _paths(host),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic visibility
    _prune(ckpt_dir, keep_last)
    return final


_ASYNC: Dict[str, threading.Thread] = {}


def async_save(ckpt_dir: str, step: int, state: PyTree, extra=None, keep_last=3):
    """Snapshot to host synchronously (cheap), serialize on a thread."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    prev = _ASYNC.get(ckpt_dir)
    if prev is not None and prev.is_alive():
        prev.join()  # backpressure: one in-flight save per dir
    th = threading.Thread(
        target=save, args=(ckpt_dir, step, host, extra, keep_last), daemon=True
    )
    th.start()
    _ASYNC[ckpt_dir] = th
    return th


def wait_for_saves(ckpt_dir: Optional[str] = None):
    for d, th in list(_ASYNC.items()):
        if ckpt_dir is None or d == ckpt_dir:
            th.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, d))
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    like: PyTree,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
) -> Tuple[int, PyTree, Dict[str, Any]]:
    """Restore into the structure of ``like``. ``shardings`` (optional,
    same structure or per-leaf NamedShardings) re-places leaves on the
    CURRENT mesh — elastic restart across mesh sizes."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves = [np.load(os.path.join(d, n)) for n in manifest["leaves"]]
    _, treedef = _flatten(like)
    state = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
            state,
            shardings,
        )
    return manifest["step"], state, manifest.get("extra", {})


def _prune(ckpt_dir: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, d))
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
