"""Checkpointing: atomic, async-capable, elastic.

- Leaves are gathered to host and written as .npy under a tmp dir, then
  atomically renamed to step_XXXXXXXX (a crash never leaves a partial
  checkpoint visible).
- ``restore`` accepts target shardings for a DIFFERENT mesh than the one
  that saved (elastic restart: N -> M chips): arrays are saved unsharded
  and re-placed per the new sharding.
- ``async_save`` runs serialization on a background thread so the train
  loop keeps stepping (double-buffered: we snapshot to host first).
- Data-pipeline state (step counter, rng) rides in the manifest so a
  restart is bit-identical.
- ``restore_stripe`` rebuilds one node-stripe [lo, hi) of a striped
  fleet from per-stripe checkpoint directories — including a stripe
  layout DIFFERENT from the one that saved (elastic membership change:
  the new stripe is stitched row-wise out of the old stripes at their
  latest COMMON step). States split into a ``"striped"`` subtree
  (leaves with a leading node axis, sliceable) and a ``"host"`` subtree
  (stripe-independent leaves like RNG keys and step counters, identical
  across hosts at a common step), so stitching needs no shape
  heuristics.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _paths(tree: PyTree):
    # jax.tree.flatten_with_path only exists in newer JAX; tree_util has
    # carried the same API for every version this repo supports.
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in flat
    ]


def save(
    ckpt_dir: str,
    step: int,
    state: PyTree,
    extra: Optional[Dict[str, Any]] = None,
    keep_last: int = 3,
) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    leaves, treedef = _flatten(host)
    names = [f"leaf_{i:05d}.npy" for i in range(len(leaves))]
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    for n, leaf in zip(names, leaves):
        np.save(os.path.join(tmp, n), np.asarray(leaf))
    manifest = {
        "step": int(step),
        "leaves": names,
        "paths": _paths(host),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic visibility
    _prune(ckpt_dir, keep_last)
    return final


_ASYNC: Dict[str, threading.Thread] = {}


def async_save(ckpt_dir: str, step: int, state: PyTree, extra=None, keep_last=3):
    """Snapshot to host synchronously (cheap), serialize on a thread."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    prev = _ASYNC.get(ckpt_dir)
    if prev is not None and prev.is_alive():
        prev.join()  # backpressure: one in-flight save per dir
    th = threading.Thread(
        target=save, args=(ckpt_dir, step, host, extra, keep_last), daemon=True
    )
    th.start()
    _ASYNC[ckpt_dir] = th
    return th


def wait_for_saves(ckpt_dir: Optional[str] = None):
    for d, th in list(_ASYNC.items()):
        if ckpt_dir is None or d == ckpt_dir:
            th.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, d))
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    like: PyTree,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
) -> Tuple[int, PyTree, Dict[str, Any]]:
    """Restore into the structure of ``like``. ``shardings`` (optional,
    same structure or per-leaf NamedShardings) re-places leaves on the
    CURRENT mesh — elastic restart across mesh sizes."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves = [np.load(os.path.join(d, n)) for n in manifest["leaves"]]
    _, treedef = _flatten(like)
    state = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
            state,
            shardings,
        )
    return manifest["step"], state, manifest.get("extra", {})


def _prune(ckpt_dir: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, d))
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


# ---------------------------------------------------------------------------
# stripe checkpoints: per-host directories under one fleet root
# ---------------------------------------------------------------------------


def stripe_dir(root: str, lo: int, hi: int) -> str:
    """The checkpoint directory for the node stripe [lo, hi)."""
    return os.path.join(root, f"stripe_{int(lo):06d}_{int(hi):06d}")


def list_stripes(root: str) -> Dict[Tuple[int, int], str]:
    """(lo, hi) -> directory for every stripe saved under ``root``."""
    out: Dict[Tuple[int, int], str] = {}
    if not os.path.isdir(root):
        return out
    for d in sorted(os.listdir(root)):
        parts = d.split("_")
        if d.startswith("stripe_") and len(parts) == 3:
            try:
                lo, hi = int(parts[1]), int(parts[2])
            except ValueError:
                continue
            if os.path.isdir(os.path.join(root, d)):
                out[(lo, hi)] = os.path.join(root, d)
    return out


def list_steps(ckpt_dir: str) -> list:
    """Every complete checkpoint step under one stripe dir, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, d))
    )


def restore_stripe(
    root: str,
    lo: int,
    hi: int,
    like: PyTree,
    step: Optional[int] = None,
) -> Tuple[int, PyTree, Dict[str, Any]]:
    """Restore the node stripe [lo, hi) from the per-stripe checkpoints
    under ``root``, stitching across saved stripes when the requested
    bounds don't match any saved directory (elastic membership change).

    ``like`` must be a ``{"striped": ..., "host": ...}`` state (the
    distributed controller's ``state_dict`` contract): every leaf under
    ``"striped"`` has a leading node axis and is sliced/concatenated
    row-wise; the ``"host"`` subtree is taken from the first covering
    stripe (stripe-independent by construction — RNG key chains and
    step counters advance identically on every host).

    When stitching across stripes the chosen step must exist in EVERY
    covering stripe (states are only mutually coherent at a common
    step); ``step=None`` picks the latest such common step.
    """
    stripes = list_stripes(root)
    if (lo, hi) in stripes and (
        step is None or step in list_steps(stripes[(lo, hi)])
    ):
        return restore(stripes[(lo, hi)], like, step=step)
    # greedy non-overlapping cover walk: saved roots can hold stripes
    # from DIFFERENT layouts (an H=3 epoch next to an H=2 epoch), so
    # candidates may overlap — at each position take the overlapping
    # stripe reaching furthest, and slice each pick to its uncovered run
    cover = []  # (slo, shi, dir, row_lo, row_hi): rows of that stripe used
    pos = lo
    while pos < hi:
        best = None
        for (slo, shi), d in stripes.items():
            if slo <= pos < shi and (best is None or shi > best[1]):
                best = (slo, shi, d)
        if best is None:
            raise FileNotFoundError(
                f"stripe checkpoints under {root} leave node {pos} of the "
                f"requested [{lo}, {hi}) uncovered "
                f"(saved stripes: {sorted(stripes)})"
            )
        slo, shi, d = best
        cover.append((slo, shi, d, pos - slo, min(hi, shi) - slo))
        pos = min(hi, shi)
    common = set(list_steps(cover[0][2]))
    for _, _, d, _, _ in cover[1:]:
        common &= set(list_steps(d))
    if step is None:
        if not common:
            raise FileNotFoundError(
                f"stripes covering [{lo}, {hi}) under {root} share no "
                "common checkpoint step (states are only coherent at a "
                "common step)"
            )
        step = max(common)
    elif step not in common:
        raise FileNotFoundError(
            f"step {step} is not present in every stripe covering "
            f"[{lo}, {hi}) under {root} (common steps: {sorted(common)})"
        )
    parts = []
    extra: Dict[str, Any] = {}
    host_part: PyTree = None
    for slo, shi, d, a, b in cover:
        _, state, ex = restore(d, like, step=step)
        parts.append(jax.tree.map(lambda x: x[a:b], state["striped"]))
        if host_part is None:
            host_part, extra = state["host"], ex
    striped = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *parts)
    return step, {"striped": striped, "host": host_part}, extra
