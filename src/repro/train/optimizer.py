"""AdamW with dtype-configurable moments + warmup-cosine schedule.

Moments can be held in bf16 (the 400B-class cells cannot afford fp32
m/v on a 256-chip pod — see EXPERIMENTS.md §Perf); update math is
always fp32. Weight decay skips rank<2 leaves (norm scales, biases).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(cfg: AdamWConfig, params: PyTree) -> PyTree:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig, grads: PyTree, opt_state: PyTree, params: PyTree
):
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, opt_state["count"])
    c1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / c1
        vhat = v32 / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, {
        "grad_norm": gnorm,
        "lr": lr,
    }
