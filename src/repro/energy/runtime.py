"""EnergyAwareRuntime: EnergyUCB as a first-class feature of the
training/serving loop.

Wraps any step callable (jitted train_step / decode step). Per step the
controller picks a frequency arm, the actuator applies it, the step
runs, telemetry deltas become the bandit observation, and the policy
updates — the paper's GEOPM loop with "decision interval" = one step
slice. On this container the actuator/telemetry are the calibrated
simulation; on hardware the same loop drives the real GEOPM-equivalent.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import Policy
from repro.core.simulator import Obs
from repro.energy.geopm import SimulatedGEOPM
from repro.energy.model import StepEnergyModel


@dataclass
class EnergyAwareRuntime:
    policy: Policy
    model: StepEnergyModel
    seed: int = 0
    reward_scale: Optional[float] = None

    def __post_init__(self):
        self.node = SimulatedGEOPM(model=self.model)
        self._key = jax.random.key(self.seed)
        self._pstate = self.policy.init(self._key)
        base = self.model.step(len(self.node.ladder_ghz) - 1)
        self._rs = self.reward_scale or (
            base["energy_j"] * base["uc"] / max(base["uu"], 1e-3)
        )
        self._last = self.node.read()
        self.history: List[Dict[str, float]] = []

    def step(self, work_fn: Optional[Callable[[], Any]] = None) -> Dict[str, Any]:
        """One decision interval: select arm -> actuate -> run work ->
        observe counters -> update policy."""
        self._key, k_sel = jax.random.split(self._key)
        arm = int(self.policy.select(self._pstate, k_sel))
        self.node.set_arm(arm)
        out = work_fn() if work_fn is not None else None
        sim = self.node.advance_one_step()
        now = self.node.read()
        d_e = now["energy_j"] - self._last["energy_j"]
        d_core = now["core_active_s"] - self._last["core_active_s"]
        d_unc = now["uncore_active_s"] - self._last["uncore_active_s"]
        d_t = now["timestamp_s"] - self._last["timestamp_s"]
        self._last = now
        uc = min(1.0, d_core / max(d_t, 1e-9))
        uu = max(1e-3, min(1.0, d_unc / max(d_t, 1e-9)))
        reward = -(d_e) * (uc / uu) / self._rs
        obs = Obs(
            energy_j=jnp.float32(d_e),
            uc=jnp.float32(uc),
            uu=jnp.float32(uu),
            progress=jnp.float32(1.0 / self.model.steps_total),
            reward=jnp.float32(reward),
            switched=jnp.bool_(False),
            active=jnp.bool_(True),
        )
        self._pstate = self.policy.update(self._pstate, jnp.int32(arm), obs)
        rec = {
            "arm": arm,
            "freq_ghz": float(self.node.ladder_ghz[arm]),
            "energy_j": d_e,
            "step_time_s": sim["step_time_s"],
            "reward": float(reward),
        }
        self.history.append(rec)
        return {"work": out, **rec}

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        e = sum(h["energy_j"] for h in self.history)
        t = sum(h["step_time_s"] for h in self.history)
        base = self.model.step(len(self.node.ladder_ghz) - 1)
        n = max(len(self.history), 1)
        return {
            "steps": n,
            "energy_j": e,
            "time_s": t,
            "baseline_energy_j": base["energy_j"] * n,
            "baseline_time_s": base["step_time_s"] * n,
            "saved_energy_j": base["energy_j"] * n - e,
            "saved_energy_pct": 100.0 * (1 - e / max(base["energy_j"] * n, 1e-9)),
            "slowdown_pct": 100.0 * (t / max(base["step_time_s"] * n, 1e-9) - 1),
            "switches": self.node.switches,
            "switch_overhead_j": self.node.switch_overhead_j,
        }
