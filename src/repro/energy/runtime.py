"""Deprecated shim: ``EnergyAwareRuntime`` is now ``EnergyController``
over a :class:`SimulatedGEOPM` backend.

The legacy class drove ``SimulatedGEOPM`` one node at a time through the
bound ``Policy`` surface and reported ``switched=False`` unconditionally;
the controller derives the real switch bit (and every other observation
field) from backend counter deltas in one vectorized path and routes
policy state through ``PolicyFns``/the fleet step. This wrapper keeps
the old constructor signature working for one release — new code should
build the backend explicitly:

    from repro.energy import EnergyController, SimulatedGEOPM
    ctl = EnergyController(policy, SimulatedGEOPM(model=model))
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.core.policies import Policy
from repro.energy.controller import EnergyController
from repro.energy.geopm import SimulatedGEOPM
from repro.energy.model import StepEnergyModel


class EnergyAwareRuntime(EnergyController):
    """Deprecated alias — one release of constructor compatibility."""

    def __init__(self, policy: Policy, model: StepEnergyModel, seed: int = 0,
                 reward_scale: Optional[float] = None):
        warnings.warn(
            "EnergyAwareRuntime is deprecated; use EnergyController with an "
            "explicit EnergyBackend (e.g. SimulatedGEOPM or SimBackend)",
            DeprecationWarning,
            stacklevel=2,
        )
        self.model = model
        super().__init__(
            policy, SimulatedGEOPM(model=model), seed=seed,
            reward_scale=reward_scale,
        )

    @property
    def node(self) -> SimulatedGEOPM:
        """Legacy attribute: the simulated node behind the controller."""
        return self.backend
