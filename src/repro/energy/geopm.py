"""GEOPM-shaped actuation/telemetry interface (paper §4.1 uses the GEOPM
Service + Runtime on Aurora; this is the TPU-fleet equivalent surface).

A real deployment implements ``FrequencyActuator`` against the platform
power API and ``Telemetry`` against hardware counters; this container
wires in the simulated implementation, which is driven by the
StepEnergyModel calibrated from the dry-run roofline terms.
"""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.calibration import (
    FREQS_GHZ,
    SWITCH_ENERGY_J,
    SWITCH_LATENCY_S,
)


class FrequencyActuator(abc.ABC):
    """Sets the accelerator core-frequency ladder index."""

    @property
    @abc.abstractmethod
    def ladder_ghz(self) -> Sequence[float]:
        ...

    @abc.abstractmethod
    def set_arm(self, arm: int) -> None:
        ...

    @abc.abstractmethod
    def current_arm(self) -> int:
        ...


class Telemetry(abc.ABC):
    """Monotonic energy counter + core/uncore active-time counters."""

    @abc.abstractmethod
    def read(self) -> Dict[str, float]:
        """{'energy_j': monotonic, 'core_active_s': .., 'uncore_active_s': ..,
        'timestamp_s': ..}"""
        ...


@dataclass
class SimulatedGEOPM(FrequencyActuator, Telemetry):
    """Simulated node: integrates the StepEnergyModel between reads."""

    model: "StepEnergyModel"  # noqa: F821  (repro.energy.model)
    arm: int = len(FREQS_GHZ) - 1
    _energy_j: float = 0.0
    _core_s: float = 0.0
    _uncore_s: float = 0.0
    _clock_s: float = 0.0
    switches: int = 0
    switch_overhead_j: float = 0.0

    @property
    def ladder_ghz(self):
        return tuple(FREQS_GHZ)

    def set_arm(self, arm: int) -> None:
        arm = int(arm)
        if arm != self.arm:
            self.switches += 1
            self._energy_j += SWITCH_ENERGY_J
            self.switch_overhead_j += SWITCH_ENERGY_J
            self._clock_s += SWITCH_LATENCY_S
        self.arm = arm

    def current_arm(self) -> int:
        return self.arm

    def advance_one_step(self) -> Dict[str, float]:
        """Simulate one train/serve step at the current frequency."""
        m = self.model.step(self.arm)
        self._energy_j += m["energy_j"]
        self._core_s += m["core_active_s"]
        self._uncore_s += m["uncore_active_s"]
        self._clock_s += m["step_time_s"]
        return m

    def read(self) -> Dict[str, float]:
        return {
            "energy_j": self._energy_j,
            "core_active_s": self._core_s,
            "uncore_active_s": self._uncore_s,
            "timestamp_s": self._clock_s,
        }
