"""GEOPM-shaped actuation/telemetry interface (paper §4.1 uses the GEOPM
Service + Runtime on Aurora; this is the TPU-fleet equivalent surface).

A real deployment implements ``FrequencyActuator`` against the platform
power API and ``Telemetry`` against hardware counters; this container
wires in the simulated implementation, which is driven by the
StepEnergyModel calibrated from the dry-run roofline terms.
``SimulatedGEOPM`` doubles as the single-node :class:`EnergyBackend`
(a fleet of N=1 with variable-length decision intervals), so the
:class:`~repro.energy.controller.EnergyController` drives it through
the exact surface a hardware backend would expose.
"""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.calibration import (
    FREQS_GHZ,
    SWITCH_ENERGY_J,
    SWITCH_LATENCY_S,
)
from repro.energy.backend import Counters, EnergyBackend


class FrequencyActuator(abc.ABC):
    """Sets the accelerator core-frequency ladder index."""

    @property
    @abc.abstractmethod
    def ladder_ghz(self) -> Sequence[float]:
        ...

    @abc.abstractmethod
    def set_arm(self, arm: int) -> None:
        ...

    @abc.abstractmethod
    def current_arm(self) -> int:
        ...


class Telemetry(abc.ABC):
    """Monotonic energy counter + core/uncore active-time counters."""

    @abc.abstractmethod
    def read(self) -> Dict[str, float]:
        """{'energy_j': monotonic, 'core_active_s': .., 'uncore_active_s': ..,
        'timestamp_s': ..}"""
        ...


@dataclass
class SimulatedGEOPM(FrequencyActuator, Telemetry, EnergyBackend):
    """Simulated node: integrates the StepEnergyModel between reads.

    As an :class:`EnergyBackend` it is a fleet of N=1 whose decision
    interval is one train/serve step — the interval's wall time varies
    with the chosen frequency (``variable_interval``), so the controller
    normalizes interval energy to the f_max step time."""

    model: "StepEnergyModel"  # noqa: F821  (repro.energy.model)
    arm: int = len(FREQS_GHZ) - 1
    _energy_j: float = 0.0
    _core_s: float = 0.0
    _uncore_s: float = 0.0
    _clock_s: float = 0.0
    _steps: int = 0
    switches: int = 0
    switch_overhead_j: float = 0.0

    @property
    def ladder_ghz(self):
        return tuple(FREQS_GHZ)

    def set_arm(self, arm: int) -> None:
        arm = int(arm)
        if arm != self.arm:
            self.switches += 1
            self._energy_j += SWITCH_ENERGY_J
            self.switch_overhead_j += SWITCH_ENERGY_J
            self._clock_s += SWITCH_LATENCY_S
        self.arm = arm

    def current_arm(self) -> int:
        return self.arm

    def advance_one_step(self) -> Dict[str, float]:
        """Simulate one train/serve step at the current frequency."""
        m = self.model.step(self.arm)
        self._energy_j += m["energy_j"]
        self._core_s += m["core_active_s"]
        self._uncore_s += m["uncore_active_s"]
        self._clock_s += m["step_time_s"]
        self._steps += 1
        return m

    def read(self) -> Dict[str, float]:
        return {
            "energy_j": self._energy_j,
            "core_active_s": self._core_s,
            "uncore_active_s": self._uncore_s,
            "timestamp_s": self._clock_s,
        }

    # -- EnergyBackend surface (fleet of N=1) --------------------------
    @property
    def n_nodes(self) -> int:
        return 1

    @property
    def interval_s(self) -> float:
        return self._fmax_step()["step_time_s"]

    @property
    def variable_interval(self) -> bool:
        return True  # one step at f takes t(f) seconds

    @property
    def reward_scale(self) -> float:
        base = self._fmax_step()
        return base["energy_j"] * base["uc"] / max(base["uu"], 1e-3)

    def _fmax_step(self) -> Dict[str, float]:
        return self.model.step(len(FREQS_GHZ) - 1)

    def baseline_interval(self):
        base = self._fmax_step()
        return (np.asarray([base["energy_j"]], np.float64),
                np.asarray([base["step_time_s"]], np.float64))

    def apply_arms(self, arms) -> None:
        self.set_arm(int(np.ravel(np.asarray(arms))[0]))

    def advance(self, work_fn: Optional[Callable[[], Any]] = None) -> Any:
        out = work_fn() if work_fn is not None else None
        self.advance_one_step()
        return out

    def read_counters(self) -> Counters:
        f = lambda v: np.asarray([v], np.float64)
        return Counters(
            energy_j=f(self._energy_j),
            core_active_s=f(self._core_s),
            uncore_active_s=f(self._uncore_s),
            timestamp_s=f(self._clock_s),
            progress=f(min(1.0, self._steps / max(self.model.steps_total, 1))),
            switches=np.asarray([self.switches], np.int32),
            active=np.asarray([self._steps < self.model.steps_total], bool),
        )
