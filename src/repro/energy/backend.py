"""EnergyBackend: the one streaming telemetry/actuation surface from
simulator to fleet (DESIGN: ROADMAP.md §PR 2).

The paper's deployment story is a single GEOPM-style loop — read
counters, pick an arm, actuate — and every environment the repo can
drive now exposes exactly that surface:

    read_counters() -> Counters   (N,) monotonic per-node counters
    apply_arms(arms)              actuate the frequency ladder, (N,)
    advance(work_fn)              complete one decision interval

Three implementations ship:

- :class:`SimBackend` wraps the pure-JAX ``env_step`` batched over N
  apps (one jitted vmapped step per interval; stacked ``EnvParams``
  give each node its own app).
- :class:`~repro.energy.geopm.SimulatedGEOPM` is the single-node
  GEOPM-shaped simulator (N=1), driven by a ``StepEnergyModel``.
- :class:`TraceReplayBackend` replays recorded counter logs for
  offline evaluation (record with :func:`record_trace`, persist with
  ``save``/``load``).

A real deployment implements this class against the platform power API
and hardware counters; :class:`~repro.energy.controller.EnergyController`
consumes any of them identically.
"""
from __future__ import annotations

import abc
import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import DEFAULT_ARM, FREQS_GHZ
from repro.core.simulator import EnvParams, EnvState, env_init, env_step

PyTree = Any

# On-disk trace format (TraceReplayBackend.save/load):
#   1 — the pre-factored format: scalar ladder, no version field. Loaders
#       treat a version-less npz as v1.
#   2 — adds `trace_version` and `uncore_ladder` (the factored product
#       ladder's uncore rungs; `[1.0]` for scalar recordings). Per-arm
#       counter semantics are unchanged — flat product arms reuse the
#       scalar arm column layout — so v1 files load unchanged, and the
#       lam_unc < 0 policy sentinel (one shared switching penalty) means
#       replaying a v1 trace through a factored policy needs no
#       translation either.
TRACE_VERSION = 2


class Counters(NamedTuple):
    """Monotonic per-node counters, all shaped (N,). The GEOPM-shaped
    contract: energy and active-time counters only ever increase, and
    the controller works purely on per-interval deltas."""

    energy_j: jax.Array  # cumulative energy (J), incl. switch overhead
    core_active_s: jax.Array  # cumulative core-engine active seconds
    uncore_active_s: jax.Array  # cumulative copy-engine active seconds
    timestamp_s: jax.Array  # cumulative wall time
    progress: jax.Array  # cumulative job fraction in [0, 1]
    switches: jax.Array  # cumulative frequency-switch count (int32)
    active: jax.Array  # bool: job still running at read time


def stack_counters(rows: Sequence[Counters]) -> Counters:
    """Stack T counter snapshots on a new leading axis -> (T, N) trace."""
    return Counters(*(np.stack([np.asarray(r[i]) for r in rows])
                      for i in range(len(Counters._fields))))


def slice_counters(counters: Counters, lo: int, hi: int) -> Counters:
    """The node-slice [lo, hi) of a counter snapshot or (T, N) trace —
    the per-host view of fleet telemetry (slices the LAST axis, so one
    helper serves both (N,) snapshots and stacked traces)."""
    return Counters(*(np.asarray(leaf)[..., lo:hi] for leaf in counters))


class EnergyBackend(abc.ABC):
    """One counter/actuator surface across simulated and real hardware.

    ``variable_interval`` declares whether the wall-time of a decision
    interval depends on the chosen frequency (one train step at f takes
    t(f) seconds). The controller then normalizes interval energy to the
    declared ``interval_s`` so rewards compare energy *rates*, not
    intervals of different lengths — the fixed-dt formulation of the
    paper (§4.1) recovered on variable-length steps.
    """

    @property
    @abc.abstractmethod
    def n_nodes(self) -> int:
        ...

    @property
    @abc.abstractmethod
    def ladder_ghz(self) -> Sequence[float]:
        ...

    @abc.abstractmethod
    def read_counters(self) -> Counters:
        ...

    @abc.abstractmethod
    def apply_arms(self, arms) -> None:
        """Actuate: set every node's frequency-ladder index, arms (N,)."""
        ...

    @abc.abstractmethod
    def advance(self, work_fn: Optional[Callable[[], Any]] = None) -> Any:
        """Complete one decision interval (run ``work_fn`` if given,
        let telemetry accumulate). Returns the work result."""
        ...

    @property
    def interval_s(self) -> float:
        """Nominal decision-interval wall time (reference duration)."""
        raise NotImplementedError

    @property
    def variable_interval(self) -> bool:
        return False

    @property
    def reward_scale(self):
        """Normalizer E*R at f_max — scalar or (N,)."""
        raise NotImplementedError

    def baseline_interval(self) -> Tuple[np.ndarray, np.ndarray]:
        """(energy_j, time_s) per node for one interval at static f_max
        (the paper's default-frequency baseline)."""
        raise NotImplementedError

    def local_slice(self, lo: int, hi: int) -> "EnergyBackend":
        """The per-host backend owning fleet nodes [lo, hi).

        The distributed control plane (repro.parallel.distributed) gives
        each of H controller processes its own backend stripe: telemetry
        and actuation stay host-local, and the stripe must reproduce the
        full-fleet backend's rows [lo:hi) bit for bit so striped and
        single-process runs agree. Backends that are inherently per-host
        (real hardware counters, SimulatedGEOPM) don't implement this —
        they ARE the local slice."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support node slicing; "
            "construct it per host instead"
        )

    def state_dict(self) -> PyTree:
        """Checkpointable backend state as ``{"striped": ..., "host":
        ...}``: every leaf under ``"striped"`` carries a leading node
        axis (so train.checkpoint.restore_stripe can re-stripe it under
        elastic membership changes), ``"host"`` holds stripe-independent
        leaves (RNG key data, cursors) that are identical across hosts
        at a common global interval. Simulated/replay backends
        implement the pair; real-hardware backends have no replayable
        state and keep the default error."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support checkpointing"
        )

    def load_state_dict(self, state: PyTree) -> None:
        """Adopt a :meth:`state_dict` snapshot — afterwards the backend
        is bit-identical to the one that saved (same stripe) or to the
        corresponding row-stripe of it (elastic restore)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support checkpointing"
        )


# ---------------------------------------------------------------------------
# SimBackend: the pure-JAX env, batched over N apps
# ---------------------------------------------------------------------------


def stack_env_params(cfgs: Sequence[EnvParams]) -> EnvParams:
    """Stack per-node apps on a leading N axis (a heterogeneous fleet)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *cfgs)


@functools.partial(jax.jit, static_argnames=("stacked",))
def _sim_advance(params, estates, core_s, uncore_s, arms, node_ids, key,
                 stacked):
    pax = 0 if stacked else None
    # per-node streams are keyed by GLOBAL node id (fold_in, not a
    # split over the local batch): a host owning the stripe [lo, hi) of
    # a striped fleet draws exactly the noise rows the full-fleet
    # backend would, which is what makes multi-process runs bit-parity
    # with single-process ones (repro.parallel.distributed)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(node_ids)
    estates2, obs = jax.vmap(env_step, in_axes=(pax, 0, 0, 0))(
        params, estates, arms, keys
    )
    # env_step folds (dt + switch latency) * active into time_s; the
    # active-time counters integrate the interval's busy fractions over
    # that same wall delta so deltas reproduce obs.uc / obs.uu exactly
    d_t = estates2.time_s - estates.time_s
    return estates2, core_s + obs.uc * d_t, uncore_s + obs.uu * d_t


@functools.partial(jax.jit, static_argnames=("n_intervals",))
def _episode_noise(key, node_ids, n_intervals):
    """The raw standard normals the next ``n_intervals`` streaming
    advances would draw — the same split -> fold_in(global node id) ->
    split(4) -> four scalar normals schedule ``env_step`` consumes via
    ``advance``, so threefry determinism makes the draws bit-identical
    to the streaming ones. (The per-node schedule is deliberately NOT
    batched into one normal(kk, (4,)) draw: per-element float bits of a
    draw must not depend on the batch shape, or striped fleets and
    scanned episodes would drift from the full-fleet streaming loop at
    the ulp level.)

    Only the per-interval split chain is inherently sequential; fold_in
    and the normals are per-key independent, so they batch over all
    T*N keys at once (one fused draw instead of T sequential N-wide
    ones)."""
    key2, ks = jax.lax.scan(
        lambda k, _: tuple(jax.random.split(k)), key, None,
        length=n_intervals)
    keys = jax.vmap(
        lambda k: jax.vmap(lambda i: jax.random.fold_in(k, i))(node_ids)
    )(ks)

    def draw(kk):
        k1, k2, k3, k4 = jax.random.split(kk, 4)
        return jnp.stack([jax.random.normal(k1), jax.random.normal(k2),
                          jax.random.normal(k3), jax.random.normal(k4)])

    z = jax.vmap(jax.vmap(draw))(keys)
    return key2, (z[..., 0], z[..., 1], z[..., 2], z[..., 3])


class SimBackend(EnergyBackend):
    """The bandit environment as a streaming backend: N apps advanced by
    one vmapped ``env_step`` per decision interval.

    ``params`` is one :class:`EnvParams` shared by every node, or a
    stacked pytree (leading N axis, see :func:`stack_env_params`) giving
    each node its own app. All counter math stays on-device; one jitted
    trace serves any N of the same shape signature.

    **Drifting workloads.** ``drift_params`` (a sequence of additional
    per-phase :class:`EnvParams`) with ``drift_every`` >= 1 makes the
    fleet cycle through ``[params, *drift_params]``, switching the
    active phase every ``drift_every`` intervals — the phase-changing
    Aurora workloads the sliding-window (gamma < 1) policies exist for.
    The schedule is keyed by the GLOBAL interval index (every stripe of
    a striped fleet counts its own lockstep advances from t=0), so
    multi-process deployments see bit-identical phase boundaries; all
    phases must share the frequency ladder and stackedness, and the
    declared ``reward_scale``/``interval_s``/``baseline_interval`` stay
    pinned to phase 0 so the controller normalizes rewards consistently
    across phases (the drifting arm ordering IS the scenario).
    """

    def __init__(self, params: EnvParams, n: Optional[int] = None,
                 seed: int = 0, node_offset: int = 0,
                 drift_params: Optional[Sequence[EnvParams]] = None,
                 drift_every: int = 0):
        self._stacked = jnp.ndim(params.dt_s) == 1
        if self._stacked:
            n_params = int(params.dt_s.shape[0])
            if n is not None and n != n_params:
                raise ValueError(f"stacked params carry N={n_params}, got n={n}")
            n = n_params
        self._n = int(n or 1)
        self.params = params
        self._phases = [params] + list(drift_params or ())
        self._drift_every = int(drift_every)
        if len(self._phases) > 1:
            if self._drift_every < 1:
                raise ValueError(
                    "drift_params needs drift_every >= 1 intervals per phase")
            for q in self._phases[1:]:
                if jnp.ndim(q.dt_s) != jnp.ndim(params.dt_s):
                    raise ValueError(
                        "drift phases must all be stacked or all shared")
                if not np.array_equal(np.asarray(q.freqs),
                                      np.asarray(params.freqs)):
                    raise ValueError(
                        "drift phases must share one frequency ladder")
        self._interval = 0
        self._seed = int(seed)
        self._offset = int(node_offset)
        self._key = jax.random.key(seed)
        # global node ids: local row i is fleet node offset + i, which
        # pins each node's noise stream independently of how the fleet
        # is striped across controller processes
        self._node_ids = jnp.arange(self._offset, self._offset + self._n)
        self._estates = jax.vmap(lambda _: env_init(params))(jnp.arange(self._n))
        self._core_s = jnp.zeros((self._n,), jnp.float32)
        self._uncore_s = jnp.zeros((self._n,), jnp.float32)
        self._arms = jnp.full((self._n,), DEFAULT_ARM, jnp.int32)

    @classmethod
    def from_roofline(cls, model, n: int = 1, seed: int = 0, **noise):
        """Backend for a framework cell: EnvParams from the dry-run
        roofline terms (see repro.energy.model.env_params_from_roofline)."""
        from repro.energy.model import env_params_from_roofline

        return cls(env_params_from_roofline(model, **noise), n=n, seed=seed)

    # -- EnergyBackend surface ----------------------------------------
    @property
    def n_nodes(self) -> int:
        return self._n

    @property
    def ladder_ghz(self):
        f = np.asarray(self.params.freqs)
        if f.ndim == 2:
            if not (f == f[0]).all():
                raise ValueError(
                    "heterogeneous per-node frequency ladders: there is no "
                    "single fleet ladder (index self.params.freqs per node)"
                )
            f = f[0]
        return tuple(f)

    @property
    def interval_s(self) -> float:
        return float(np.mean(np.asarray(self.params.dt_s)))

    @property
    def reward_scale(self):
        return self.params.reward_scale  # () or (N,)

    def baseline_interval(self):
        e = np.broadcast_to(
            np.asarray(self.params.e_interval_kj)[..., -1] * 1e3, (self._n,)
        )
        t = np.broadcast_to(np.asarray(self.params.dt_s), (self._n,))
        return e, t

    def apply_arms(self, arms) -> None:
        # broadcast, don't reshape: a scalar or (1,) actuation fans out
        # to the whole fleet; a mismatched (M,) still fails loudly
        a = jnp.asarray(arms, jnp.int32)
        self._arms = jnp.broadcast_to(a.reshape(-1) if a.ndim > 1 else a,
                                      (self._n,))

    def active_phase(self) -> int:
        """Index into the phase cycle for the NEXT interval to advance
        (0 for non-drifting backends)."""
        if len(self._phases) == 1:
            return 0
        return (self._interval // self._drift_every) % len(self._phases)

    def advance(self, work_fn: Optional[Callable[[], Any]] = None) -> Any:
        out = work_fn() if work_fn is not None else None
        self._key, k = jax.random.split(self._key)
        # the active phase is a host-side pick by global interval index:
        # params are jit operands (all phases share one shape signature),
        # so a phase switch never retraces — and every stripe of a
        # striped fleet, counting its own lockstep advances, switches at
        # the same boundary
        self._estates, self._core_s, self._uncore_s = _sim_advance(
            self._phases[self.active_phase()], self._estates, self._core_s,
            self._uncore_s, self._arms, self._node_ids, k, self._stacked,
        )
        self._interval += 1
        return out

    def local_slice(self, lo: int, hi: int) -> "SimBackend":
        """A fresh backend owning fleet nodes [lo, hi): stacked params
        (every drift phase included) slice rowwise, and the stripe
        inherits this backend's seed plus a shifted node offset, so
        (advanced in lockstep from t=0) its counters equal the full
        fleet's rows [lo:hi) bit for bit."""
        if not 0 <= lo < hi <= self._n:
            raise ValueError(f"slice [{lo}, {hi}) out of range for N={self._n}")
        sl = (lambda q: jax.tree.map(lambda x: x[lo:hi], q)) if self._stacked \
            else (lambda q: q)
        return SimBackend(sl(self.params), n=hi - lo, seed=self._seed,
                          node_offset=self._offset + lo,
                          drift_params=[sl(q) for q in self._phases[1:]] or None,
                          drift_every=self._drift_every)

    def read_counters(self) -> Counters:
        es = self._estates
        return Counters(
            energy_j=es.energy_kj * 1e3,
            core_active_s=self._core_s,
            uncore_active_s=self._uncore_s,
            timestamp_s=es.time_s,
            progress=1.0 - es.remaining,
            switches=es.switches,
            active=es.remaining > 0.0,
        )

    # -- checkpoint surface (train.checkpoint via the fleet controller) -
    def state_dict(self) -> PyTree:
        """Per-node env rows under ``"striped"``; the RNG key chain and
        global interval index under ``"host"`` (hosts advance in
        lockstep from the same seed, so both are identical across a
        striped fleet at a common interval — which is what lets an
        elastic restore stitch stripes saved by different hosts)."""
        return {
            "striped": {
                "estates": self._estates,
                "core_s": self._core_s,
                "uncore_s": self._uncore_s,
                "arms": self._arms,
            },
            "host": {
                "key": jax.random.key_data(self._key),
                "interval": np.int64(self._interval),
            },
        }

    def load_state_dict(self, state: PyTree) -> None:
        s = state["striped"]
        self._estates = EnvState(*(jnp.asarray(x) for x in s["estates"]))
        self._core_s = jnp.asarray(s["core_s"])
        self._uncore_s = jnp.asarray(s["uncore_s"])
        self._arms = jnp.asarray(s["arms"])
        self._key = jax.random.wrap_key_data(jnp.asarray(state["host"]["key"]))
        self._interval = int(state["host"]["interval"])

    # -- episode scan surface (kernels.episode_scan) -------------------
    @property
    def drift_every(self) -> int:
        return self._drift_every

    @property
    def interval_index(self) -> int:
        """Global index of the NEXT interval to advance (this is what
        keys the drift-phase schedule)."""
        return self._interval

    def episode_env(self):
        """The phase cycle as kernel-consumable :class:`ScanEnv` tables
        for the sim-fused episode scan. Raises on per-node stacked
        params — those fleets keep the streaming path."""
        from repro.kernels.episode_scan import make_scan_env

        return make_scan_env(self._phases)

    def episode_noise(self, n_intervals: int):
        """``(new_key, z)``: the four (T, N) raw-normal streams the next
        ``n_intervals`` :meth:`advance` calls would consume, plus the
        key the backend would hold afterwards. Pure — pair with
        :meth:`absorb_episode` to commit the scanned episode."""
        return _episode_noise(self._key, self._node_ids, int(n_intervals))

    def env_rows(self):
        """Env + counter state as the episode scan's (N,) EnvRows carry."""
        from repro.kernels.episode_scan import EnvRows

        es = self._estates
        return EnvRows(es.remaining, es.prev_arm, es.t, es.energy_kj,
                       es.time_s, es.switches, self._core_s, self._uncore_s)

    def absorb_episode(self, rows, key, n_intervals: int) -> None:
        """Adopt post-scan env state: afterwards the backend is
        bit-identical to one that streamed ``n_intervals`` advances."""
        self._estates = EnvState(
            remaining=rows.remaining, prev_arm=rows.prev_arm, t=rows.t,
            energy_kj=rows.energy_kj, time_s=rows.time_s,
            switches=rows.switches,
        )
        self._core_s = rows.core_s
        self._uncore_s = rows.uncore_s
        self._key = key
        self._interval += int(n_intervals)


# ---------------------------------------------------------------------------
# TraceReplayBackend: recorded counter logs for offline evaluation
# ---------------------------------------------------------------------------


class TraceReplayBackend(EnergyBackend):
    """Replays a recorded (T+1, N) counter trace interval by interval.

    Actuation requests are logged (``requested_arms``) but have no
    effect — the trace is immutable history, which is exactly what makes
    replay useful for offline policy evaluation and regression-testing
    the controller's obs derivation against a live run.
    """

    def __init__(self, trace: Counters, ladder_ghz: Sequence[float],
                 interval_s: float, variable_interval: bool = False,
                 reward_scale: float = 1.0,
                 baseline: Optional[Tuple[np.ndarray, np.ndarray]] = None,
                 uncore_ladder: Optional[Sequence[float]] = None):
        if np.asarray(trace.energy_j).ndim != 2:
            raise ValueError("trace counters must be stacked (T+1, N)")
        self.trace = trace
        self._ladder = tuple(float(f) for f in ladder_ghz)
        self._interval_s = float(interval_s)
        self._variable = bool(variable_interval)
        self._rs = reward_scale
        self._baseline = baseline
        self._uncore = (tuple(float(y) for y in uncore_ladder)
                        if uncore_ladder is not None else (1.0,))
        if self._uncore[-1] != 1.0:
            raise ValueError(
                f"uncore_ladder must ascend to 1.0, got {self._uncore}")
        self._cursor = 0
        self.requested_arms: list = []

    def __len__(self) -> int:
        """Number of replayable decision intervals."""
        return int(np.asarray(self.trace.energy_j).shape[0]) - 1

    # -- EnergyBackend surface ----------------------------------------
    @property
    def n_nodes(self) -> int:
        return int(np.asarray(self.trace.energy_j).shape[1])

    @property
    def ladder_ghz(self):
        return self._ladder

    @property
    def uncore_ladder(self) -> Tuple[float, ...]:
        """Uncore rungs of the recorded (flat product) ladder; ``(1.0,)``
        for scalar recordings, so ``len(uncore_ladder)`` is the k_unc to
        replay the trace's arm columns with."""
        return self._uncore

    @property
    def interval_s(self) -> float:
        return self._interval_s

    @property
    def variable_interval(self) -> bool:
        return self._variable

    @property
    def reward_scale(self):
        return self._rs

    def baseline_interval(self):
        if self._baseline is None:
            raise NotImplementedError("trace recorded without a baseline")
        return self._baseline

    def apply_arms(self, arms) -> None:
        self.requested_arms.append(np.asarray(arms, np.int32))

    def advance(self, work_fn: Optional[Callable[[], Any]] = None) -> Any:
        if self._cursor >= len(self):
            raise RuntimeError(
                f"trace exhausted after {len(self)} intervals"
            )
        out = work_fn() if work_fn is not None else None
        self._cursor += 1
        return out

    def read_counters(self) -> Counters:
        i = self._cursor
        return Counters(*(np.asarray(leaf)[i] for leaf in self.trace))

    # -- checkpoint surface --------------------------------------------
    def state_dict(self) -> PyTree:
        """Only the replay cursor: the trace is immutable input, loaded
        from disk (column-sliced) at construction, so an elastic restore
        has no striped leaves to stitch. ``requested_arms`` is a log,
        not state — a resumed replay re-requests from the cursor on."""
        return {"striped": {}, "host": {"cursor": np.int64(self._cursor)}}

    def load_state_dict(self, state: PyTree) -> None:
        self._cursor = int(state["host"]["cursor"])

    def local_slice(self, lo: int, hi: int) -> "TraceReplayBackend":
        """The trace columns [lo, hi) as a per-host replay backend: a
        single-process recording striped across H controller processes
        replays each host's nodes from its own shard."""
        n = self.n_nodes
        if not 0 <= lo < hi <= n:
            raise ValueError(f"slice [{lo}, {hi}) out of range for N={n}")
        rs = np.asarray(self._rs)
        baseline = self._baseline
        return TraceReplayBackend(
            slice_counters(self.trace, lo, hi),
            ladder_ghz=self._ladder,
            interval_s=self._interval_s,
            variable_interval=self._variable,
            reward_scale=rs[lo:hi] if rs.ndim >= 1 and rs.shape[0] == n else rs,
            baseline=None if baseline is None else tuple(
                np.asarray(b)[lo:hi] for b in baseline
            ),
            uncore_ladder=self._uncore,
        )

    # -- persistence ---------------------------------------------------
    def save(self, path: str) -> None:
        np.savez(
            path,
            trace_version=TRACE_VERSION,
            uncore_ladder=np.asarray(self._uncore),
            ladder_ghz=np.asarray(self._ladder),
            interval_s=self._interval_s,
            variable_interval=self._variable,
            reward_scale=np.asarray(self._rs),
            has_baseline=self._baseline is not None,
            baseline_e=np.zeros(0) if self._baseline is None else self._baseline[0],
            baseline_t=np.zeros(0) if self._baseline is None else self._baseline[1],
            **{f: np.asarray(getattr(self.trace, f)) for f in Counters._fields},
        )
        # explicit round-trip check: the version and ladder layout a
        # future loader will dispatch on must read back exactly (savez
        # appends .npz when the suffix is missing)
        p = path if str(path).endswith(".npz") else f"{path}.npz"
        with np.load(p) as z:
            if (int(z["trace_version"]) != TRACE_VERSION
                    or tuple(z["uncore_ladder"].tolist()) != self._uncore):
                raise IOError(f"trace round-trip failed for {p}")

    @classmethod
    def load(cls, path: str,
             nodes: Optional[Tuple[int, int]] = None) -> "TraceReplayBackend":
        """Load a saved trace; ``nodes=(lo, hi)`` keeps only that column
        stripe, so a host replaying its shard of a big recording never
        materializes the full-fleet backend (the multi-process replay
        path — see :func:`trace_n_nodes` for sizing the stripes).
        Version-less files are the v1 (scalar-ladder) format and load
        unchanged; files newer than :data:`TRACE_VERSION` fail loudly."""
        z = np.load(path)
        version = int(z["trace_version"]) if "trace_version" in z.files else 1
        if not 1 <= version <= TRACE_VERSION:
            raise ValueError(
                f"trace {path} has format version {version}; this build "
                f"reads versions 1..{TRACE_VERSION}")
        unc = (z["uncore_ladder"].tolist()
               if "uncore_ladder" in z.files else None)
        sl = slice(None) if nodes is None else slice(*nodes)
        trace = Counters(*(z[f][:, sl] for f in Counters._fields))
        rs = z["reward_scale"]
        baseline = (
            (z["baseline_e"][sl], z["baseline_t"][sl])
            if bool(z["has_baseline"]) else None
        )
        return cls(
            trace, ladder_ghz=z["ladder_ghz"].tolist(),
            interval_s=float(z["interval_s"]),
            variable_interval=bool(z["variable_interval"]),
            reward_scale=rs[sl] if rs.ndim >= 1 else rs, baseline=baseline,
            uncore_ladder=unc,
        )


def trace_n_nodes(path: str) -> int:
    """Fleet width N of a saved trace (reads one counter member)."""
    with np.load(path) as z:
        return int(z["energy_j"].shape[1])


def record_trace(backend: EnergyBackend, arm_schedule) -> TraceReplayBackend:
    """Drive ``backend`` through a (T, N) arm schedule and capture its
    counter log as a replayable backend. Advances (mutates) ``backend``."""
    sched = np.asarray(arm_schedule, np.int32)
    if sched.ndim == 1:
        # a 1-D schedule is one arm per interval for the WHOLE fleet:
        # broadcast across nodes instead of pinning the shape to N=1
        sched = np.broadcast_to(sched[:, None],
                                (sched.shape[0], backend.n_nodes))
    rows = [backend.read_counters()]
    for arms in sched:
        backend.apply_arms(arms)
        backend.advance()
        rows.append(backend.read_counters())
    try:
        baseline = backend.baseline_interval()
    except NotImplementedError:
        baseline = None
    return TraceReplayBackend(
        stack_counters(rows),
        ladder_ghz=backend.ladder_ghz,
        interval_s=backend.interval_s,
        variable_interval=backend.variable_interval,
        reward_scale=np.asarray(backend.reward_scale),
        baseline=baseline,
        # factored backends expose their uncore rungs; scalar backends
        # record the degenerate (1.0,) ladder
        uncore_ladder=getattr(backend, "uncore_ladder", None),
    )
