from repro.energy.geopm import FrequencyActuator, SimulatedGEOPM, Telemetry
from repro.energy.model import StepEnergyModel, env_params_from_roofline
from repro.energy.runtime import EnergyAwareRuntime

__all__ = [
    "FrequencyActuator",
    "Telemetry",
    "SimulatedGEOPM",
    "StepEnergyModel",
    "env_params_from_roofline",
    "EnergyAwareRuntime",
]
