"""Energy control plane: one streaming telemetry/actuation surface
(:class:`EnergyBackend`) consumed by one controller
(:class:`EnergyController`).

Which backend to use where:

- :class:`SimBackend` — the calibrated pure-JAX bandit environment,
  batched over N nodes. Use for experiments, fleet-scale streaming
  (auto-dispatches the fused Pallas fleet step for kernel-exact
  policies), and anything that needs vmap/jit-friendly telemetry.
  ``SimBackend.from_roofline(model)`` packages a framework cell.
- :class:`SimulatedGEOPM` — the single-node GEOPM-shaped simulator
  driven by a :class:`StepEnergyModel`; decision interval = one real
  train/serve step. Use inside live training/serving loops on this
  container; on hardware, implement :class:`EnergyBackend` against the
  platform power API with the same shape.
- :class:`TraceReplayBackend` — replays recorded counter logs
  (:func:`record_trace`, ``save``/``load``). Use for offline policy
  evaluation and controller regression tests.
"""
from repro.energy.backend import (
    Counters,
    EnergyBackend,
    SimBackend,
    TraceReplayBackend,
    record_trace,
    slice_counters,
    stack_counters,
    stack_env_params,
)
from repro.energy.controller import (
    EnergyController,
    derive_obs,
    reduce_summaries,
)
from repro.energy.geopm import FrequencyActuator, SimulatedGEOPM, Telemetry
from repro.energy.model import StepEnergyModel, env_params_from_roofline


def make_backend(model: StepEnergyModel, kind: str = "geopm", n: int = 1,
                 seed: int = 0, **noise) -> EnergyBackend:
    """The one place callers turn a framework cell into a backend.

    ``kind="geopm"`` gives the single-node live-loop simulator (decision
    interval = one real step); ``kind="sim"`` gives the batched pure-JAX
    environment (N nodes, fixed decision interval, optional ``noise``
    overrides forwarded to :func:`env_params_from_roofline`).
    """
    if kind == "geopm":
        if n != 1:
            raise ValueError("geopm backend is single-node; use kind='sim'")
        return SimulatedGEOPM(model=model)
    if kind == "sim":
        return SimBackend.from_roofline(model, n=n, seed=seed, **noise)
    raise ValueError(f"unknown backend kind {kind!r} (geopm | sim)")

__all__ = [
    "Counters",
    "EnergyBackend",
    "EnergyController",
    "FrequencyActuator",
    "SimBackend",
    "SimulatedGEOPM",
    "StepEnergyModel",
    "Telemetry",
    "TraceReplayBackend",
    "derive_obs",
    "env_params_from_roofline",
    "make_backend",
    "record_trace",
    "reduce_summaries",
    "slice_counters",
    "stack_counters",
    "stack_env_params",
]
