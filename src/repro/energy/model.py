"""StepEnergyModel: the hardware-adaptation bridge between the dry-run
roofline and the EnergyUCB controller (DESIGN.md §2).

Given a cell's three roofline terms at f_max, the step time at relative
core frequency x = f/f_max is the max-overlap model

    t(x) = max(t_compute / x, t_memory, t_collective)

(MXU throughput scales with core clock; HBM and ICI do not). Chip power
follows the DVFS decomposition P(x) = P_idle + P_dyn * x^gamma * activity.
The paper's counters map to:

    UC (core)   = (t_compute/x) / t(x)      MXU-busy fraction
    UU (uncore) = max(t_mem, t_coll)/ t(x)  HBM+ICI-busy fraction

so compute-bound cells (train) are energy-optimal near f_max while
memory/collective-bound cells (decode, long-context) favor low f —
exactly the per-app structure the paper measures on Aurora.

``env_params_from_roofline`` repackages a cell as a bandit EnvParams so
every policy/rollout in repro.core runs unchanged on framework cells.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.calibration import FREQS_GHZ, F_MAX
from repro.core.simulator import EnvParams

# TPU-v5e-like chip power envelope (public TDP ~170-220 W class)
P_IDLE_W = 75.0
P_DYN_W = 125.0
GAMMA = 2.2
# Uncore (HBM + fabric) dynamic envelope for the factored ladder: HBM
# stacks are a comparable-sized lever to core DVFS on memory-heavy
# phases. The scalar model folds this into its pinned power; the
# factored model exposes it as a y-controlled term.
P_UNC_W = 60.0
GAMMA_UNC = 2.0
UNC_FREQS = (0.6, 0.8, 1.0)  # ascending; max LAST (arm K-1 convention)


@dataclass(frozen=True)
class StepEnergyModel:
    """One (arch x shape x mesh) cell's energy behavior per step."""

    t_compute_s: float
    t_memory_s: float
    t_collective_s: float
    n_chips: int = 256
    steps_total: int = 1000  # job length in steps (sets episode horizon)
    p_idle_w: float = P_IDLE_W
    p_dyn_w: float = P_DYN_W
    gamma: float = GAMMA

    def step(self, arm: int) -> Dict[str, float]:
        x = float(FREQS_GHZ[arm]) / F_MAX
        t_comp = self.t_compute_s / x
        t_other = max(self.t_memory_s, self.t_collective_s)
        t = max(t_comp, t_other, 1e-9)
        activity = (t_comp + t_other) / (2 * t)
        p_chip = self.p_idle_w + self.p_dyn_w * (x ** self.gamma) * activity
        return {
            "step_time_s": t,
            "power_w": p_chip * self.n_chips,
            "energy_j": p_chip * self.n_chips * t,
            "core_active_s": t_comp,
            "uncore_active_s": t_other,
            "uc": t_comp / t,
            "uu": max(t_other / t, 1e-3),
        }

    def step_factored(self, core_arm: int, unc_arm: int,
                      unc_freqs=UNC_FREQS) -> Dict[str, float]:
        """One step at relative core clock x and relative uncore clock
        ``y = unc_freqs[unc_arm]``: HBM time stretches as 1/y (bandwidth
        tracks the memory clock), the collective term does not (ICI has
        its own clock domain), and the chip pays an extra
        ``P_UNC_W * y^GAMMA_UNC * uu`` uncore-dynamic term. Unlike the
        scalar :meth:`step` (which folds uncore power into its pinned
        envelope), both the y = 1 column and every other column carry
        the explicit uncore term — build the scalar BASELINE for a
        factored comparison from ``unc_freqs=(1.0,)``, not from
        :meth:`step`, so the two ladders share one power model."""
        x = float(FREQS_GHZ[core_arm]) / F_MAX
        y = float(unc_freqs[unc_arm])
        t_comp = self.t_compute_s / x
        t_other = max(self.t_memory_s / y, self.t_collective_s)
        t = max(t_comp, t_other, 1e-9)
        # activity counts work issued at the reference uncore clock, not
        # stall time: stretching HBM must not bill core-dynamic power
        # (coincides with the scalar expression at y = 1)
        act_other = max(self.t_memory_s, self.t_collective_s)
        activity = (t_comp + act_other) / (2 * t)
        uu = max(t_other / t, 1e-3)
        p_chip = (self.p_idle_w + self.p_dyn_w * (x ** self.gamma) * activity
                  + P_UNC_W * (y ** GAMMA_UNC) * uu)
        return {
            "step_time_s": t,
            "power_w": p_chip * self.n_chips,
            "energy_j": p_chip * self.n_chips * t,
            "core_active_s": t_comp,
            "uncore_active_s": t_other,
            "uc": t_comp / t,
            "uu": uu,
        }

    def static_energy_j(self, arm: int) -> float:
        return self.step(arm)["energy_j"] * self.steps_total

    def optimal_arm(self) -> int:
        return int(np.argmin([self.static_energy_j(i) for i in range(len(FREQS_GHZ))]))


def env_params_from_roofline(
    model: StepEnergyModel,
    noise_energy: float = 0.03,
    noise_util: float = 0.05,
    early_noise: float = 4.0,
    early_tau: float = 30.0,
) -> EnvParams:
    """Package a framework cell as a bandit environment (decision interval
    = one train/serve step; progress = steps completed)."""
    k = len(FREQS_GHZ)
    rows = [model.step(i) for i in range(k)]
    t = np.array([r["step_time_s"] for r in rows])
    p_kw = np.array([r["power_w"] for r in rows]) / 1e3
    uc = np.array([r["uc"] for r in rows])
    uu = np.array([r["uu"] for r in rows])
    # decision interval = one f_max-step of wall time; progress per
    # interval = dt / (t(f) * steps_total); energy per interval = P(f)*dt
    dt = float(t[-1])
    e_kj = p_kw * dt
    progress = dt / (t * model.steps_total)
    r_scale = float(e_kj[-1] * 1e3 * uc[-1] / uu[-1])
    return EnvParams(
        freqs=jnp.asarray(FREQS_GHZ, jnp.float32),
        p_used_kw=jnp.asarray(p_kw, jnp.float32),
        t_rel=jnp.asarray(t / t[-1], jnp.float32),
        progress=jnp.asarray(progress, jnp.float32),
        uc=jnp.asarray(uc, jnp.float32),
        uu=jnp.asarray(uu, jnp.float32),
        t_ref_s=jnp.float32(t[-1] * model.steps_total),
        dt_s=jnp.float32(t[-1]),
        noise_energy=jnp.float32(noise_energy),
        noise_util=jnp.float32(noise_util),
        early_noise=jnp.float32(early_noise),
        early_tau=jnp.float32(early_tau),
        reward_scale=jnp.float32(r_scale),
        e_interval_kj=jnp.asarray(e_kj, jnp.float32),
    )


def factored_env_params_from_roofline(
    model: StepEnergyModel,
    unc_freqs=UNC_FREQS,
    noise_energy: float = 0.03,
    noise_util: float = 0.05,
    early_noise: float = 4.0,
    early_tau: float = 30.0,
) -> EnvParams:
    """Package a framework cell as a PRODUCT-ladder bandit environment:
    flat ``K = K_core * K_unc`` tables with the uncore axis minor (arm
    ``i`` = core ``i // K_unc``, uncore ``i % K_unc``), built from
    :meth:`StepEnergyModel.step_factored`. ``unc_freqs=(1.0,)`` is the
    matching scalar-core-ladder baseline (same power model, uncore
    pinned at max) — the fair comparison for factored-vs-scalar energy.
    The decision interval and reward scale come from the top corner
    (f_max, max uncore), mirroring the scalar convention."""
    y = np.asarray(unc_freqs, np.float64)
    if y[-1] != 1.0 or np.any(np.diff(y) <= 0) or np.any(y <= 0):
        raise ValueError(
            f"unc_freqs must ascend to 1.0, got {tuple(unc_freqs)}"
        )
    kc, ku = len(FREQS_GHZ), len(y)
    rows = [model.step_factored(i, j, unc_freqs)
            for i in range(kc) for j in range(ku)]
    t = np.array([r["step_time_s"] for r in rows])
    p_kw = np.array([r["power_w"] for r in rows]) / 1e3
    uc = np.array([r["uc"] for r in rows])
    uu = np.array([r["uu"] for r in rows])
    dt = float(t[-1])
    e_kj = p_kw * dt
    progress = dt / (t * model.steps_total)
    r_scale = float(e_kj[-1] * 1e3 * uc[-1] / uu[-1])
    return EnvParams(
        freqs=jnp.asarray(np.repeat(FREQS_GHZ, ku), jnp.float32),
        p_used_kw=jnp.asarray(p_kw, jnp.float32),
        t_rel=jnp.asarray(t / t[-1], jnp.float32),
        progress=jnp.asarray(progress, jnp.float32),
        uc=jnp.asarray(uc, jnp.float32),
        uu=jnp.asarray(uu, jnp.float32),
        t_ref_s=jnp.float32(t[-1] * model.steps_total),
        dt_s=jnp.float32(t[-1]),
        noise_energy=jnp.float32(noise_energy),
        noise_util=jnp.float32(noise_util),
        early_noise=jnp.float32(early_noise),
        early_tau=jnp.float32(early_tau),
        reward_scale=jnp.float32(r_scale),
        e_interval_kj=jnp.asarray(e_kj, jnp.float32),
    )
