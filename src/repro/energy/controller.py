"""EnergyController: the streaming control plane over any EnergyBackend.

One loop from simulator to fleet (the paper's GEOPM Runtime loop, §4.1):
per decision interval the controller actuates every node's arm, lets the
interval elapse (optionally running real work), reads the monotonic
counters back, derives the bandit observation from the deltas in one
vectorized path — including the REAL ``switched`` bit from the backend's
switch counter — and folds it into policy state through the
``PolicyFns`` surface:

- a single node is just a fleet of N=1;
- a fleet of N>1 with a kernel-exact policy auto-dispatches the fused
  Pallas ``fleet_step`` (update-then-select in one launch, see
  repro.core.fleet.Fleet / kernels.fleet_ucb) — which is now the whole
  EnergyUCB family: the QoS feasible set (``qos_delta``/``default_arm``
  lanes), the sliding-window discount (``gamma`` lane; reward AND
  progress statistics decay, so the feasible set tracks workload phase
  changes), and the round-robin warm-up ablation (``optimistic`` lane)
  all ride per-controller kernel lanes;
- fleets beyond one chip's VMEM pass ``mesh=`` to shard the (N, K)
  controller state over the mesh's data axis (repro.parallel.fleet);
- non-UCB policy families take the vmapped ``PolicyFns`` path.

For backends whose raw interval wall-time depends on the chosen
frequency (``variable_interval``, e.g. one train step at f takes t(f)
seconds) the interval energy is normalized to the backend's declared
``interval_s`` so rewards compare energy rates — this makes the live
loop's reward agree with ``simulator.expected_rewards`` on the same
cell, which the legacy runtime's raw delta did not.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import SWITCH_ENERGY_J
from repro.core.fleet import Fleet, kernel_compatible
from repro.core.policies import Policy
from repro.core.simulator import Obs
from repro.energy.backend import Counters, EnergyBackend
from repro.kernels import ops

PyTree = Any


def derive_obs(last: Counters, now: Counters, reward_scale,
               interval_s: Optional[float] = None) -> Obs:
    """Per-interval bandit observation from two counter snapshots.

    Pure and vectorized over N: deltas of the monotonic counters become
    interval energy / busy fractions / progress, ``switched`` comes from
    the switch counter (not assumed False), and ``active`` is the
    pre-interval job state (the env convention). ``interval_s`` enables
    the variable-interval energy-rate normalization.
    """
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    d_e = f32(now.energy_j) - f32(last.energy_j)
    d_t = f32(now.timestamp_s) - f32(last.timestamp_s)
    safe_t = jnp.maximum(d_t, 1e-9)
    uc = jnp.clip((f32(now.core_active_s) - f32(last.core_active_s)) / safe_t,
                  1e-3, 1.0)
    uu = jnp.clip((f32(now.uncore_active_s) - f32(last.uncore_active_s)) / safe_t,
                  1e-3, 1.0)
    e_rate = d_e * (interval_s / safe_t) if interval_s is not None else d_e
    reward = -e_rate * (uc / uu) / jnp.asarray(reward_scale, jnp.float32)
    return Obs(
        energy_j=d_e,
        uc=uc,
        uu=uu,
        progress=f32(now.progress) - f32(last.progress),
        reward=reward,
        switched=(jnp.asarray(now.switches, jnp.int32)
                  - jnp.asarray(last.switches, jnp.int32)) > 0,
        active=jnp.asarray(last.active, bool),
    )


def reduce_summaries(summaries) -> Dict[str, Any]:
    """Fold H per-host :meth:`EnergyController.summary` dicts into one
    fleet-level summary — the only cross-host reduction the distributed
    control plane ever performs (extensive counters sum, per-node times
    average weighted by stripe width, and the derived percentages are
    recomputed from the fleet totals so they match what a single process
    owning the whole fleet would report)."""
    summaries = list(summaries)
    if not summaries:
        raise ValueError("no summaries to reduce")
    nodes = np.asarray([s["nodes"] for s in summaries], np.float64)
    w = nodes / nodes.sum()
    tot = lambda f: float(sum(s[f] for s in summaries))
    wmean = lambda f: float(sum(wi * s[f] for wi, s in zip(w, summaries)))
    out = {
        "steps": max(s["steps"] for s in summaries),
        "hosts": len(summaries),
        "nodes": int(nodes.sum()),
        "energy_j": tot("energy_j"),
        "time_s": wmean("time_s"),
        "switches": int(tot("switches")),
        "switch_overhead_j": tot("switch_overhead_j"),
    }
    if all("baseline_energy_j" in s for s in summaries):
        base_e, base_t = tot("baseline_energy_j"), wmean("baseline_time_s")
        out.update(
            baseline_energy_j=base_e,
            baseline_time_s=base_t,
            saved_energy_j=base_e - out["energy_j"],
            saved_energy_pct=100.0 * (1 - out["energy_j"] / max(base_e, 1e-9)),
            slowdown_pct=100.0 * (out["time_s"] / max(base_t, 1e-9) - 1),
        )
    return out


@functools.partial(jax.jit, static_argnames=("n",))
def _burn_key(key, n):
    """The key a controller streaming ``n`` intervals would hold (one
    split per step), without the Python loop."""

    def f(k, _):
        return jax.random.split(k)[0], None

    return jax.lax.scan(f, key, None, length=n)[0]


class EnergyController:
    """Consumes any :class:`EnergyBackend`; N = ``backend.n_nodes``.

    ``use_kernel=None`` auto-dispatches the fused Pallas fleet step when
    the backend reports N>1, the policy is kernel-exact, and a TPU is
    present (or ``interpret=True`` forces interpret mode, as the parity
    tests do). Policy state, selection and updates all flow through the
    :class:`~repro.core.fleet.Fleet` / ``PolicyFns`` surface, so one
    jitted trace serves every hyperparameter value — including
    per-node alpha/lambda/qos_delta lanes.
    """

    def __init__(self, policy: Policy, backend: EnergyBackend, seed: int = 0,
                 reward_scale=None, use_kernel: Optional[bool] = None,
                 interpret: bool = False, record_history: bool = True,
                 mesh=None):
        self.policy = policy
        self.backend = backend
        # fleet-scale streams opt out: per-interval records are (N,) host
        # arrays, i.e. a device sync and unbounded growth per interval
        self.record_history = record_history
        self.n = int(backend.n_nodes)
        if use_kernel is None:
            use_kernel = (
                self.n > 1
                and kernel_compatible(policy)
                and (ops.pallas_available() or interpret)
            )
        self.fleet = Fleet(policy, self.n, use_kernel=use_kernel,
                           interpret=interpret, mesh=mesh)
        self._key = jax.random.key(seed)
        self._key, k0 = jax.random.split(self._key)
        self._states = self.fleet.init(k0)
        self._arms: Optional[jax.Array] = None
        # the arms actuated by the most recent step() — a device array,
        # so observers (e.g. the distributed plane's arm log) can read
        # it without forcing a host sync on the streaming path
        self.last_arms: Optional[jax.Array] = None
        # the (T, N) arm trace of the most recent run_scanned() episode
        self.last_episode_arms: Optional[jax.Array] = None
        self._start = backend.read_counters()
        self._last = self._start
        self._rs = (backend.reward_scale if reward_scale is None
                    else reward_scale)
        self._interval_s = (backend.interval_s if backend.variable_interval
                            else None)
        self._n_steps = 0
        self.history: List[Dict[str, Any]] = []

    @property
    def use_kernel(self) -> bool:
        return self.fleet.use_kernel

    @property
    def states(self) -> PyTree:
        return self._states

    def _scalar(self, x):
        a = np.asarray(x)
        return a.reshape(()).item() if self.n == 1 else a

    def step(self, work_fn: Optional[Callable[[], Any]] = None) -> Dict[str, Any]:
        """One decision interval for the whole fleet: actuate -> run work
        -> read counters -> derive Obs -> fused/vmapped update+select."""
        if self._arms is None:
            self._key, k = jax.random.split(self._key)
            self._arms = self.fleet.select(self._states, k)
        arms = self._arms
        self.last_arms = arms
        self.backend.apply_arms(arms)
        out = self.backend.advance(work_fn)
        now = self.backend.read_counters()
        obs = derive_obs(self._last, now, self._rs, self._interval_s)
        self._key, k = jax.random.split(self._key)
        self._states, self._arms = self.fleet.step(self._states, arms, obs, k)
        if not self.record_history:
            self._last = now
            self._n_steps += 1
            return {"work": out}
        d_t = np.asarray(now.timestamp_s) - np.asarray(self._last.timestamp_s)
        self._last = now
        self._n_steps += 1
        ladder = np.asarray(self.backend.ladder_ghz)
        rec = {
            "arm": self._scalar(np.asarray(arms)),
            "freq_ghz": self._scalar(ladder[np.asarray(arms)]),
            "energy_j": self._scalar(obs.energy_j),
            "step_time_s": self._scalar(d_t),
            "reward": self._scalar(obs.reward),
            "switched": self._scalar(np.asarray(obs.switched)),
        }
        self.history.append(rec)
        return {"work": out, **rec}

    def run(self, n_intervals: int,
            work_fn: Optional[Callable[[], Any]] = None) -> Dict[str, float]:
        """Drive ``n_intervals`` decision intervals; returns summary()."""
        for _ in range(n_intervals):
            self.step(work_fn)
        return self.summary()

    def run_scanned(self, n_intervals: int) -> Dict[str, float]:
        """Advance ``n_intervals`` decision intervals in ONE episode-scan
        dispatch (kernels.episode_scan) instead of ``n_intervals``
        streamed :meth:`step` calls — identical to streaming arm for
        arm and counter for counter (env counters and RNG/key streams
        are bit-exact; the controller MEANS agree to float32 round-off,
        because streaming derives observations eagerly op-by-op while
        the scan fuses the same expressions, so FMA contraction can
        differ by ulps) — but paying one launch (or one XLA scan) per
        episode.

        Works over a :class:`~repro.energy.backend.SimBackend` (the
        sim-fused mode: env step, counters, obs derivation and the
        drift-phase schedule run inside the scan; the backend then
        adopts the post-scan state, so streaming can resume seamlessly)
        or a :class:`~repro.energy.backend.TraceReplayBackend` (the
        trace-fed mode: observation columns derived vectorized from the
        counter trace, requested arms logged, cursor advanced). Raises
        for non-scannable setups — non-UCB policies, per-node stacked
        EnvParams, overridden reward scales, other backend types —
        which keep the streaming loop. Per-interval ``history`` records
        are NOT produced (the whole point is not materializing
        per-interval host data); ``summary()``/telemetry still work.
        Returns :meth:`summary`; the (T, N) arm trace is left on
        ``self.last_episode_arms`` for observers."""
        from repro.energy.backend import SimBackend, TraceReplayBackend

        tt = int(n_intervals)
        if tt < 1:
            return self.summary()
        if self._arms is None:
            self._key, k = jax.random.split(self._key)
            self._arms = self.fleet.select(self._states, k)
        backend = self.backend
        if isinstance(backend, SimBackend):
            # the kernel pins the reward normalizer to the phase-0
            # reward_scale (the backend's declared one); a constructor
            # override would silently diverge from streaming
            rs0 = np.asarray(backend.params.reward_scale)
            rs = np.asarray(self._rs)
            if rs.shape != () or rs0.shape != () or float(rs) != float(rs0):
                raise ValueError(
                    "scanned sim episodes need the backend's scalar "
                    "phase-0 reward_scale (got an override or per-node "
                    "scales); stream with run() instead"
                )
            senv = backend.episode_env()  # raises on stacked params
            key2, z = backend.episode_noise(tt)
            self._states, self._arms, env2, arms = self.fleet.episode_sim(
                self._states, self._arms, backend.env_rows(), z, senv,
                t_start=backend.interval_index,
                drift_every=backend.drift_every, counter_obs=True,
            )
            backend.absorb_episode(env2, key2, tt)
        elif isinstance(backend, TraceReplayBackend):
            c = backend._cursor
            if c + tt > len(backend):
                raise RuntimeError(
                    f"trace has {len(backend) - c} intervals left, "
                    f"asked to scan {tt}"
                )
            win = lambda lo, hi: Counters(
                *(np.asarray(leaf)[lo:hi] for leaf in backend.trace)
            )
            obs = derive_obs(win(c, c + tt), win(c + 1, c + 1 + tt),
                             self._rs, self._interval_s)
            self._states, self._arms, arms = self.fleet.episode_trace(
                self._states, self._arms, obs.reward, obs.progress,
                obs.active,
            )
            backend.requested_arms.extend(np.asarray(arms))
            backend._cursor = c + tt
        else:
            raise ValueError(
                f"{type(backend).__name__} has no episode surface; "
                "stream with run() instead"
            )
        # streaming step() burns one controller-key split per interval
        # (the vmapped path's select key); burn the same splits so a
        # scanned prefix leaves the key stream where streaming would
        self._key = _burn_key(self._key, tt)
        self.last_episode_arms = arms
        self.last_arms = arms[-1]
        self._last = backend.read_counters()
        self._n_steps += tt
        return self.summary()

    # -- checkpoint surface (train.checkpoint via the fleet controller) -
    def state_dict(self) -> PyTree:
        """Checkpointable controller state, split per the distributed
        control plane's contract: per-node leaves (policy state, the
        pre-selected next arms, the counter snapshots) under
        ``"striped"`` with their leading N axis; the RNG key chain and
        step count under ``"host"`` (every host burns one split per
        interval from the same seed, so these are identical across a
        striped fleet at a common interval — elastic restores can take
        them from any covering stripe). Forces the initial arm
        selection if it hasn't happened yet (the same split ``step``
        would burn), so the snapshot always holds concrete next arms."""
        if self._arms is None:
            self._key, k = jax.random.split(self._key)
            self._arms = self.fleet.select(self._states, k)
        return {
            "striped": {
                "states": dict(self._states),
                "arms": self._arms,
                "last": self._last,
                "start": self._start,
            },
            "host": {
                "key": jax.random.key_data(self._key),
                "n_steps": np.int64(self._n_steps),
            },
        }

    def load_state_dict(self, state: PyTree) -> None:
        """Adopt a :meth:`state_dict` snapshot: the next :meth:`step`
        actuates the restored pre-selected arms and continues the exact
        key/observation stream the saver would have produced."""
        s = state["striped"]
        self._states = {k: jnp.asarray(v) for k, v in s["states"].items()}
        self._arms = jnp.asarray(s["arms"])
        self.last_arms = self._arms
        self._last = Counters(*(jnp.asarray(x) for x in s["last"]))
        self._start = Counters(*(jnp.asarray(x) for x in s["start"]))
        self._key = jax.random.wrap_key_data(jnp.asarray(state["host"]["key"]))
        self._n_steps = int(state["host"]["n_steps"])

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Job-so-far telemetry vs the static-f_max baseline (per-node
        counters summed over the fleet; times fleet-averaged). Backends
        without a declared baseline (e.g. a bare trace, real hardware)
        get the counter-derived fields only."""
        now, start = self._last, self._start
        d = lambda f: np.asarray(f(now), np.float64) - np.asarray(f(start), np.float64)
        e = float(d(lambda c: c.energy_j).sum())
        t = float(d(lambda c: c.timestamp_s).mean())
        switches = int(d(lambda c: c.switches).sum())
        n_steps = self._n_steps
        out = {
            "steps": n_steps,
            "nodes": self.n,
            "energy_j": e,
            "time_s": t,
            "switches": switches,
            "switch_overhead_j": switches * SWITCH_ENERGY_J,
        }
        try:
            base_e, base_t = self.backend.baseline_interval()
        except NotImplementedError:
            return out
        base_e_tot = float(np.sum(base_e)) * n_steps
        base_t_tot = float(np.mean(base_t)) * n_steps
        out.update(
            baseline_energy_j=base_e_tot,
            baseline_time_s=base_t_tot,
            saved_energy_j=base_e_tot - e,
            saved_energy_pct=100.0 * (1 - e / max(base_e_tot, 1e-9)),
            slowdown_pct=100.0 * (t / max(base_t_tot, 1e-9) - 1),
        )
        return out
