"""Architecture / shape / layout configuration for the repro framework.

Every assigned architecture gets one module in this package defining an
``ArchConfig`` with the exact dimensions from the public source, plus a
``reduced()`` counterpart used by CPU smoke tests. The FULL configs are
only ever lowered via ``repro.launch.dryrun`` (ShapeDtypeStruct, no
allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set; applies to every LM-family arch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell shape. ``kind`` selects which step fn is lowered."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Layout knobs (per arch x shape overridable)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayoutConfig:
    """Distribution / memory knobs; defaults are safe, per-arch tuned."""

    microbatch: int = 0  # 0 => no grad accumulation (single microbatch)
    param_dtype: str = "bfloat16"
    parallelism: str = "2d"  # "2d" (FSDP x TP) | "fsdp" (no TP; small models)
    remat: str = "full"  # "none" | "full" | "dots"
    seq_parallel: bool = True  # shard residual-stream seq dim over "model"
    opt_dtype: str = "float32"  # adam m/v dtype
    grad_accum_dtype: str = "float32"
    kv_cache_shard: str = "hd"  # "hd" | "heads" | "seq" (decode cache)
    attn_chunk_kv: int = 512  # kv block for chunked-flash xla path
    attn_chunk_q: int = 0  # 0 => no q chunking
    attn_impl: str = "chunked"  # "dense" | "chunked" | "pallas"
    scan_layers: bool = True
    logits_fp32: bool = True
    remat_group: int = 1  # checkpoint every G-th layer (memory / G)
    decode_logits_bf16: bool = False  # bf16 partial-logit ARs at decode
    moe_capacity_override: float = 0.0  # 0 => cfg.moe_capacity_factor


@dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact public dims)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""

    # attention / mlp options
    mlp_gated: bool = True  # SwiGLU (w1,w3,w2) vs classic 2-matrix MLP
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # moe
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_interleave: int = 1  # every k-th layer is MoE (1 => all layers)
    moe_d_ff: int = 0
    moe_shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    dense_d_ff: int = 0  # d_ff of non-MoE layers in interleaved MoE stacks

    # ssm (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2): shared attention block applied every `attn_every`
    attn_every: int = 0

    # encdec (seamless)
    enc_layers: int = 0
    dec_layers: int = 0
    decode_enc_len: int = 4096  # encoder memory length for decode shapes

    # vlm (pixtral): stub patch embeddings occupy the first n positions
    num_img_patches: int = 0

    # layout
    layout: LayoutConfig = field(default_factory=LayoutConfig)
    layout_overrides: Tuple[Tuple[str, Tuple[Tuple[str, object], ...]], ...] = ()

    # which shape cells this arch supports (long_500k only sub-quadratic)
    supports_long_context: bool = False

    def layout_for(self, shape_name: str) -> LayoutConfig:
        for sname, kvs in self.layout_overrides:
            if sname == shape_name:
                return dataclasses.replace(self.layout, **dict(kvs))
        return self.layout

    # ---- derived quantities -------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a multiple of 256 so the vocab
        dim shards cleanly on a 16-way model axis (standard practice)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def n_moe_layers(self) -> int:
        if self.moe_num_experts == 0:
            return 0
        return self.num_layers // self.moe_interleave

    def n_dense_layers(self) -> int:
        if self.family in ("dense", "vlm"):
            return self.num_layers
        if self.family == "moe":
            return self.num_layers - self.n_moe_layers()
        return 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline + memory checks)."""
        D, H, KV, HD = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        attn = D * H * HD + 2 * D * KV * HD + H * HD * D
        if self.qkv_bias:
            attn += (H + 2 * KV) * HD
        mlp = lambda dff: (3 if self.mlp_gated else 2) * D * dff
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        n = emb
        if self.family in ("dense", "vlm"):
            n += self.num_layers * (attn + mlp(self.d_ff) + 2 * D)
        elif self.family == "moe":
            nm, nd = self.n_moe_layers(), self.n_dense_layers()
            expert = mlp(self.moe_d_ff)
            moe_layer = (
                self.moe_num_experts * expert
                + D * self.moe_num_experts  # router
                + (expert if self.moe_shared_expert else 0)
            )
            n += nm * (attn + moe_layer + 2 * D)
            n += nd * (attn + mlp(self.dense_d_ff or self.d_ff) + 2 * D)
        elif self.family == "ssm":
            n += self.num_layers * (self._ssm_block_params() + D)
        elif self.family == "hybrid":
            n += self.num_layers * (self._ssm_block_params() + D)
            n += self._attn_block_params()  # shared
        elif self.family == "encdec":
            enc = self.enc_layers * (attn + mlp(self.d_ff) + 2 * D)
            dec = self.dec_layers * (2 * attn + mlp(self.d_ff) + 3 * D)
            n += enc + dec
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-in experts)."""
        if self.family != "moe":
            return self.param_count()
        expert = (3 if self.mlp_gated else 2) * self.d_model * self.moe_d_ff
        inactive = (
            self.n_moe_layers() * (self.moe_num_experts - self.moe_top_k) * expert
        )
        return self.param_count() - inactive

    def _ssm_block_params(self) -> int:
        D, DI, N, Hs = self.d_model, self.d_inner, self.ssm_state, self.ssm_heads
        conv_ch = DI + 2 * N
        return (
            D * (2 * DI + 2 * N + Hs)  # in_proj
            + conv_ch * self.ssm_conv + conv_ch  # depthwise conv + bias
            + 3 * Hs  # A_log, D, dt_bias
            + DI  # gated rmsnorm
            + DI * D  # out_proj
        )

    def _attn_block_params(self) -> int:
        D, H, KV, HD = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        nm = 3 if self.mlp_gated else 2
        return D * H * HD + 2 * D * KV * HD + H * HD * D + nm * D * self.d_ff + 2 * D

    def supported_shapes(self) -> Tuple[str, ...]:
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context:
            names.append("long_500k")
        return tuple(names)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}
_REDUCED: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig, reduced: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _REDUCED[cfg.name] = reduced
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_reduced(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REDUCED[name]


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (  # noqa: F401
        granite_moe_1b,
        llama3_405b,
        llama4_maverick,
        mamba2_27b,
        pixtral_12b,
        qwen25_3b,
        qwen3_17b,
        seamless_m4t_v2,
        starcoder2_15b,
        zamba2_7b,
    )

    _LOADED = True
