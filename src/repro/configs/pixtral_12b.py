"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB) + mistral-nemo-class
decoder backbone. [hf:mistralai/Pixtral-12B-2409; unverified]

The vision frontend is a stub per the brief: ``input_specs()`` provides
precomputed patch embeddings occupying the first ``num_img_patches``
sequence positions; the remaining positions are text tokens. Loss is on
text positions only.
"""
from repro.configs.base import ArchConfig, LayoutConfig, register

FULL = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    num_img_patches=1024,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
    layout=LayoutConfig(microbatch=64, remat="full", seq_parallel=False),
    layout_overrides=(
        ("decode_32k", (("parallelism", "serve"), ("decode_logits_bf16", True), ("kv_cache_shard", "hd"))),
        ("train_4k", (("parallelism", "fsdp"), ("microbatch", 0))),
    ),
)

REDUCED = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    num_img_patches=8,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none", seq_parallel=False),
)

register(FULL, REDUCED)
