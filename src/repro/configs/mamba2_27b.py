"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]

d_inner = 2*d_model = 5120, head_dim 64 => 80 SSD heads, N = 128.
Sub-quadratic: supports long_500k.
"""
from repro.configs.base import ArchConfig, LayoutConfig, register

FULL = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    supports_long_context=True,
    source="arXiv:2405.21060; unverified",
    layout=LayoutConfig(microbatch=64, remat="full", seq_parallel=False),
    layout_overrides=(
        ("decode_32k", (("parallelism", "serve"), ("decode_logits_bf16", True),)),
        ("long_500k", (("parallelism", "serve"), ("decode_logits_bf16", True),)),
        ("train_4k", (("parallelism", "fsdp"), ("microbatch", 0))),
    ),
)

REDUCED = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=32,
    supports_long_context=True,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none", seq_parallel=False),
)

register(FULL, REDUCED)
