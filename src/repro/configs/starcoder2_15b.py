"""starcoder2-15b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig, LayoutConfig, register

FULL = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    mlp_gated=False,
    vocab_size=49152,
    rope_theta=100_000.0,
    source="arXiv:2402.19173; hf",
    layout=LayoutConfig(microbatch=64, remat="full", seq_parallel=False),
    layout_overrides=(
        ("train_4k", (("parallelism", "fsdp"), ("microbatch", 0))),
        ("prefill_32k", (("attn_chunk_kv", 512), ("microbatch", 0))),
        ("decode_32k", (("parallelism", "serve"), ("decode_logits_bf16", True), ("kv_cache_shard", "hd"))),
    ),
)

REDUCED = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    rope_theta=100_000.0,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none", seq_parallel=False),
)

register(FULL, REDUCED)
