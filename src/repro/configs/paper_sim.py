"""The paper's own experimental configuration (§4.1 Implementation Details).

K = 9 arms (0.8..1.6 GHz, 0.1 GHz steps), 10 ms decision interval,
10 repeats averaged, switching overhead 150 us / 0.3 J per switch
(§4.4), default frequency = f_max = 1.6 GHz.
"""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class PaperSimConfig:
    freqs_ghz: Tuple[float, ...] = tuple(round(0.8 + 0.1 * i, 1) for i in range(9))
    decision_interval_s: float = 0.010
    n_repeats: int = 10
    switch_latency_s: float = 150e-6
    switch_energy_j: float = 0.3
    default_arm: int = 8  # index of 1.6 GHz (arms sorted ascending)
    # EnergyUCB hyper-parameters (Alg. 1)
    alpha: float = 0.2
    switching_penalty: float = 0.05
    mu_init: float = 0.0  # optimistic prior, in normalized-reward units
    seed: int = 0


PAPER_SIM = PaperSimConfig()
