"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal backbone.
[arXiv:2308.11596; hf]

The modality frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings (batch, frames, d_model). 24L encoder + 24L
decoder (brief's "24L" is per stack for the large-v2 backbone). kv=16 on
16 heads => MHA. Decode shapes cache the decoder self-attn KV over
seq_len and cross-attend to a fixed 4096-frame encoder memory.
"""
from repro.configs.base import ArchConfig, LayoutConfig, register

FULL = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=48,  # 24 enc + 24 dec
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    mlp_gated=False,
    vocab_size=256206,
    decode_enc_len=4096,
    source="arXiv:2308.11596; hf",
    layout=LayoutConfig(microbatch=128, remat="full", seq_parallel=False),
    layout_overrides=(
        ("decode_32k", (("parallelism", "serve"), ("decode_logits_bf16", True), ("kv_cache_shard", "hd"))),
        ("train_4k", (("parallelism", "fsdp"), ("microbatch", 0))),
    ),
)

REDUCED = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=4,
    enc_layers=2,
    dec_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    mlp_gated=False,
    vocab_size=256,
    decode_enc_len=32,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none", seq_parallel=False),
)

register(FULL, REDUCED)
