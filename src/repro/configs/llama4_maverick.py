"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared
expert, MoE every other layer (interleave=2), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ArchConfig, LayoutConfig, register

FULL = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,  # expert d_ff (brief)
    vocab_size=202048,
    rope_theta=500_000.0,
    moe_num_experts=128,
    moe_top_k=1,
    moe_interleave=2,
    moe_d_ff=8192,
    moe_shared_expert=True,
    moe_capacity_factor=1.25,
    dense_d_ff=16384,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    layout=LayoutConfig(
        microbatch=128,
        remat="full",
        seq_parallel=False,
        opt_dtype="bfloat16",
        grad_accum_dtype="bfloat16",
    ),
    layout_overrides=(
        ("decode_32k", (("parallelism", "serve2d"), ("decode_logits_bf16", True),)),
    ),
)

REDUCED = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    moe_num_experts=4,
    moe_top_k=1,
    moe_interleave=2,
    moe_d_ff=96,
    moe_shared_expert=True,
    dense_d_ff=128,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none", seq_parallel=False),
)

register(FULL, REDUCED)
