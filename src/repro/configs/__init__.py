"""Config registry: one module per assigned architecture + shape registry."""
from repro.configs.base import (
    SHAPES,
    ArchConfig,
    LayoutConfig,
    ShapeConfig,
    get_arch,
    get_reduced,
    list_archs,
)
from repro.configs.paper_sim import PAPER_SIM, PaperSimConfig

__all__ = [
    "SHAPES",
    "ArchConfig",
    "LayoutConfig",
    "ShapeConfig",
    "get_arch",
    "get_reduced",
    "list_archs",
    "PAPER_SIM",
    "PaperSimConfig",
]
