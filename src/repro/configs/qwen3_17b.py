"""qwen3-1.7b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig, LayoutConfig, register

FULL = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B; hf",
    layout=LayoutConfig(microbatch=128, remat="full", seq_parallel=False),
    layout_overrides=(
        ("decode_32k", (("parallelism", "serve"), ("decode_logits_bf16", True), ("kv_cache_shard", "hd"))),
        ("train_4k", (("parallelism", "fsdp"), ("microbatch", 0))),
    ),
)

REDUCED = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    qk_norm=True,
    tie_embeddings=True,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none", seq_parallel=False),
)

register(FULL, REDUCED)
