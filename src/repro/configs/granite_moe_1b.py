"""granite-moe-1b-a400m [moe] — 32 experts top-8, every layer MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ArchConfig, LayoutConfig, register

FULL = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    rope_theta=10_000.0,
    moe_num_experts=32,
    moe_top_k=8,
    moe_interleave=1,
    moe_d_ff=512,
    moe_shared_expert=False,
    moe_capacity_factor=1.25,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    layout=LayoutConfig(microbatch=256, remat="full", seq_parallel=False),
    layout_overrides=(
        ("decode_32k", (("parallelism", "serve"), ("decode_logits_bf16", True), ("kv_cache_shard", "hd"))),
        ("train_4k", (("parallelism", "fsdp"), ("microbatch", 0))),
    ),
)

REDUCED = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=48,
    vocab_size=256,
    moe_num_experts=4,
    moe_top_k=2,
    moe_interleave=1,
    moe_d_ff=48,
    tie_embeddings=True,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none", seq_parallel=False),
)

register(FULL, REDUCED)
