"""zamba2-7b [hybrid] — Mamba2 backbone + shared full-attention block
applied every 6 layers. [arXiv:2411.15242; unverified]

81 Mamba2 layers; ONE shared attention+MLP block (weight-tied) applied
before mamba layer i when i % 6 == 0 (14 applications, each with its own
KV cache at decode time). Sub-quadratic overall: supports long_500k via
chunked-flash attention in the shared blocks + constant-size SSM state.
"""
from repro.configs.base import ArchConfig, LayoutConfig, register

FULL = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    attn_every=6,
    supports_long_context=True,
    source="arXiv:2411.15242; unverified",
    layout=LayoutConfig(microbatch=64, remat="full", seq_parallel=False),
    layout_overrides=(
        ("decode_32k", (("parallelism", "serve"), ("decode_logits_bf16", True), ("kv_cache_shard", "hd"))),
        ("train_4k", (("parallelism", "fsdp"), ("microbatch", 0))),
        ("long_500k", (("parallelism", "serve"), ("decode_logits_bf16", True), ("kv_cache_shard", "hd"), ("attn_chunk_kv", 2048))),
    ),
)

REDUCED = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=7,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=32,
    attn_every=3,
    supports_long_context=True,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none", seq_parallel=False),
)

register(FULL, REDUCED)
