"""llama3-405b [dense] — GQA, 128k vocab. [arXiv:2407.21783; unverified]

The heaviest assigned cell. On a 256-chip v5e pod the fp32-state Adam
footprint alone (4.9 TB) cannot fit, so this config uses bf16 optimizer
states + bf16 grad accumulation + microbatched grad-accum + sequence-
parallel residual checkpoints (see EXPERIMENTS.md §Perf for the
iteration log that arrived here).
"""
from repro.configs.base import ArchConfig, LayoutConfig, register

FULL = ArchConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783; unverified",
    layout=LayoutConfig(
        microbatch=64,
        remat="full",
        remat_group=9,
        seq_parallel=False,
        opt_dtype="bfloat16",
        grad_accum_dtype="bfloat16",
    ),
    layout_overrides=(
        ("decode_32k", (("parallelism", "serve2d"), ("decode_logits_bf16", True),)),
        ("prefill_32k", (("attn_chunk_kv", 256), ("microbatch", 16))),
    ),
)

REDUCED = ArchConfig(
    name="llama3-405b",
    family="dense",
    num_layers=3,
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none", seq_parallel=False),
)

register(FULL, REDUCED)
