"""Paper Fig. 4: switching-cost analysis on Llama — number of switches,
switching energy overhead, and added execution time, with vs. without
the switching-aware penalty."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import energy_ucb, get_app, make_env_params, run_repeats
from repro.core.calibration import SWITCH_ENERGY_J, SWITCH_LATENCY_S


def run(fast: bool = True, out_json: str = None):
    reps = 3 if fast else 10
    p = make_env_params(get_app("llama"))
    key = jax.random.key(0)
    w = run_repeats(energy_ucb(switching_penalty=0.05), p, key, reps)
    wo = run_repeats(energy_ucb(switching_penalty=0.0), p, key, reps)
    rows = []
    print(f"{'metric':28s} {'w/o penalty':>14s} {'with penalty':>14s}")
    sw_w, sw_wo = w["switches"].mean(), wo["switches"].mean()
    print(f"{'switches':28s} {sw_wo:14.0f} {sw_w:14.0f}   ({sw_wo/max(sw_w,1):.1f}x reduction; paper 6.7x)")
    e_w, e_wo = sw_w * SWITCH_ENERGY_J / 1e3, sw_wo * SWITCH_ENERGY_J / 1e3
    print(f"{'switch energy overhead (kJ)':28s} {e_wo:14.3f} {e_w:14.3f}")
    t_w, t_wo = sw_w * SWITCH_LATENCY_S, sw_wo * SWITCH_LATENCY_S
    print(f"{'switch time overhead (s)':28s} {t_wo:14.3f} {t_w:14.3f}")
    rows.append({
        "name": "fig4_switching_llama",
        "us_per_call": "",
        "derived": f"switches {sw_wo:.0f}->{sw_w:.0f} ({sw_wo/max(sw_w,1):.1f}x)",
    })
    return rows


if __name__ == "__main__":
    run()
