"""Paper Table 1: energy (kJ) for 9 static frequencies + 7 dynamic/RL
methods + EnergyUCB across the 9 Aurora applications, plus the Saved
Energy and Energy Regret rows."""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import ALL_APPS, FAST_APPS, bench_policy_energy
from repro.core import TABLE1_KJ, get_app, make_env_params, static_energy_kj

METHODS = (
    "RRFreq", "eps-greedy", "EnergyTS", "RL-Power",
    "DRLCap", "DRLCap-Online", "DRLCap-Cross", "EnergyUCB",
)


def run(fast: bool = True, n_repeats: int = None, out_json: str = None):
    apps = ALL_APPS  # the headline table always covers all 9 workloads
    reps = n_repeats or (5 if fast else 10)
    table = {}
    t0 = time.time()
    for i, f in enumerate([f"{0.8+0.1*k:.1f} GHz" for k in range(9)][::-1]):
        arm = 8 - i
        table[f] = {
            a: float(static_energy_kj(make_env_params(get_app(a)), arm)) for a in apps
        }
    for m in METHODS:
        table[m] = {a: bench_policy_energy(m, a, reps) for a in apps}
    ucb = table["EnergyUCB"]
    table["Saved Energy"] = {a: TABLE1_KJ[a][-1] - ucb[a] for a in apps}
    table["Energy Regret"] = {a: ucb[a] - TABLE1_KJ[a].min() for a in apps}

    # render
    hdr = f"{'Method':15s}" + "".join(f"{a:>10s}" for a in apps)
    lines = [hdr]
    for m, row in table.items():
        lines.append(f"{m:15s}" + "".join(f"{row[a]:10.2f}" for a in apps))
    text = "\n".join(lines)
    print(text)
    regrets = [table["Energy Regret"][a] / TABLE1_KJ[a].min() for a in apps]
    derived = f"mean_energy_regret_pct={100*np.mean(regrets):.2f}"
    print(f"# {derived}  ({time.time()-t0:.0f}s)")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(table, f, indent=1)
    return [{"name": "table1_energy", "us_per_call": "", "derived": derived}]


if __name__ == "__main__":
    import sys

    run(fast="--fast" in sys.argv, out_json="results/table1.json")
