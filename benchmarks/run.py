"""Benchmark aggregator: one module per paper table/figure + the
framework benches. Prints ``name,us_per_call,derived`` CSV at the end.

  PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    fast = "--full" not in sys.argv
    os.makedirs("results", exist_ok=True)
    from benchmarks import (
        common,
        controller_overhead,
        energy_cells,
        perf_compare,
        fig3_regret,
        fig4_switching,
        fig5a_reward,
        fig5b_qos,
        roofline_table,
        table1_energy,
        table2_ablation,
    )

    modules = [
        ("table1_energy", table1_energy),
        ("fig3_regret", fig3_regret),
        ("table2_ablation", table2_ablation),
        ("fig4_switching", fig4_switching),
        ("fig5a_reward", fig5a_reward),
        ("fig5b_qos", fig5b_qos),
        ("roofline_table", roofline_table),
        ("perf_compare", perf_compare),
        ("energy_cells", energy_cells),
        ("controller_overhead", controller_overhead),
    ]
    rows = []
    for name, mod in modules:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            rows.extend(mod.run(fast=fast) or [])
            print(f"[{name}: {time.time()-t0:.0f}s]")
        except Exception:
            traceback.print_exc()
            rows.append({"name": name, "us_per_call": "", "derived": "ERROR"})
    print("\n===== summary CSV =====")
    common.emit(rows)


if __name__ == "__main__":
    main()
