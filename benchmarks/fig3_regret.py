"""Paper Fig. 3: cumulative (reward) regret traces per method,
seed-averaged through the unified rollout engine (one vmapped
run_repeats call per method instead of a single-seed episode)."""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import FAST_APPS, dynamic_policies
from repro.core import get_app, make_env_params, run_repeats


def run(fast: bool = True, out_json: str = None):
    apps = ("tealeaf", "miniswp") if fast else FAST_APPS
    reps = 3 if fast else 10
    traces = {}
    rows = []
    for app in apps:
        p = make_env_params(get_app(app))
        traces[app] = {}
        n_min = None
        for name, pol in dynamic_policies().items():
            out = run_repeats(pol, p, jax.random.key(0), reps)
            cr = out["cum_regret"].mean(axis=0)  # seed-averaged trace
            n = int(out["steps"].min())
            n_min = n if n_min is None else min(n_min, n)
            ds = np.linspace(0, n - 1, 200).astype(int)
            traces[app][name] = {
                "t": ds.tolist(),
                "regret": cr[ds].round(2).tolist(),
            }
        t4k = min(4000, n_min - 1)
        ucb4k = traces[app]["EnergyUCB"]["regret"][
            int(np.searchsorted(traces[app]["EnergyUCB"]["t"], t4k))
        ]
        rr4k = traces[app]["RRFreq"]["regret"][
            int(np.searchsorted(traces[app]["RRFreq"]["t"], t4k))
        ]
        print(f"{app}: cum regret @t={t4k}: EnergyUCB={ucb4k:.1f}  RRFreq={rr4k:.1f} "
              f"(paper tealeaf: 1.99k vs 25.51k, unnormalized units)")
        rows.append({
            "name": f"fig3_regret_{app}",
            "us_per_call": "",
            "derived": f"ucb@4k={ucb4k:.1f};rrfreq@4k={rr4k:.1f};ratio={rr4k/max(ucb4k,1e-9):.1f}x",
        })
    if out_json:
        os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(traces, f)
    return rows


if __name__ == "__main__":
    import sys

    run(fast="--fast" in sys.argv, out_json="results/fig3_regret.json")
