"""Paper Table 2: ablation on the three most energy-intensive apps —
EnergyUCB vs w/o optimistic init vs w/o switching penalty."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import energy_ucb, get_app, make_env_params, run_repeats

APPS = ("sph_exa", "llama", "diffusion")


def run(fast: bool = True, out_json: str = None):
    reps = 3 if fast else 10
    rows = []
    print(f"{'app':10s} {'EnergyUCB':>14s} {'w/o Opt.Ini.':>14s} {'w/o Penalty':>14s}")
    for app in APPS:
        p = make_env_params(get_app(app))
        key = jax.random.key(0)
        full = run_repeats(energy_ucb(), p, key, reps)["energy_kj"]
        noopt = run_repeats(energy_ucb(optimistic_init=False), p, key, reps)["energy_kj"]
        nopen = run_repeats(energy_ucb(switching_penalty=0.0), p, key, reps)["energy_kj"]
        print(
            f"{app:10s} {full.mean():9.2f}±{full.std():4.2f}"
            f" {noopt.mean():9.2f}±{noopt.std():4.2f}"
            f" {nopen.mean():9.2f}±{nopen.std():4.2f}"
        )
        rows.append({
            "name": f"table2_ablation_{app}",
            "us_per_call": "",
            "derived": (
                f"full={full.mean():.2f};no_optinit={noopt.mean():.2f};"
                f"no_penalty={nopen.mean():.2f}"
            ),
        })
    return rows


if __name__ == "__main__":
    import sys

    run(fast="--fast" in sys.argv)
