"""Paper Table 2: ablation on the three most energy-intensive apps —
EnergyUCB vs w/o optimistic init vs w/o switching penalty.

With hyperparams-as-data all three variants (plus an alpha x lambda
calibration grid) are one stacked PolicyParams batch: run_sweep pushes
configs x seeds through a single jitted trace per app instead of
retracing per variant."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import (
    energy_ucb,
    get_app,
    make_env_params,
    make_policy_params,
    run_sweep,
    stack_policy_params,
    summarize_sweep,
    sweep_policy_params,
)

APPS = ("sph_exa", "llama", "diffusion")

VARIANTS = (
    ("full", dict()),
    ("no_optinit", dict(optimistic_init=False)),
    ("no_penalty", dict(switching_penalty=0.0)),
)


def run(fast: bool = True, out_json: str = None):
    reps = 3 if fast else 10
    pol = energy_ucb()
    stacked = stack_policy_params([make_policy_params(**kw) for _, kw in VARIANTS])
    rows = []
    print(f"{'app':10s} {'EnergyUCB':>14s} {'w/o Opt.Ini.':>14s} {'w/o Penalty':>14s}")
    for app in APPS:
        p = make_env_params(get_app(app))
        out = run_sweep(pol, stacked, p, jax.random.key(0), n_repeats=reps)
        e = out["energy_kj"]  # (n_variants, reps)
        print(
            f"{app:10s} "
            + " ".join(f"{e[i].mean():9.2f}±{e[i].std():4.2f}" for i in range(len(VARIANTS)))
        )
        rows.append({
            "name": f"table2_ablation_{app}",
            "us_per_call": "",
            "derived": ";".join(
                f"{name}={e[i].mean():.2f}" for i, (name, _) in enumerate(VARIANTS)
            ),
        })
    # beyond-paper: the alpha x lambda calibration grid, still one trace
    grid_a, grid_l = (0.05, 0.1, 0.2), (0.0, 0.01, 0.02, 0.05)
    p = make_env_params(get_app(APPS[0]))
    grid = sweep_policy_params(grid_a, grid_l)
    out = run_sweep(pol, grid, p, jax.random.key(1), n_repeats=reps)
    summaries = summarize_sweep(p, out["energy_kj"])
    best = int(np.argmin([s["energy_kj"] for s in summaries]))
    a, l = grid_a[best // len(grid_l)], grid_l[best % len(grid_l)]
    print(f"alpha x lambda grid ({len(summaries)} configs, one trace): "
          f"best alpha={a} lam={l} -> {summaries[best]['energy_kj']:.2f} kJ")
    rows.append({
        "name": f"table2_grid_{APPS[0]}",
        "us_per_call": "",
        "derived": f"best_alpha={a};best_lam={l};energy={summaries[best]['energy_kj']:.2f}",
    })
    return rows


if __name__ == "__main__":
    import sys

    run(fast="--fast" in sys.argv)
