"""End-to-end serving-energy benchmark: joules-per-served-token vs
p99-latency SLO violation rate on a bursty diurnal trace.

The paper's headline claim transplanted to the serving setting
(ISSUE 7 acceptance criteria), six configs over the same seeded
traffic (`bursty_diurnal_traffic`) against the roofline-parameterized
`ServingBackend`:

- ``fmax`` / ``lowest``: static ladder endpoints. f_max is the QoS
  reference (meets the SLO with headroom, pays peak power); the lowest
  frequency saturates prefill during peak bursts and blows the p99.
- ``ucb``: one shared unconstrained EnergyUCB lane per node — lowest
  joules/token, but free to violate the SLO.
- ``ucb_qos``: shared lane with the slowdown budget (QoS feasible set)
  — SLO-compliant, but one arm must serve both phases.
- ``phase``: per-phase lanes (prefill row / decode row per node),
  both unconstrained.
- ``phase_qos``: the physics-informed config from
  ``repro.core.phase_policy`` — compute-bound prefill keeps the tight
  slowdown budget, bandwidth-bound decode (whose step time is flat in
  frequency) runs unconstrained. Beats the shared QoS config on
  joules/token at equal SLO compliance: the decode lane's savings are
  latency-free.

Timing rows (numeric ``us_per_call`` = wall microseconds per decision
interval, end to end through the streaming controller + discrete-event
serve loop) feed ``scripts/bench_check.py`` in the CI bench-smoke
lane; the energy/QoS claims land in the JSON payload under ``serve``
and are asserted by tests/test_workload.py at smaller scale.

CLI (the CI benchmark-smoke job runs --quick and uploads the JSON):

  PYTHONPATH=src:. python benchmarks/serve_energy.py \\
      [--quick] [--json BENCH_serve_energy.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

import jax
import numpy as np

from repro.core import (
    ActionSpace,
    energy_ucb,
    make_policy_params,
    phase_policy,
    static_policy,
)
from repro.core.calibration import FREQS_GHZ
from repro.energy import EnergyController
from repro.kernels import ops
from repro.workload import ServingBackend, bursty_diurnal_traffic
from repro.workload.serving_backend import SERVE_P_UNC_W

K = len(FREQS_GHZ)
MODEL = "qwen2.5-3b"
QOS_DELTA = 0.01  # slowdown budget of the constrained configs
VIOL_BUDGET = 0.05  # acceptance bar on the post-warmup violation rate
# factored scenario: (core x uncore) product ladder on uncore-aware
# physics (p_unc_w > 0). The scalar baseline runs the SAME physics with
# uncore pinned at max — the best a core-only ladder can do there.
UNC_LADDER = (0.7, 1.0)


def configs(n_nodes: int):
    """name -> (policy, phase_split)."""
    return {
        "fmax": (static_policy(K - 1), False),
        "lowest": (static_policy(0), False),
        "ucb": (energy_ucb(), False),
        "ucb_qos": (energy_ucb(qos_delta=QOS_DELTA), False),
        "phase": (energy_ucb(), True),
        "phase_qos": (
            phase_policy(
                n_nodes,
                prefill=make_policy_params(qos_delta=QOS_DELTA),
                decode=make_policy_params(qos_delta=None),
            ),
            True,
        ),
    }


def run_config(name, policy, phase_split, *, n_nodes, t_intervals, warmup):
    traf = bursty_diurnal_traffic()
    be = ServingBackend(traf, MODEL, n_nodes=n_nodes, phase_split=phase_split)
    ctl = EnergyController(policy, be, use_kernel=False, record_history=False)
    t0 = time.perf_counter()
    ctl.run(t_intervals)
    wall = time.perf_counter() - t0
    c = be.read_counters()
    energy = float(c.energy_j.sum())
    tok = be.served_tokens
    rep = be.slo_report(warmup_s=warmup * traf.interval_s)
    return {
        "name": name,
        "j_per_token": round(energy / max(tok, 1), 4),
        "energy_j": round(energy, 1),
        "served_tokens": int(tok),
        "violation_rate": round(rep["violation_rate"], 4),
        "p99_s": round(rep["p99_s"], 4),
        "slo_s": round(rep["slo_s"], 4),
        "completed": rep["completed"],
        "us_per_interval": wall / t_intervals * 1e6,
    }


def factored_configs(n_nodes: int):
    """name -> (policy, uncore_ladder): the factored phase-split config
    vs the best scalar config on identical uncore-aware physics. Both
    keep the slowdown budget on the compute-bound prefill lane."""
    space = ActionSpace(K, len(UNC_LADDER))
    return {
        "scalar_unc_qos": (
            phase_policy(
                n_nodes,
                prefill=make_policy_params(qos_delta=QOS_DELTA),
                decode=make_policy_params(qos_delta=None),
            ),
            None,
        ),
        "factored_qos": (
            phase_policy(
                n_nodes,
                prefill=make_policy_params(k=space.k,
                                           default_arm=space.k - 1,
                                           qos_delta=QOS_DELTA),
                decode=make_policy_params(k=space.k,
                                          default_arm=space.k - 1,
                                          qos_delta=None),
                space=space,
            ),
            UNC_LADDER,
        ),
    }


def run_factored_config(name, policy, uncore_ladder, *, n_nodes,
                        t_intervals, warmup):
    """One factored-scenario config: stepped manually so the (T, lanes)
    arm trajectory yields per-dimension switch counts, and the energy
    accounting splits at the warm-up boundary (the acceptance criterion
    is STEADY-STATE energy — exploration over k_core*k_unc arms is paid
    before it)."""
    traf = bursty_diurnal_traffic()
    be = ServingBackend(traf, MODEL, n_nodes=n_nodes, phase_split=True,
                        uncore_ladder=uncore_ladder, p_unc_w=SERVE_P_UNC_W)
    ctl = EnergyController(policy, be, use_kernel=False,
                           record_history=False)
    arms_hist = []
    e_warm = tok_warm = 0.0
    t0 = time.perf_counter()
    for t in range(t_intervals):
        ctl.step()
        arms_hist.append(np.asarray(ctl.last_arms, np.int64).copy())
        if t + 1 == warmup:
            e_warm = float(be.read_counters().energy_j.sum())
            tok_warm = be.served_tokens
    wall = time.perf_counter() - t0
    c = be.read_counters()
    energy = float(c.energy_j.sum())
    tok = be.served_tokens
    rep = be.slo_report(warmup_s=warmup * traf.interval_s)
    arms = np.stack(arms_hist)  # (T, 2 * n_nodes): prefill/decode lanes
    core, unc = arms // be.k_unc, arms % be.k_unc
    steady = arms[warmup:]
    return {
        "name": name,
        "k_unc": be.k_unc,
        "steady_j_per_token": round((energy - e_warm)
                                    / max(tok - tok_warm, 1), 4),
        "j_per_token": round(energy / max(tok, 1), 4),
        "energy_j": round(energy, 1),
        "served_tokens": int(tok),
        "violation_rate": round(rep["violation_rate"], 4),
        "p99_s": round(rep["p99_s"], 4),
        "slo_s": round(rep["slo_s"], 4),
        "completed": rep["completed"],
        "core_switches": int((core[1:] != core[:-1]).sum()),
        "unc_switches": int((unc[1:] != unc[:-1]).sum()),
        # modal steady-state uncore rung per phase lane (prefill rows
        # are even, decode odd) — the phase asymmetry, made visible
        "steady_unc_mode_prefill": int(np.median(steady[:, 0::2]
                                                 % be.k_unc)),
        "steady_unc_mode_decode": int(np.median(steady[:, 1::2]
                                                % be.k_unc)),
        "us_per_interval": wall / t_intervals * 1e6,
    }


def run(out_json=None, quick: bool = False):
    if quick:
        n_nodes, t_intervals, warmup = 1, 240, 80
    else:
        n_nodes, t_intervals, warmup = 2, 800, 200

    results = {}
    rows = []
    for name, (pol, split) in configs(n_nodes).items():
        r = run_config(name, pol, split, n_nodes=n_nodes,
                       t_intervals=t_intervals, warmup=warmup)
        results[name] = r
        rows.append({
            "name": f"serve_interval_{name}",
            "us_per_call": round(r["us_per_interval"], 2),
            "derived": (f"{r['j_per_token']} J/tok, "
                        f"viol {r['violation_rate']}, "
                        f"p99 {r['p99_s']}s (slo {r['slo_s']}s)"),
        })
        print(f"{name:10s} J/tok={r['j_per_token']:.4f} "
              f"viol={r['violation_rate']:.3f} p99={r['p99_s']:.3f}s "
              f"({r['us_per_interval']:.0f} us/interval)")

    # factored scenario: (core x uncore) arms vs the best scalar config
    # on identical uncore-aware physics, steady-state accounting
    for name, (pol, ladder) in factored_configs(n_nodes).items():
        r = run_factored_config(name, pol, ladder, n_nodes=n_nodes,
                                t_intervals=t_intervals, warmup=warmup)
        results[name] = r
        rows.append({
            "name": f"serve_interval_{name}",
            "us_per_call": round(r["us_per_interval"], 2),
            "derived": (f"{r['steady_j_per_token']} J/tok steady, "
                        f"viol {r['violation_rate']}, "
                        f"switches core {r['core_switches']}"
                        f"/unc {r['unc_switches']}"),
        })
        print(f"{name:15s} steady J/tok={r['steady_j_per_token']:.4f} "
              f"viol={r['violation_rate']:.3f} switches "
              f"core={r['core_switches']} unc={r['unc_switches']} "
              f"unc-mode pre={r['steady_unc_mode_prefill']} "
              f"dec={r['steady_unc_mode_decode']}")

    # the acceptance-criteria booleans, recomputed on every run
    claims = {
        "ucb_saves_vs_fmax":
            results["ucb"]["j_per_token"] < results["fmax"]["j_per_token"],
        "qos_compliant":
            results["ucb_qos"]["violation_rate"] <= VIOL_BUDGET,
        "fmax_compliant_lowest_not":
            results["fmax"]["violation_rate"] <= VIOL_BUDGET
            < results["lowest"]["violation_rate"],
        "phase_beats_shared_at_compliance":
            results["phase_qos"]["j_per_token"]
            < results["ucb_qos"]["j_per_token"]
            and results["phase_qos"]["violation_rate"] <= VIOL_BUDGET,
        # the factored controller's steady-state energy beats the best
        # scalar-core-ladder config on the same uncore-aware physics,
        # while its QoS-constrained prefill lane keeps the budget
        "factored_beats_scalar_at_compliance":
            results["factored_qos"]["steady_j_per_token"]
            < results["scalar_unc_qos"]["steady_j_per_token"]
            and results["factored_qos"]["violation_rate"] <= VIOL_BUDGET,
    }
    for k, v in claims.items():
        print(f"claim {k}: {'PASS' if v else 'FAIL'}")

    if out_json is not None:
        payload = {
            "benchmark": "serve_energy",
            "mode": "quick" if quick else "full",
            "model": MODEL,
            "n_nodes": n_nodes,
            "t_intervals": t_intervals,
            "qos_delta": QOS_DELTA,
            "backend": jax.default_backend(),
            "pallas": ops.pallas_available(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "serve": results,
            "claims": claims,
            "rows": rows,
        }
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {len(rows)} rows -> {out_json}")
    return results, claims


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (1 node, 240 intervals)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + claims + env metadata as JSON")
    args = ap.parse_args(argv)
    _, claims = run(out_json=args.json, quick=args.quick)
    return 0 if all(claims.values()) else 1


if __name__ == "__main__":
    sys.exit(main())
