"""Integration benchmark (beyond the paper's tables): EnergyUCB driving
DVFS for the assigned (arch x shape) cells. Each cell's dry-run roofline
terms parameterize a StepEnergyModel; the controller discovers the cell's
energy-optimal frequency online. Memory/collective-bound cells (decode,
long-context, MoE-dispatch-heavy) yield real savings; compute-bound train
cells correctly converge to f_max."""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.roofline_table import cell_row
from repro.configs import get_arch, list_archs
from repro.core import (
    ActionSpace,
    energy_ucb,
    factored_energy_ucb,
    run_repeats,
    static_energy_kj,
)
from repro.core.calibration import FREQS_GHZ
from repro.energy.model import (
    UNC_FREQS,
    StepEnergyModel,
    env_params_from_roofline,
    factored_env_params_from_roofline,
)

CELLS_FAST = [
    ("llama3-405b", "train_4k"),
    ("starcoder2-15b", "decode_32k"),
    ("granite-moe-1b-a400m", "train_4k"),
    ("mamba2-2.7b", "long_500k"),
]


def run(fast: bool = True, dryrun_dir: str = "results/dryrun", out_json=None):
    # the per-cell rollouts are cheap (jitted); cover every cell always
    cells = [(a, s) for a in list_archs() for s in get_arch(a).supported_shapes()]
    rows = []
    print(f"{'cell':42s} {'bound':>7s} {'opt_f':>6s} {'saved%':>8s} {'slow%':>7s}")
    for arch, shape in cells:
        r = cell_row(dryrun_dir, arch, shape)
        if r is None:
            continue
        # decision interval = max(one step, 10 ms): sub-ms decode steps
        # are grouped so the 150 us/0.3 J switch cost stays amortized,
        # exactly the paper's 10 ms GEOPM cadence.
        tstep = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        g = max(1, int(np.ceil(0.010 / max(tstep, 1e-9))))
        m = StepEnergyModel(
            t_compute_s=g * r["t_compute_s"],
            t_memory_s=g * r["t_memory_s"],
            t_collective_s=g * r["t_collective_s"],
            steps_total=300,
        )
        p = env_params_from_roofline(m)
        out = run_repeats(energy_ucb(), p, jax.random.key(0), 3)
        e = out["energy_kj"].mean()
        e_def = m.static_energy_j(8) / 1e3
        t_def = m.step(8)["step_time_s"] * m.steps_total
        saved = 100 * (1 - e / e_def)
        slow = 100 * (out["time_s"].mean() / t_def - 1)
        opt_f = 0.8 + 0.1 * m.optimal_arm()
        print(f"{arch+'/'+shape:42s} {r['bottleneck']:>7s} {opt_f:6.1f} "
              f"{saved:8.2f} {slow:7.2f}")
        rows.append({
            "name": f"energyucb_{arch}_{shape}",
            "us_per_call": "",
            "derived": f"bound={r['bottleneck']};saved={saved:.2f}%;slowdown={slow:.2f}%",
        })
        if r["bottleneck"] == "compute":
            continue
        # factored (core x uncore) rows for the memory/collective-bound
        # cells — where the uncore axis has leverage. Both the factored
        # run and its baseline use the SAME uncore-aware power model;
        # the baseline is the best STATIC scalar-core arm on the pinned
        # (y = 1) ladder, i.e. the best a core-only ladder can reach.
        pf = factored_env_params_from_roofline(m)
        pf1 = factored_env_params_from_roofline(m, unc_freqs=(1.0,))
        space = ActionSpace(len(FREQS_GHZ), len(UNC_FREQS))
        outf = run_repeats(factored_energy_ucb(space), pf, jax.random.key(1), 3)
        ef = outf["energy_kj"].mean()
        e_best_scalar = min(static_energy_kj(pf1, i)
                            for i in range(len(FREQS_GHZ)))
        saved_f = 100 * (1 - ef / e_best_scalar)
        print(f"{'  factored ' + str(space.k_core) + 'x' + str(space.k_unc):42s}"
              f" {'':>7s} {'':>6s} {saved_f:8.2f} vs best scalar arm")
        rows.append({
            "name": f"factored_{arch}_{shape}",
            "us_per_call": "",
            "derived": (f"bound={r['bottleneck']};"
                        f"saved_vs_best_scalar={saved_f:.2f}%;"
                        f"k={space.k_core}x{space.k_unc}"),
        })
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    run(fast=False, out_json="results/energy_cells.json")
