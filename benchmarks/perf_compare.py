"""§Perf audit table: baseline (results/dryrun_baseline) vs final
(results/dryrun) per-device collective bytes and peak memory for every
cell — the measured record behind EXPERIMENTS.md §Perf."""
from __future__ import annotations

import glob
import json
import os


def _load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*__pod.json")):
        r = json.load(open(f))
        if r.get("status") == "ok":
            out[(r["arch"], r["shape"])] = r
    return out


def run(fast: bool = True, base_dir="results/dryrun_baseline",
        final_dir="results/dryrun", out_json=None):
    base, final = _load(base_dir), _load(final_dir)
    rows = []
    print(f"{'cell':44s} {'coll GB base':>12s} {'final':>9s} {'x':>6s} {'peak GB base':>13s} {'final':>7s}")
    for key in sorted(final):
        if key not in base:
            continue
        b, f = base[key], final[key]
        cb = b["collectives"]["total_bytes_per_device"] / 2**30
        cf = f["collectives"]["total_bytes_per_device"] / 2**30
        mb = b["memory_per_device"]["peak_est_bytes"] / 2**30
        mf = f["memory_per_device"]["peak_est_bytes"] / 2**30
        ratio = cb / max(cf, 1e-9)
        print(f"{key[0]+'/'+key[1]:44s} {cb:12.2f} {cf:9.2f} {ratio:5.1f}x {mb:13.2f} {mf:7.2f}")
        rows.append({"arch": key[0], "shape": key[1], "coll_gb_base": cb,
                     "coll_gb_final": cf, "speedup_x": ratio,
                     "peak_gb_base": mb, "peak_gb_final": mf})
    if out_json:
        with open(out_json, "w") as fp:
            json.dump(rows, fp, indent=1)
    import numpy as np

    gm = float(np.exp(np.mean([np.log(max(r["speedup_x"], 1e-9)) for r in rows]))) if rows else 0
    print(f"# geometric-mean collective reduction: {gm:.2f}x over {len(rows)} cells")
    return [{"name": "perf_compare", "us_per_call": "",
             "derived": f"geomean_collective_reduction={gm:.2f}x;cells={len(rows)}"}]


if __name__ == "__main__":
    run(out_json="results/perf_compare.json")
