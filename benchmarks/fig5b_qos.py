"""Paper Fig. 5(b): QoS analysis — static execution times vs
unconstrained EnergyUCB vs constrained (delta=0.05)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import energy_ucb, get_app, make_env_params, run_repeats
from repro.core.calibration import FREQS_GHZ

APPS = ("clvleaf", "miniswp")


def run(fast: bool = True, out_json: str = None):
    reps = 3 if fast else 10
    rows = []
    for app in APPS:
        a = get_app(app)
        p = make_env_params(a)
        t_static = a.time_s(np.asarray(FREQS_GHZ))
        unc = run_repeats(energy_ucb(), p, jax.random.key(0), reps)
        con = run_repeats(energy_ucb(qos_delta=0.05), p, jax.random.key(0), reps)
        t_max = t_static[-1]
        s_unc = 100 * (unc["time_s"].mean() / t_max - 1)
        s_con = 100 * (con["time_s"].mean() / t_max - 1)
        print(f"{app}: static times 0.8..1.6 GHz = "
              + ", ".join(f"{t:.1f}" for t in t_static))
        print(f"  unconstrained: t={unc['time_s'].mean():.1f}s slowdown={s_unc:.2f}% "
              f"E={unc['energy_kj'].mean():.2f} kJ")
        print(f"  constrained d=0.05: t={con['time_s'].mean():.1f}s slowdown={s_con:.2f}% "
              f"E={con['energy_kj'].mean():.2f} kJ  (paper: 4.05%/4.82%)")
        rows.append({
            "name": f"fig5b_qos_{app}",
            "us_per_call": "",
            "derived": f"slowdown_unc={s_unc:.2f}%;slowdown_qos={s_con:.2f}%",
        })
    return rows


if __name__ == "__main__":
    run()
