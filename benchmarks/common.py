"""Shared benchmark helpers: policy zoo, timing, CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.core import (
    energy_ts,
    energy_ucb,
    eps_greedy,
    get_app,
    make_env_params,
    rr_freq,
    run_drlcap_cross,
    run_drlcap_protocol,
    run_repeats,
)
from repro.core.rl import drlcap, rl_power

ALL_APPS = (
    "lbm", "tealeaf", "clvleaf", "miniswp", "pot3d",
    "sph_exa", "weather", "llama", "diffusion",
)
FAST_APPS = ("tealeaf", "miniswp", "clvleaf", "llama")


def dynamic_policies():
    return {
        "RRFreq": rr_freq(),
        "eps-greedy": eps_greedy(),
        "EnergyTS": energy_ts(),
        "RL-Power": rl_power(),
        "DRLCap-Online": drlcap(name="DRLCap-Online"),
        "EnergyUCB": energy_ucb(),
    }


def bench_policy_energy(name: str, app: str, n_repeats: int, seed: int = 0) -> float:
    p = make_env_params(get_app(app))
    key = jax.random.key(seed)
    if name == "DRLCap":
        es = [
            float(run_drlcap_protocol(drlcap, p, k)["energy_kj"])
            for k in jax.random.split(key, max(2, n_repeats // 3))
        ]
        return float(np.mean(es))
    if name == "DRLCap-Cross":
        others = [a for a in ALL_APPS if a != app][:2]
        srcs = [make_env_params(get_app(a)) for a in others]
        es = [
            float(run_drlcap_cross(drlcap, p, srcs, k)["energy_kj"])
            for k in jax.random.split(key, 2)
        ]
        return float(np.mean(es))
    pol = dynamic_policies()[name]
    return float(run_repeats(pol, p, key, n_repeats)["energy_kj"].mean())


def time_us(fn: Callable, n: int = 100, warmup: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def emit(rows: List[Dict]):
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
