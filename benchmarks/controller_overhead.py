"""Controller-plane overhead: us per decision for a single jitted
controller (select+update), for the full Aurora-scale fleet (63,720
controllers) — vmapped, and through the fused Pallas select+update
fleet step — and end-to-end through the streaming EnergyController
(actuate -> advance -> read counters -> derive Obs -> policy step), the
path every deployment runs. The paper's feasibility argument
('lightweight') quantified.

CLI (the CI benchmark-smoke job runs --quick and uploads the JSON):

  PYTHONPATH=src:. python benchmarks/controller_overhead.py \\
      [--full] [--quick] [--json BENCH_controller_overhead.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_us
from repro.core import (
    ActionSpace,
    energy_ucb,
    factored_energy_ucb,
    get_app,
    make_env_params,
    make_factored_env_params,
)
from repro.core.fleet import Fleet
from repro.core.simulator import Obs, env_init, env_step
from repro.energy import EnergyController, SimBackend
from repro.energy.backend import record_trace
from repro.kernels import ops


def run(fast: bool = True, out_json=None, quick: bool = False):
    """``fast`` shrinks the fleet from Aurora scale; ``quick`` shrinks
    further (CI smoke: minutes, not tens of minutes, on a cold CPU
    runner). ``out_json`` writes the rows + environment metadata so CI
    can upload the perf trajectory as an artifact."""
    rows = []
    pol = energy_ucb()
    p = make_env_params(get_app("tealeaf"))
    st = pol.init(jax.random.key(0))
    es = env_init(p)
    key = jax.random.key(1)

    # hyperparams-as-data: params ride as operands, fns are the only
    # static part, so every config shares these two traces
    sel = jax.jit(pol.fns.select)
    arm = sel(pol.params, st, key)
    _, obs = env_step(p, es, arm, key)
    upd = jax.jit(pol.fns.update)

    us_sel = time_us(lambda: jax.block_until_ready(sel(pol.params, st, key)))
    us_upd = time_us(lambda: jax.block_until_ready(upd(pol.params, st, arm, obs)))
    print(f"single controller: select {us_sel:.1f} us, update {us_upd:.1f} us "
          f"(decision interval 10,000 us => overhead {(us_sel+us_upd)/100:.2f}%)")
    # us_per_call is NUMERIC (scripts/bench_check.py compares rows
    # across runs); human-readable context lives in "derived"
    rows.append({"name": "controller_select", "us_per_call": round(us_sel, 2),
                 "derived": "single"})
    rows.append({"name": "controller_update", "us_per_call": round(us_upd, 2),
                 "derived": "single"})

    n = 2048 if quick else (63_720 if not fast else 8192)
    # pin the vmap path so the vmap-vs-kernel rows stay distinct on TPU
    fleet = Fleet(pol, n, use_kernel=False)
    states = fleet.init(jax.random.key(2))
    us_fleet = time_us(
        lambda: jax.block_until_ready(fleet.select(states, jax.random.key(3))),
        n=20,
    )
    print(f"fleet of {n}: vmapped select {us_fleet:.1f} us "
          f"({us_fleet/n*1000:.1f} ns/controller)")
    rows.append({"name": f"fleet_select_vmap_n{n}",
                 "us_per_call": round(us_fleet, 2),
                 "derived": f"{us_fleet/n*1000:.2f} ns/controller"})

    # full fused interval step (update + select), vmapped fallback path
    arms = fleet.select(states, jax.random.key(3))
    fobs = Obs(
        energy_j=jnp.full((n,), 20.0), uc=jnp.full((n,), 0.9),
        uu=jnp.full((n,), 0.3), progress=jnp.full((n,), 1e-4),
        reward=jnp.full((n,), -1.0), switched=jnp.zeros((n,), bool),
        active=jnp.ones((n,), bool),
    )
    us_step = time_us(
        lambda: jax.block_until_ready(
            fleet.step(states, arms, fobs, jax.random.key(4))[1]
        ),
        n=20,
    )
    print(f"fleet of {n}: fused step (vmap path) {us_step:.1f} us "
          f"({us_step/n*1000:.1f} ns/controller)")
    rows.append({"name": f"fleet_step_vmap_n{n}",
                 "us_per_call": round(us_step, 2),
                 "derived": f"{us_step/n*1000:.2f} ns/controller"})

    # the fused Pallas kernel (interpret mode off-TPU, so time a small N)
    nk = n if ops.pallas_available() else (512 if quick else 2048)
    kf = Fleet(pol, nk, use_kernel=True, interpret=not ops.pallas_available())
    kstates = kf.init(jax.random.key(5))
    karms = kf.select(kstates, jax.random.key(6))
    kobs = jax.tree.map(lambda x: x[:nk], fobs)
    us_kernel = time_us(
        lambda: jax.block_until_ready(kf.step(kstates, karms, kobs)[1]),
        n=5,
    )
    rows.append({"name": f"fleet_step_kernel_n{nk}",
                 "us_per_call": round(us_kernel, 2),
                 "derived": "pallas" + ("" if ops.pallas_available()
                                        else " (interpret mode on CPU)")})
    print(f"fleet kernel step n={nk}: {us_kernel:.1f} us")

    # the same fused step over a factored 9x3 ladder (flat K = 27):
    # marginal-bonus reshapes plus 3x the per-arm state
    kff = Fleet(factored_energy_ucb(ActionSpace(9, 3)), nk,
                use_kernel=True, interpret=not ops.pallas_available())
    kfstates = kff.init(jax.random.key(7))
    kfarms = kff.select(kfstates, jax.random.key(8))
    us_fk = time_us(
        lambda: jax.block_until_ready(kff.step(kfstates, kfarms, kobs)[1]),
        n=5,
    )
    rows.append({"name": f"fleet_step_kernel_factored_n{nk}",
                 "us_per_call": round(us_fk, 2),
                 "derived": "pallas 9x3" + ("" if ops.pallas_available()
                                            else " (interpret mode on CPU)")})
    print(f"fleet kernel step (factored 9x3) n={nk}: {us_fk:.1f} us")

    # end-to-end per-interval latency through the streaming control
    # plane (EnergyController over SimBackend): telemetry advance +
    # counter read + Obs derivation + policy step per decision interval
    def ctrl_us(nn, use_kernel, label, reps, policy=pol, env=p):
        ctl = EnergyController(
            policy, SimBackend(env, n=nn), use_kernel=use_kernel,
            interpret=use_kernel and not ops.pallas_available(),
            record_history=nn == 1,  # fleet streams skip the host sync
        )
        ctl.step()  # warm up the traces
        us = time_us(
            lambda: (ctl.step(), jax.block_until_ready(ctl.states["mu"]))[0],
            n=reps,
        )
        rows.append({"name": f"controller_interval_{label}_n{nn}",
                     "us_per_call": round(us, 2),
                     "derived": f"{us/nn*1000:.1f} ns/controller streaming"
                     + ("" if not use_kernel or ops.pallas_available()
                        else " (interpret mode on CPU)")})
        print(f"EnergyController interval ({label}, n={nn}): {us:.1f} us "
              f"({us/nn*1000:.1f} ns/controller)")
        return us

    ctrl_us(1, False, "python", 20 if quick else 50)
    nf = 512 if quick else (2048 if fast else 8192)
    ctrl_us(nf, False, "vmap", 5 if quick else 10)
    kreps = 3 if not ops.pallas_available() else 10
    ctrl_us(nf, True, "fused", kreps)
    # the QoS feasible-set lane's latency cost on the same fused path
    ctrl_us(nf, True, "fused_qos", kreps, policy=energy_ucb(qos_delta=0.05))
    # the nonstationary lanes: sliding-window discount, and a fully
    # mixed fleet (per-node alpha + QoS + gamma + warm-up lanes in one
    # launch) — the whole EnergyUCB family is kernel-exact now
    ctrl_us(nf, True, "fused_sw", kreps,
            policy=energy_ucb(window_discount=0.95))
    base = energy_ucb()
    mixed = base.with_params(base.params._replace(
        alpha=jnp.linspace(0.05, 0.3, nf).astype(jnp.float32),
        qos_delta=jnp.where(jnp.arange(nf) % 3 == 0, 0.05, -1.0),
        gamma=jnp.where(jnp.arange(nf) % 2 == 0, 0.95, 1.0),
        optimistic=jnp.where(jnp.arange(nf) % 5 == 0, 0.0, 1.0),
    ))
    ctrl_us(nf, True, "fused_mixed", kreps, policy=mixed)
    # factored (core x uncore) lanes: the flat K = 9 * 3 = 27 product
    # ladder with per-dimension bonuses/penalties, same fused launch —
    # the VMEM story is linear in K, so this row tracks the 3x-K cost
    space = ActionSpace(9, 3)
    ctrl_us(nf, True, "fused_factored", kreps,
            policy=factored_energy_ucb(space, uncore_penalty=0.01),
            env=make_factored_env_params(get_app("tealeaf")))

    # megakernel episode scan (kernels/episode_scan) vs the per-interval
    # streaming loop on the same control plane: streaming pays T python
    # dispatches + T host syncs per episode, the scan pays ONE launch.
    # us_per_call is normalized to per-interval so the rows compare
    # directly; the headline acceptance is scan >= 5x under streaming
    # (the trace-fed row; the sim-fused row is bounded near ~3x on a
    # 1-core host because the env RNG + (N, K) arithmetic are shared
    # with streaming there — the scan removes only dispatch/sync).
    ne = 4096
    te = 128
    ereps = 3 if quick else 5

    ctl_s = EnergyController(pol, SimBackend(p, n=ne), use_kernel=False,
                             record_history=False)
    ctl_s.step()  # warm the streaming traces

    def stream_episode():
        for _ in range(te):
            ctl_s.step()
        jax.block_until_ready(ctl_s.states["mu"])

    us_stream = time_us(stream_episode, n=ereps, warmup=1) / te
    rows.append({"name": f"episode_stream_n{ne}",
                 "us_per_call": round(us_stream, 2),
                 "derived": f"streaming, per interval over T={te}"})
    print(f"episode streaming n={ne}: {us_stream:.1f} us/interval")

    ctl_e = EnergyController(pol, SimBackend(p, n=ne),
                             record_history=False)
    ctl_e.run_scanned(te)  # compile warm-up
    us_scan = time_us(lambda: ctl_e.run_scanned(te), n=ereps, warmup=1) / te
    rows.append({"name": f"episode_scan_sim_n{ne}",
                 "us_per_call": round(us_scan, 2),
                 "derived": f"one launch per T={te} episode"
                 + (", pallas" if ops.pallas_available() else ", xla scan")})
    print(f"episode scan (sim) n={ne}: {us_scan:.1f} us/interval "
          f"({us_stream/us_scan:.1f}x under streaming)")

    # trace-fed flavor: record a live episode, then time the scanned
    # replay of its (T, N) observation columns (cursor reset per rep)
    rec = EnergyController(pol, SimBackend(p, n=ne), use_kernel=False,
                           record_history=False)
    rec_arms = []
    for _ in range(te):
        rec.step()
        rec_arms.append(np.asarray(rec.last_arms))
    trace = record_trace(SimBackend(p, n=ne), np.stack(rec_arms))
    ctl_t = EnergyController(pol, trace, record_history=False)
    ctl_t.run_scanned(te)  # compile warm-up

    def replay_episode():
        trace._cursor = 0
        trace.requested_arms.clear()
        ctl_t.run_scanned(te)

    us_trace = time_us(replay_episode, n=ereps, warmup=1) / te
    rows.append({"name": f"episode_scan_trace_n{ne}",
                 "us_per_call": round(us_trace, 2),
                 "derived": f"trace-fed, one launch per T={te} episode"
                 + (", pallas" if ops.pallas_available() else ", xla scan")})
    print(f"episode scan (trace) n={ne}: {us_trace:.1f} us/interval "
          f"({us_stream/us_trace:.1f}x under streaming)")

    # distributed control plane: rendezvous, the strict aggregate round,
    # and stripe checkpoint save/restore. Per-interval stepping never
    # touches the network, so these four rows ARE the whole off-hot-path
    # overhead of the fault-tolerant multi-process fleet.
    import shutil
    import socket
    import tempfile
    import threading
    import time

    from repro.parallel.distributed import (ClientComm, CoordinatorComm,
                                            DistributedFleetController)
    from repro.train import checkpoint as dckpt

    hh = 4

    def free_port():
        with socket.socket() as s:
            s.bind(("127.0.0.1", 0))
            return s.getsockname()[1]

    def rendezvous_once():
        port = free_port()

        def dial(h):
            # fixed settle delay so the listener is up before the first
            # dial: the row stays a constant-bias rendezvous latency
            # instead of sometimes swallowing a connect-backoff sleep
            time.sleep(0.02)
            ClientComm(("127.0.0.1", port), hh, h).close()

        ts = [threading.Thread(target=dial, args=(h,))
              for h in range(1, hh)]
        for t in ts:
            t.start()
        CoordinatorComm(("127.0.0.1", port), hh).close()
        for t in ts:
            t.join()

    us_rdv = time_us(rendezvous_once, n=3, warmup=1)
    rows.append({"name": f"distributed_rendezvous_h{hh}",
                 "us_per_call": round(us_rdv, 2),
                 "derived": f"H={hh} loopback check-in, 20 ms settle bias"})
    print(f"distributed rendezvous H={hh}: {us_rdv:.1f} us")

    ticks, twarm = (20, 3) if quick else (50, 5)
    port = free_port()

    def client_rounds(h):
        c = ClientComm(("127.0.0.1", port), hh, h)
        for i in range(ticks + twarm):
            c.allgather(h, f"tick-{i}")
        c.close()

    ts = [threading.Thread(target=client_rounds, args=(h,))
          for h in range(1, hh)]
    for t in ts:
        t.start()
    coord = CoordinatorComm(("127.0.0.1", port), hh)
    cnt = {"i": 0}

    def tick_round():
        coord.allgather(0, f"tick-{cnt['i']}")
        cnt["i"] += 1

    us_tick = time_us(tick_round, n=ticks, warmup=twarm)
    for t in ts:
        t.join()
    coord.close()
    rows.append({"name": f"distributed_aggregate_tick_h{hh}",
                 "us_per_call": round(us_tick, 2),
                 "derived": f"strict H={hh} gather round on loopback"})
    print(f"distributed aggregate tick H={hh}: {us_tick:.1f} us")

    nd = 1024 if quick else 4096
    dctl = DistributedFleetController(
        pol, SimBackend(p, n=nd), seed=0, use_kernel=False)
    dctl.step()
    sd = dctl.state_dict()
    root = tempfile.mkdtemp(prefix="bench_ckpt_")
    spath = dckpt.stripe_dir(root, 0, nd)
    try:
        us_save = time_us(
            lambda: dckpt.save(spath, dctl.interval, sd, keep_last=1),
            n=3, warmup=1)
        rows.append({"name": f"distributed_checkpoint_save_n{nd}",
                     "us_per_call": round(us_save, 2),
                     "derived": "blocking stripe save, atomic rename"})
        print(f"distributed checkpoint save n={nd}: {us_save:.1f} us")
        us_rest = time_us(
            lambda: dckpt.restore_stripe(root, 0, nd, like=sd),
            n=3, warmup=1)
        rows.append({"name": f"distributed_checkpoint_restore_n{nd}",
                     "us_per_call": round(us_rest, 2),
                     "derived": "stripe restore incl. cover walk"})
        print(f"distributed checkpoint restore n={nd}: {us_rest:.1f} us")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    if out_json is not None:
        payload = {
            "benchmark": "controller_overhead",
            "mode": "quick" if quick else ("fast" if fast else "full"),
            "backend": jax.default_backend(),
            "pallas": ops.pallas_available(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "rows": rows,
        }
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {len(rows)} rows -> {out_json}")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="Aurora-scale fleet (63,720 controllers)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (minutes on a cold CPU runner)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + env metadata as JSON")
    args = ap.parse_args(argv)
    run(fast=not args.full, out_json=args.json, quick=args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
