"""Controller-plane overhead: us per decision for a single jitted
controller (select+update) and for the full Aurora-scale fleet (63,720
controllers) through the fused fleet kernel. The paper's feasibility
argument ('lightweight') quantified."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.core import energy_ucb, get_app, make_env_params
from repro.core.fleet import Fleet
from repro.core.simulator import Obs, env_init, env_step
from repro.kernels import ops


def run(fast: bool = True, out_json=None):
    rows = []
    pol = energy_ucb()
    p = make_env_params(get_app("tealeaf"))
    st = pol.init(jax.random.key(0))
    es = env_init(p)
    key = jax.random.key(1)

    sel = jax.jit(pol.select)
    arm = sel(st, key)
    _, obs = env_step(p, es, arm, key)
    upd = jax.jit(pol.update)

    us_sel = time_us(lambda: jax.block_until_ready(sel(st, key)))
    us_upd = time_us(lambda: jax.block_until_ready(upd(st, arm, obs)))
    print(f"single controller: select {us_sel:.1f} us, update {us_upd:.1f} us "
          f"(decision interval 10,000 us => overhead {(us_sel+us_upd)/100:.2f}%)")
    rows.append({"name": "controller_select", "us_per_call": f"{us_sel:.1f}",
                 "derived": "single"})
    rows.append({"name": "controller_update", "us_per_call": f"{us_upd:.1f}",
                 "derived": "single"})

    n = 63_720 if not fast else 8192
    fleet = Fleet(pol, n)
    states = fleet.init(jax.random.key(2))
    us_fleet = time_us(
        lambda: jax.block_until_ready(fleet.select(states, jax.random.key(3))),
        n=20,
    )
    print(f"fleet of {n}: vmapped select {us_fleet:.1f} us "
          f"({us_fleet/n*1000:.1f} ns/controller)")
    rows.append({"name": f"fleet_select_vmap_n{n}", "us_per_call": f"{us_fleet:.1f}",
                 "derived": f"{us_fleet/n*1000:.2f} ns/controller"})

    mu, cnt = states["mu"], states["n"]
    prev, t = states["prev"], jnp.maximum(states["t"], 2.0)
    us_kernel = time_us(
        lambda: jax.block_until_ready(
            ops.fleet_select(mu, cnt, prev, t, interpret=not ops.pallas_available())
        ),
        n=5,
    )
    rows.append({"name": f"fleet_select_kernel_n{n}", "us_per_call": f"{us_kernel:.1f}",
                 "derived": "pallas (interpret mode on CPU)"})
    print(f"fleet kernel (interpret on CPU): {us_kernel:.1f} us")
    return rows


if __name__ == "__main__":
    run()
