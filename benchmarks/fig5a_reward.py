"""Paper Fig. 5(a): reward-formulation comparison — E*R vs E^2*R vs
E*R^2 (squared terms amplify counter noise and slow convergence)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import REWARD_VARIANTS, energy_ucb, get_app, make_env_params, make_reward_fn, run_repeats

APPS = ("miniswp", "clvleaf")


def run(fast: bool = True, out_json: str = None):
    reps = 3 if fast else 10
    rows = []
    print(f"{'app':10s}" + "".join(f"{v:>12s}" for v in REWARD_VARIANTS))
    for app in APPS:
        p = make_env_params(get_app(app))
        es = {}
        for vname, (a, b) in REWARD_VARIANTS.items():
            rf = make_reward_fn(p, a, b)
            out = run_repeats(energy_ucb(), p, jax.random.key(0), reps, reward_fn=rf)
            es[vname] = out["energy_kj"].mean()
        print(f"{app:10s}" + "".join(f"{es[v]:12.2f}" for v in REWARD_VARIANTS))
        rows.append({
            "name": f"fig5a_reward_{app}",
            "us_per_call": "",
            "derived": ";".join(f"{v}={es[v]:.2f}" for v in REWARD_VARIANTS),
        })
    return rows


if __name__ == "__main__":
    run()
