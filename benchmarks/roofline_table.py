"""Roofline table (EXPERIMENTS.md §Roofline): per (arch x shape), merge
the dry-run artifact (per-device memory, HLO collectives with trip-count
attribution) with the analytic compute/memory terms, identify the
bottleneck, and report MODEL_FLOPS / exec ratio + roofline fraction."""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs import SHAPES, get_arch, list_archs
from repro.roofline.analysis import HW, roofline_terms


def load_cell(dryrun_dir: str, arch: str, shape: str, mesh: str = "pod"):
    path = os.path.join(dryrun_dir, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def cell_row(dryrun_dir: str, arch: str, shape: str):
    rec = load_cell(dryrun_dir, arch, shape)
    if rec is None or rec.get("status") != "ok":
        return None
    cfg = get_arch(arch)
    coll = rec["collectives"]["total_bytes_per_device"]
    t = roofline_terms(cfg, shape, collective_bytes_per_dev=coll)
    mem = rec["memory_per_device"]
    return {
        "arch": arch,
        "shape": shape,
        "t_compute_s": t["t_compute_s"],
        "t_memory_s": t["t_memory_s"],
        "t_collective_s": t["t_collective_s"],
        "bottleneck": t["bottleneck"],
        "roofline_fraction": t["roofline_fraction"],
        "mfu_bound": t["mfu_bound"],
        "model_flops": t["model_flops"],
        "exec_flops": t["exec_flops"],
        "useful_ratio": t["model_flops"] / t["exec_flops"],
        "peak_gb_per_dev": mem["peak_est_bytes"] / 2**30,
        "coll_gb_per_dev": coll / 2**30,
        "hlo_flops_raw": rec["hlo_cost"]["flops_raw"],
        "compile_s": rec.get("compile_s"),
    }


def run(fast: bool = True, dryrun_dir: str = "results/dryrun", out_json=None):
    rows = []
    for arch in list_archs():
        for shape in get_arch(arch).supported_shapes():
            r = cell_row(dryrun_dir, arch, shape)
            if r:
                rows.append(r)
    rows.sort(key=lambda r: r["roofline_fraction"])
    hdr = (f"{'arch':26s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} "
           f"{'bound':>7s} {'roofl%':>7s} {'MFU%':>6s} {'useful':>7s} {'mem_GB':>7s}")
    print(hdr)
    for r in rows:
        print(
            f"{r['arch']:26s} {r['shape']:12s} {r['t_compute_s']:9.4f} "
            f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
            f"{r['bottleneck']:>7s} {100*r['roofline_fraction']:7.1f} "
            f"{100*r['mfu_bound']:6.1f} {r['useful_ratio']:7.2f} "
            f"{r['peak_gb_per_dev']:7.2f}"
        )
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    worst = rows[0] if rows else {}
    return [{
        "name": "roofline_table",
        "us_per_call": "",
        "derived": f"cells={len(rows)};worst={worst.get('arch','')}/{worst.get('shape','')}@{100*worst.get('roofline_fraction',0):.0f}%",
    }]


if __name__ == "__main__":
    run(out_json="results/roofline_table.json")
