"""Simulator faithfulness: Table-1 static energies reproduce exactly;
the reward landscape aligns with total energy; episodes complete."""
import jax
import numpy as np
import pytest

from repro.core import (
    TABLE1_KJ,
    app_names,
    expected_rewards,
    get_app,
    make_env_params,
    run_repeats,
    static_energy_kj,
    static_policy,
)


@pytest.mark.parametrize("name", app_names())
def test_static_energy_matches_table1(name):
    p = make_env_params(get_app(name))
    got = np.array([static_energy_kj(p, i) for i in range(9)])
    np.testing.assert_allclose(got, TABLE1_KJ[name], rtol=2e-2)


@pytest.mark.parametrize("name", app_names())
def test_reward_argmax_is_energy_argmin(name):
    p = make_env_params(get_app(name))
    arm_r = int(np.argmax(np.asarray(expected_rewards(p))))
    arm_e = int(np.argmin(TABLE1_KJ[name]))
    assert abs(arm_r - arm_e) <= 1, f"{name}: reward arm {arm_r} vs energy arm {arm_e}"


def test_static_rollout_reproduces_table1_with_noise():
    name = "tealeaf"
    p = make_env_params(get_app(name))
    for arm in (0, 4, 8):
        out = run_repeats(static_policy(arm), p, jax.random.key(0), n_repeats=3)
        assert out["completed"].all()
        np.testing.assert_allclose(
            out["energy_kj"].mean(), TABLE1_KJ[name][arm], rtol=3e-2
        )


def test_switching_costs_accrue():
    from repro.core import rr_freq

    p = make_env_params(get_app("clvleaf"))
    out = run_repeats(rr_freq(), p, jax.random.key(0), n_repeats=2)
    # RRFreq switches every step
    assert (out["switches"] >= out["steps"] - 1).all()


def test_time_monotone_in_frequency():
    app = get_app("pot3d")
    ts = app.time_s(np.round(np.arange(0.8, 1.61, 0.1), 1))
    assert np.all(np.diff(ts) < 0)  # higher f => faster


def test_fit_quality():
    """The fitted analytic E(f) curve tracks the table (fit used for
    time/utilization; energies are pinned exactly)."""
    for name in app_names():
        a = get_app(name)
        f = np.round(np.arange(0.8, 1.61, 0.1), 1)
        e_fit = (a.p_static_kw + a.p_dyn_kw * (f / 1.6) ** a.gamma) * a.time_s(f)
        err = np.abs(e_fit - TABLE1_KJ[name]) / TABLE1_KJ[name]
        assert np.median(err) < 0.08, f"{name} fit err {np.median(err):.3f}"
