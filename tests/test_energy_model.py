"""StepEnergyModel: the roofline->bandit bridge behaves physically."""
import jax
import numpy as np
import pytest

from repro.core import energy_ucb, run_repeats, static_energy_kj
from repro.energy.model import StepEnergyModel, env_params_from_roofline


def test_compute_bound_prefers_high_freq():
    m = StepEnergyModel(t_compute_s=1.0, t_memory_s=0.1, t_collective_s=0.05)
    assert m.optimal_arm() >= 6  # near f_max


def test_memory_bound_prefers_low_freq():
    m = StepEnergyModel(t_compute_s=0.05, t_memory_s=1.0, t_collective_s=0.2)
    assert m.optimal_arm() <= 2


def test_step_time_max_overlap():
    m = StepEnergyModel(t_compute_s=0.5, t_memory_s=0.2, t_collective_s=0.1)
    assert m.step(8)["step_time_s"] == pytest.approx(0.5)
    # at 0.8 GHz compute takes 2x
    assert m.step(0)["step_time_s"] == pytest.approx(1.0)


def test_env_params_consistent_with_model():
    m = StepEnergyModel(t_compute_s=0.2, t_memory_s=0.4, t_collective_s=0.1,
                        steps_total=200)
    p = env_params_from_roofline(m)
    for arm in (0, 4, 8):
        np.testing.assert_allclose(
            static_energy_kj(p, arm), m.static_energy_j(arm) / 1e3, rtol=1e-4
        )


def test_bandit_saves_energy_on_memory_bound_cell():
    m = StepEnergyModel(t_compute_s=0.1, t_memory_s=0.5, t_collective_s=0.2,
                        steps_total=400)
    p = env_params_from_roofline(m)
    out = run_repeats(energy_ucb(), p, jax.random.key(0), 3)
    e_default = m.static_energy_j(8) / 1e3
    e_opt = m.static_energy_j(m.optimal_arm()) / 1e3
    e_ucb = out["energy_kj"].mean()
    assert e_ucb < e_default * 0.97  # saves >3% vs f_max default
    assert e_ucb < e_default and e_ucb > e_opt * 0.98


def test_runtime_summary_fields():
    from repro.core.policies import energy_ucb as mk
    from repro.energy import EnergyController, SimulatedGEOPM

    m = StepEnergyModel(t_compute_s=0.1, t_memory_s=0.3, t_collective_s=0.1,
                        n_chips=2, steps_total=50)
    rt = EnergyController(mk(), SimulatedGEOPM(model=m))
    for _ in range(50):
        rt.step()
    s = rt.summary()
    assert s["steps"] == 50
    assert s["saved_energy_pct"] > 0  # memory-bound: should save
    assert s["switches"] < 40
