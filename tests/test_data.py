import numpy as np
import pytest

from repro.train.data import DataConfig, SyntheticTokens


def test_deterministic_batches():
    a = SyntheticTokens(DataConfig(1000, 32, 8, seed=5))
    b = SyntheticTokens(DataConfig(1000, 32, 8, seed=5))
    for step in (0, 3, 17):
        np.testing.assert_array_equal(a.batch_at(step)["tokens"], b.batch_at(step)["tokens"])


def test_seed_changes_data():
    a = SyntheticTokens(DataConfig(1000, 32, 8, seed=5))
    b = SyntheticTokens(DataConfig(1000, 32, 8, seed=6))
    assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])


def test_host_shards_disjoint():
    h0 = SyntheticTokens(DataConfig(1000, 32, 8, seed=1, host_index=0, host_count=2))
    h1 = SyntheticTokens(DataConfig(1000, 32, 8, seed=1, host_index=1, host_count=2))
    b0, b1 = h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"]
    assert b0.shape == (4, 32)
    assert not np.array_equal(b0, b1)


def test_restart_state_roundtrip():
    a = SyntheticTokens(DataConfig(1000, 16, 4, seed=2))
    next(a); next(a); next(a)
    st = a.state()
    b = SyntheticTokens(DataConfig(1000, 16, 4, seed=2))
    b.restore(st)
    np.testing.assert_array_equal(next(a)["tokens"], next(b)["tokens"])


def test_labels_are_next_tokens():
    a = SyntheticTokens(DataConfig(1000, 16, 4, seed=3))
    batch = a.batch_at(0)
    np.testing.assert_array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])


def test_learnable_structure():
    """Every other position is a deterministic successor — a model can
    beat the unigram entropy."""
    a = SyntheticTokens(DataConfig(500, 64, 16, seed=4))
    b = a.batch_at(0)
    toks, labs = b["tokens"], b["labels"]
    # positions 0,2,4... have deterministic next-token
    pred = a._succ[toks[:, 0::2]]
    agree = (pred[:, : labs[:, 0::2].shape[1]] == labs[:, 0::2]).mean()
    assert agree > 0.95
