"""Roofline machinery: HLO collective parser on a real lowered module +
analytic term sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch
from repro.roofline.analysis import exec_flops, hbm_bytes, model_flops, roofline_terms
from repro.roofline.hlo_parse import collective_bytes_from_hlo, split_computations


def test_parser_on_synthetic_hlo():
    hlo = """
HloModule test

%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(%x), replica_groups={}
  ROOT %t = tuple(...)
}

%cond.1 (p: (s32[], f32[64])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %ag = f32[128]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[64]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[128]{0} copy(%ag)
}
"""
    out = collective_bytes_from_hlo(hlo)
    # all-gather: 128*4 = 512 bytes x1; all-reduce inside while: 64*4 x10
    assert out["per_kind"]["all-gather"] == 512
    assert out["per_kind"]["all-reduce"] == 2560
    assert out["count"] == 2


def test_parser_on_lowered_module():
    """End-to-end: lower a psum on a fake 2-device mesh? single device:
    ensure parser returns zero collectives for a collective-free fn."""
    hlo = jax.jit(lambda x: x * 2 + 1).lower(jnp.zeros((8, 8))).compile().as_text()
    out = collective_bytes_from_hlo(hlo)
    assert out["total"] == 0


@pytest.mark.parametrize("arch,shape", [
    ("llama3-405b", "train_4k"),
    ("granite-moe-1b-a400m", "train_4k"),
    ("mamba2-2.7b", "long_500k"),
    ("starcoder2-15b", "decode_32k"),
])
def test_analytic_terms_positive(arch, shape):
    cfg = get_arch(arch)
    t = roofline_terms(cfg, shape, collective_bytes_per_dev=1e9)
    assert t["t_compute_s"] > 0 and t["t_memory_s"] > 0
    assert t["model_flops"] <= t["exec_flops"] * 1.001
    assert t["bottleneck"] in ("compute", "memory", "collective")


def test_train_flops_scale():
    """llama3 train: 6ND ~ 6 * 405e9 * 1M tokens within 2x (attn extra)."""
    cfg = get_arch("llama3-405b")
    mf = model_flops(cfg, SHAPES["train_4k"])
    ndd = 6 * cfg.param_count() * 256 * 4096
    assert 0.8 * ndd < mf < 2.0 * ndd


def test_decode_is_memory_bound():
    cfg = get_arch("starcoder2-15b")
    t = roofline_terms(cfg, "decode_32k", collective_bytes_per_dev=0.0)
    assert t["t_memory_s"] > t["t_compute_s"]


def test_train_dense_is_compute_bound_analytically():
    cfg = get_arch("llama3-405b")
    t = roofline_terms(cfg, "train_4k")
    assert t["t_compute_s"] > t["t_memory_s"]
