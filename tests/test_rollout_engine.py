"""The unified rollout engine vs. the seed implementation, and the
single-trace guarantee of hyperparams-as-data.

The seed's closure-based policy + scan loop are inlined here verbatim
as the frozen reference: the engine must reproduce them bit-for-bit for
a fixed key across every EnergyUCB variant."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    energy_ucb,
    engine_trace_count,
    get_app,
    make_env_params,
    make_policy_params,
    reset_engine_trace_count,
    run_episode,
    run_sweep,
    stack_policy_params,
    sweep_policy_params,
)
from repro.core.simulator import env_init, env_step, expected_rewards

K = 9


# --- frozen seed reference (closure-based policy, seed scan loop) ----------


def _seed_policy(alpha=0.1, switching_penalty=0.02, mu_init=0.0,
                 optimistic_init=True, qos_delta=None, default_arm=K - 1,
                 window_discount=None, prior_mu=None, prior_n=0.0):
    lam = switching_penalty

    def init(key):
        del key
        mu0 = jnp.full((K,), mu_init, jnp.float32)
        n0 = jnp.zeros((K,), jnp.float32)
        if prior_mu is not None:
            mu0 = jnp.asarray(prior_mu, jnp.float32)
            n0 = jnp.full((K,), float(prior_n), jnp.float32)
        return {"mu": mu0, "n": n0, "prev": jnp.int32(default_arm),
                "t": jnp.float32(0.0), "phat": jnp.zeros((K,), jnp.float32),
                "pn": jnp.zeros((K,), jnp.float32)}

    def select(state, key):
        del key
        t = jnp.maximum(state["t"] + 1.0, 2.0)
        bonus = alpha * jnp.sqrt(jnp.log(t) / jnp.maximum(state["n"], 1.0))
        mu = state["mu"]
        if window_discount is not None:
            # mirrors the policy core's sliding-window optimism (stale
            # estimates shrink back to the prior); stationary variants
            # stay the literal seed formula
            prior = (jnp.full((K,), mu_init, jnp.float32) if prior_mu is None
                     else jnp.asarray(prior_mu, jnp.float32))
            mu = (state["n"] * mu + 0.25 * prior) / (state["n"] + 0.25)
        sa = mu + bonus - lam * (jnp.arange(K) != state["prev"])
        if not optimistic_init:
            untried = state["n"] < 1.0
            sa = jnp.where(jnp.any(untried),
                           jnp.where(untried, 1e9 - jnp.arange(K) * 1.0, -1e9), sa)
        feasible = jnp.ones((K,), bool)
        if qos_delta is not None:
            p_ref = jnp.where(state["pn"][default_arm] > 0,
                              state["phat"][default_arm], jnp.inf)
            slowdown = 1.0 - state["phat"] / p_ref
            feasible = (state["pn"] < 1.0) | (slowdown <= qos_delta)
        neg = jnp.finfo(sa.dtype).min
        masked = jnp.where(feasible, sa, neg)
        return jnp.where(jnp.any(feasible), jnp.argmax(masked),
                         jnp.argmax(sa)).astype(jnp.int32)

    def update(state, arm, obs):
        # mirrors the policy core's decay-then-increment sliding window:
        # discounting the effective counts (reward AND progress — the
        # QoS feasible set must re-learn slowdowns after a phase change)
        # and then applying the seed's incremental mean IS the
        # discounted mean; stationary variants keep the literal seed
        # formula (an undecayed count)
        n0, pn0 = state["n"], state["pn"]
        if window_discount is not None:
            n0, pn0 = n0 * window_discount, pn0 * window_discount
        n = n0.at[arm].add(1.0)
        mu = state["mu"].at[arm].set(
            state["mu"][arm] + (obs.reward - state["mu"][arm]) / n[arm]
        )
        pn = pn0.at[arm].add(1.0)
        phat = state["phat"].at[arm].set(
            state["phat"][arm] + (obs.progress - state["phat"][arm]) / pn[arm]
        )
        return {"mu": mu, "n": n, "prev": jnp.asarray(arm, jnp.int32),
                "t": state["t"] + 1.0, "phat": phat, "pn": pn}

    return init, select, update


@functools.partial(jax.jit, static_argnames=("init", "select", "update",
                                             "max_steps"))
def _seed_episode(init, select, update, params, key, max_steps):
    k_init, k_run = jax.random.split(key)
    pstate0, estate0 = init(k_init), env_init(params)
    mu = expected_rewards(params)
    mu_star = jnp.max(mu)

    def step(carry, k):
        pstate, estate = carry
        k1, k2 = jax.random.split(k)
        arm = select(pstate, k1)
        new_estate, obs = env_step(params, estate, arm, k2)
        new_pstate = update(pstate, arm, obs)
        where = lambda a, b: jax.tree.map(
            lambda x, y: jnp.where(obs.active, x, y), a, b)
        pstate, estate = where(new_pstate, pstate), where(new_estate, estate)
        return (pstate, estate), (arm, (mu_star - mu[arm]) * obs.active)

    (pstate, estate), (arms, regret_inc) = jax.lax.scan(
        step, (pstate0, estate0), jax.random.split(k_run, max_steps))
    return {"energy_kj": estate.energy_kj, "time_s": estate.time_s,
            "switches": estate.switches, "steps": estate.t, "arms": arms,
            "cum_regret": jnp.cumsum(regret_inc), "pstate": pstate}


VARIANTS = {
    "default": {},
    "no_optinit": dict(optimistic_init=False),
    "no_penalty": dict(switching_penalty=0.0),
    "qos": dict(qos_delta=0.05),
    "window": dict(window_discount=0.995),
    "warm_start": dict(prior_mu=np.linspace(-1.0, -0.5, K), prior_n=1.0),
}


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_engine_matches_seed_episode_bit_for_bit(variant):
    kw = VARIANTS[variant]
    p = make_env_params(get_app("tealeaf"))
    key = jax.random.key(42)
    ms = 400
    init, select, update = _seed_policy(**kw)
    want = _seed_episode(init, select, update, p, key, ms)
    got = run_episode(energy_ucb(**kw), p, key, max_steps=ms)
    for field in ("energy_kj", "time_s", "switches", "steps", "arms",
                  "cum_regret"):
        np.testing.assert_array_equal(
            np.asarray(got[field]), np.asarray(want[field]),
            err_msg=f"{variant}: {field} diverged from the seed loop")
    for leaf in ("mu", "n", "prev", "t", "phat", "pn"):
        g = np.asarray(got["pstate"][leaf])
        w = np.asarray(want["pstate"][leaf])
        if variant == "window" and leaf in ("mu", "n", "phat", "pn"):
            # the engine's discounted statistics flow through a
            # traced-gamma graph (hyperparams are data) while this
            # frozen reference folds gamma at trace time, so XLA makes
            # different mul-add contraction choices and the float
            # accumulators drift at ulp scale — while every arm, count
            # integer and trajectory field above stays bit-exact (and
            # the fused kernel matches the vmapped path bit-for-bit;
            # see test_fleet's mixed-lane parity)
            np.testing.assert_allclose(
                g, w, rtol=3e-7, atol=1e-12,
                err_msg=f"window: pstate[{leaf}] diverged beyond ulp noise")
            continue
        np.testing.assert_array_equal(
            g, w, err_msg=f"{variant}: pstate[{leaf}] diverged from the seed loop")


# --- single-trace sweeps ---------------------------------------------------


def test_alpha_lambda_sweep_is_single_trace():
    p = make_env_params(get_app("tealeaf"))
    grid = sweep_policy_params((0.05, 0.1, 0.15, 0.2), (0.0, 0.02))  # 8 cfgs
    reset_engine_trace_count()
    out = run_sweep(energy_ucb(), grid, p, jax.random.key(0), n_repeats=2,
                    max_steps=301)
    assert engine_trace_count() == 1, "8-config sweep must trace exactly once"
    assert out["energy_kj"].shape == (8, 2)
    assert np.isfinite(out["energy_kj"]).all()
    # new values, same shapes: cache hit, still one trace total
    grid2 = sweep_policy_params((0.06, 0.11, 0.16, 0.21), (0.01, 0.03))
    run_sweep(energy_ucb(), grid2, p, jax.random.key(1), n_repeats=2,
              max_steps=301)
    assert engine_trace_count() == 1


def test_sweep_mixes_flag_variants_in_one_trace():
    """QoS / warm-up / sliding-window flags are data, so one vmapped call
    covers heterogeneous variants."""
    p = make_env_params(get_app("tealeaf"))
    stacked = stack_policy_params([
        make_policy_params(),
        make_policy_params(optimistic_init=False),
        make_policy_params(qos_delta=0.05),
        make_policy_params(window_discount=0.99),
    ])
    reset_engine_trace_count()
    out = run_sweep(energy_ucb(), stacked, p, jax.random.key(0), n_repeats=2,
                    max_steps=302)
    assert engine_trace_count() == 1
    assert out["energy_kj"].shape == (4, 2)
    assert np.isfinite(out["energy_kj"]).all()


def test_episode_variants_share_one_trace():
    p = make_env_params(get_app("tealeaf"))
    reset_engine_trace_count()
    run_episode(energy_ucb(alpha=0.07), p, jax.random.key(0), max_steps=217)
    first = engine_trace_count()
    assert first == 1
    for alpha, lam in ((0.1, 0.0), (0.2, 0.05), (0.33, 0.01)):
        run_episode(energy_ucb(alpha=alpha, switching_penalty=lam), p,
                    jax.random.key(1), max_steps=217)
    assert engine_trace_count() == first, "param changes must not retrace"
