"""Tests for repro-lint (src/repro/analysis + scripts/repro_lint.py).

Each rule gets fixture snippets that MUST trigger and MUST NOT trigger,
plus suppression handling, the RPL003 synthetic-lane cross-check, and a
self-check that the real tree lints clean. Pure-stdlib under test — no
jax needed by the analyzer itself.
"""
import json
import subprocess
import sys
from pathlib import Path

from repro.analysis import run_lint

REPO_ROOT = Path(__file__).resolve().parent.parent


def lint_tree(tmp_path, files):
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return run_lint(tmp_path, [tmp_path])


def active(findings, rule=None):
    return [
        f for f in findings
        if not f.suppressed and (rule is None or f.rule == rule)
    ]


# ------------------------------------------------------------- RPL001

SCATTER = "def f(state, arm):\n    return state.at[arm].add(1.0)\n"
ONEHOT = (
    "def f(state, arm, k, r):\n"
    "    hot = (jnp.arange(k) == arm).astype(state.dtype)\n"
    "    return state + hot * r\n"
)


def test_rpl001_triggers_in_kernels(tmp_path):
    found = lint_tree(tmp_path, {"kernels/k.py": SCATTER})
    assert len(active(found, "RPL001")) == 1


def test_rpl001_triggers_in_core_policies(tmp_path):
    found = lint_tree(tmp_path, {"core/policies.py": SCATTER})
    assert len(active(found, "RPL001")) == 1


def test_rpl001_onehot_form_clean(tmp_path):
    found = lint_tree(tmp_path, {"kernels/k.py": ONEHOT})
    assert not active(found, "RPL001")


def test_rpl001_out_of_scope_module_exempt(tmp_path):
    # scatters are fine outside the parity-critical modules
    found = lint_tree(tmp_path, {"workload/traffic.py": SCATTER})
    assert not active(found, "RPL001")


# -------------------------------------------------------- suppressions


def test_suppression_same_line(tmp_path):
    src = (
        "def f(state, arm):\n"
        "    return state.at[arm].add(1.0)"
        "  # repro-lint: disable=RPL001 baseline helper, no fused twin\n"
    )
    found = lint_tree(tmp_path, {"kernels/k.py": src})
    assert not active(found)
    sup = [f for f in found if f.suppressed]
    assert len(sup) == 1 and sup[0].reason == "baseline helper, no fused twin"


def test_suppression_previous_comment_line(tmp_path):
    src = (
        "def f(state, arm):\n"
        "    # repro-lint: disable=RPL001 baseline helper\n"
        "    return state.at[arm].add(1.0)\n"
    )
    found = lint_tree(tmp_path, {"kernels/k.py": src})
    assert not active(found)


def test_suppression_without_reason_escalates(tmp_path):
    src = (
        "def f(state, arm):\n"
        "    return state.at[arm].add(1.0)  # repro-lint: disable=RPL001\n"
    )
    found = lint_tree(tmp_path, {"kernels/k.py": src})
    # the reasonless directive does NOT suppress, and adds RPL000
    assert len(active(found, "RPL001")) == 1
    assert len(active(found, "RPL000")) == 1


def test_suppression_on_code_line_above_does_not_leak(tmp_path):
    src = (
        "def f(state, other, arm):\n"
        "    x = other.at[arm].add(1.0)  # repro-lint: disable=RPL001 this line only\n"
        "    return state.at[arm].add(1.0)\n"
    )
    found = lint_tree(tmp_path, {"kernels/k.py": src})
    # only the annotated line is suppressed; a directive attached to
    # code does not cover the next line
    assert len(active(found, "RPL001")) == 1


# ------------------------------------------------------------- RPL002


def test_rpl002_scan_unroll_triggers(tmp_path):
    src = (
        "import jax\n"
        "def ep(f, c, xs):\n"
        "    return jax.lax.scan(f, c, xs, unroll=2)\n"
    )
    found = lint_tree(tmp_path, {"kernels/episode.py": src})
    assert len(active(found, "RPL002")) == 1


def test_rpl002_scan_without_unroll_clean(tmp_path):
    src = (
        "import jax\n"
        "def ep(f, c, xs):\n"
        "    return jax.lax.scan(f, c, xs)\n"
    )
    found = lint_tree(tmp_path, {"kernels/episode.py": src})
    assert not active(found, "RPL002")


DONATE_ENV_ROWS = (
    "import functools\n"
    "import jax\n"
    "@functools.partial(jax.jit, donate_argnums=tuple(range(8)))\n"
    "def xla_episode_sim(a, b, c, d, e, f, g, env_rows):\n"
    "    return env_rows\n"
)


def test_rpl002_env_rows_donation_const_eval(tmp_path):
    # tuple(range(8)) covers index 7 == env_rows
    found = lint_tree(tmp_path, {"kernels/episode.py": DONATE_ENV_ROWS})
    hits = active(found, "RPL002")
    assert len(hits) == 1 and "env_rows" in hits[0].message


def test_rpl002_state_only_donation_clean(tmp_path):
    src = DONATE_ENV_ROWS.replace("tuple(range(8))", "tuple(range(7))")
    found = lint_tree(tmp_path, {"kernels/episode.py": src})
    assert not active(found, "RPL002")


def test_rpl002_call_form_jit_donation(tmp_path):
    src = (
        "import jax\n"
        "def xla_episode_sim(a, b, env_rows):\n"
        "    return env_rows\n"
        "sim = jax.jit(xla_episode_sim, donate_argnums=(2,))\n"
    )
    found = lint_tree(tmp_path, {"kernels/episode.py": src})
    assert len(active(found, "RPL002")) == 1


def test_rpl002_donate_argnames(tmp_path):
    src = (
        "import functools\n"
        "import jax\n"
        "@functools.partial(jax.jit, donate_argnames=('env_rows',))\n"
        "def xla_episode_sim(a, env_rows):\n"
        "    return env_rows\n"
    )
    found = lint_tree(tmp_path, {"kernels/episode.py": src})
    assert len(active(found, "RPL002")) == 1


# ------------------------------------------------------------- RPL003

LANES_OK = """
from typing import NamedTuple

class PolicyParams(NamedTuple):
    alpha: float
    lam: float
    qos_delta: float
    gamma: float
    optimistic: float
    prior_mu: float
    prior_n: float
    default_arm: int
    lam_unc: float

def _params_axes(p):
    return PolicyParams(alpha=0, lam=0, qos_delta=0, gamma=0,
                        optimistic=0, prior_mu=0, prior_n=0,
                        default_arm=0, lam_unc=0)

def slice_policy_lanes(p, sl):
    axes = _params_axes(p)
    return axes
"""


def test_rpl003_faithful_copy_clean(tmp_path):
    found = lint_tree(tmp_path, {"core/fleet.py": LANES_OK})
    assert not active(found, "RPL003")


def test_rpl003_unregistered_synthetic_lane(tmp_path):
    # a new lane added to PolicyParams but absent from the registry
    # (and from _params_axes) must fire
    src = LANES_OK.replace(
        "    lam_unc: float\n",
        "    lam_unc: float\n    context_w: float\n",
    )
    found = lint_tree(tmp_path, {"core/fleet.py": src})
    msgs = [f.message for f in active(found, "RPL003")]
    assert any("context_w" in m and "not registered" in m for m in msgs)


def test_rpl003_lane_removed_from_params_axes(tmp_path):
    src = LANES_OK.replace("gamma=0,", "")
    found = lint_tree(tmp_path, {"core/fleet.py": src})
    msgs = [f.message for f in active(found, "RPL003")]
    assert any("`gamma`" in m and "_params_axes" in m for m in msgs)


def test_rpl003_slicer_must_derive_from_classifier(tmp_path):
    src = LANES_OK.replace(
        "    axes = _params_axes(p)\n    return axes\n",
        "    return p\n",
    )
    found = lint_tree(tmp_path, {"core/fleet.py": src})
    msgs = [f.message for f in active(found, "RPL003")]
    assert any("slice_policy_lanes" in m for m in msgs)


def test_rpl003_surface_missing_lane(tmp_path):
    kernel = (
        "def fleet_step(mu, n, phat, pn, prev, t, arm, reward, prog, act,\n"
        "               alpha, lam, qos, def_arm, g, opt, prior):\n"
        "    return mu\n"  # no lam_unc parameter
    )
    found = lint_tree(
        tmp_path, {"core/fleet.py": LANES_OK, "kernels/k.py": kernel}
    )
    msgs = [f.message for f in active(found, "RPL003")]
    assert any("fleet_step" in m and "`lam_unc`" in m for m in msgs)


def test_rpl003_surface_with_aliases_clean(tmp_path):
    kernel = (
        "def fleet_step(mu, n, phat, pn, prev, t, arm, reward, prog, act,\n"
        "               alpha, lam, qos, def_arm, g, opt, prior, lam_unc):\n"
        "    return mu\n"
    )
    found = lint_tree(
        tmp_path, {"core/fleet.py": LANES_OK, "kernels/k.py": kernel}
    )
    assert not active(found, "RPL003")


def test_rpl003_pad_fills_must_cover_args(tmp_path):
    sharded = (
        "def make_sharded_fleet_step(mesh):\n"
        "    def step(mu, n, phat, pn, prev, t, arm, reward, prog, act,\n"
        "             alpha, lam, qos, def_arm, gamma, optimistic, prior_mu,\n"
        "             lam_unc):\n"
        "        args = [mu, n, alpha]\n"
        "        fills = (0, 1)\n"
        "        return args, fills\n"
        "    return step\n"
    )
    found = lint_tree(
        tmp_path, {"core/fleet.py": LANES_OK, "parallel/fleet.py": sharded}
    )
    msgs = [f.message for f in active(found, "RPL003")]
    assert any("fills" in m and "silently" in m for m in msgs)


def test_rpl003_absent_policyparams_is_exempt(tmp_path):
    # fixture trees without the dataclass (e.g. every other test here)
    # must not fire the project rule
    found = lint_tree(tmp_path, {"kernels/k.py": ONEHOT})
    assert not active(found, "RPL003")


# ------------------------------------------------------------- RPL004


def test_rpl004_wall_clock(tmp_path):
    src = "import time\n\ndef sample():\n    return time.time()\n"
    found = lint_tree(tmp_path, {"energy/backend.py": src})
    assert len(active(found, "RPL004")) == 1


def test_rpl004_local_count_split(tmp_path):
    src = (
        "import jax\n"
        "def noise(key, n_local):\n"
        "    return jax.random.split(key, n_local)\n"
    )
    found = lint_tree(tmp_path, {"energy/backend.py": src})
    hits = active(found, "RPL004")
    assert len(hits) == 1 and "fold_in" in hits[0].message


def test_rpl004_literal_split_and_fold_in_clean(tmp_path):
    src = (
        "import jax\n"
        "def noise(key, node_ids):\n"
        "    k1, k2, k3, k4 = jax.random.split(key, 4)\n"
        "    return jax.vmap(lambda i: jax.random.fold_in(k1, i))(node_ids)\n"
    )
    found = lint_tree(tmp_path, {"energy/backend.py": src})
    assert not active(found, "RPL004")


def test_rpl004_np_global_rng(tmp_path):
    src = "import numpy as np\n\ndef j():\n    return np.random.rand(3)\n"
    found = lint_tree(tmp_path, {"workload/traffic.py": src})
    assert len(active(found, "RPL004")) == 1


def test_rpl004_argless_default_rng(tmp_path):
    src = "import numpy as np\n\ndef j():\n    return np.random.default_rng()\n"
    found = lint_tree(tmp_path, {"energy/backend.py": src})
    assert len(active(found, "RPL004")) == 1


def test_rpl004_seeded_default_rng_clean(tmp_path):
    src = "import numpy as np\n\ndef j(s):\n    return np.random.default_rng(s)\n"
    found = lint_tree(tmp_path, {"energy/backend.py": src})
    assert not active(found, "RPL004")


def test_rpl004_out_of_scope_exempt(tmp_path):
    src = "import time\n\ndef bench():\n    return time.time()\n"
    found = lint_tree(tmp_path, {"launch/fleet_serve.py": src})
    assert not active(found, "RPL004")


# ------------------------------------------------------------- RPL005

LOCKED_CLASS = """
import threading

class Comm:
    def __init__(self):
        self._lock = threading.Lock()
        self._stash = {}
        self._epoch = 0

    def _bump_locked(self):
        self._epoch += 1
        self._stash.pop(0, None)

    def admit(self, h):
        with self._lock:
            self._stash[h] = 1
            self._bump_locked()

    def drain(self, h):
        self._stash.setdefault(h, {})["x"] = 1

    def mark(self, h):
        self._bump_locked()
"""


def test_rpl005_unlocked_mutation_and_locked_call(tmp_path):
    found = lint_tree(tmp_path, {"parallel/distributed.py": LOCKED_CLASS})
    hits = active(found, "RPL005")
    assert len(hits) == 2
    assert any("_stash" in h.message for h in hits)       # drain()
    assert any("_bump_locked" in h.message for h in hits)  # mark()


def test_rpl005_fixed_class_clean(tmp_path):
    src = LOCKED_CLASS.replace(
        '    def drain(self, h):\n'
        '        self._stash.setdefault(h, {})["x"] = 1\n',
        '    def drain(self, h):\n'
        '        with self._lock:\n'
        '            self._stash.setdefault(h, {})["x"] = 1\n',
    ).replace(
        "    def mark(self, h):\n        self._bump_locked()\n",
        "    def mark(self, h):\n"
        "        with self._lock:\n            self._bump_locked()\n",
    )
    found = lint_tree(tmp_path, {"parallel/distributed.py": src})
    assert not active(found, "RPL005")


def test_rpl005_lockless_class_exempt(tmp_path):
    src = (
        "class Plain:\n"
        "    def __init__(self):\n"
        "        self._stash = {}\n"
        "    def drain(self, h):\n"
        "        self._stash.setdefault(h, {})['x'] = 1\n"
    )
    found = lint_tree(tmp_path, {"parallel/distributed.py": src})
    assert not active(found, "RPL005")


def test_rpl005_unguarded_flag_poll_exempt(tmp_path):
    # a boolean flag only ever mutated OUTSIDE the lock (e.g. _closing)
    # is not lock-guarded; polling/flipping it lock-free is idiomatic
    src = LOCKED_CLASS + (
        "\n    def close(self):\n        self._closing = True\n"
    )
    found = lint_tree(tmp_path, {"parallel/distributed.py": src})
    assert not any("_closing" in f.message for f in active(found, "RPL005"))


# -------------------------------------------------- engine / CLI / repo


def test_syntax_error_reported_not_crash(tmp_path):
    found = lint_tree(tmp_path, {"kernels/bad.py": "def f(:\n"})
    assert len(active(found, "RPL000")) == 1


def test_real_repo_lints_clean():
    findings = run_lint(REPO_ROOT, [REPO_ROOT / "src" / "repro"])
    bad = active(findings)
    assert not bad, "\n".join(f.format() for f in bad)
    # and every suppression in the tree carries a justification
    assert all(f.reason for f in findings if f.suppressed)


def test_cli_exit_codes_and_json(tmp_path):
    trigger = tmp_path / "kernels" / "k.py"
    trigger.parent.mkdir(parents=True)
    trigger.write_text(SCATTER)
    out = subprocess.run(
        [sys.executable, str(REPO_ROOT / "scripts" / "repro_lint.py"),
         "--root", str(tmp_path), "--json", str(tmp_path)],
        capture_output=True, text=True,
    )
    assert out.returncode == 1
    payload = json.loads(out.stdout)
    assert payload["counts"]["error"] == 1
    assert payload["findings"][0]["rule"] == "RPL001"

    clean = subprocess.run(
        [sys.executable, str(REPO_ROOT / "scripts" / "repro_lint.py")],
        capture_output=True, text=True, cwd=REPO_ROOT,
    )
    assert clean.returncode == 0, clean.stdout + clean.stderr
