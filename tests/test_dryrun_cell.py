"""End-to-end dry-run regression: one real cell compiled in a subprocess
(fresh process so the 512 fake devices never leak into this test run),
guarding both the launcher path and the sharding-profile wins of
EXPERIMENTS.md §Perf."""
import json
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_qwen3_train_cell(tmp_path):
    out = str(tmp_path)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-1.7b", "--shape", "train_4k", "--out", out],
        capture_output=True, text=True, timeout=560,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.load(open(f"{out}/qwen3-1.7b__train_4k__pod.json"))
    assert rec["status"] == "ok"
    # fsdp profile regression guard: collectives stay ~20 GB/dev (the 2d
    # baseline was 193 GB; a sharding regression would blow past this)
    assert rec["collectives"]["total_bytes_per_device"] < 40 * 2**30
    # fits a 16 GB chip
    assert rec["memory_per_device"]["peak_est_bytes"] < 14 * 2**30


@pytest.mark.slow
def test_dryrun_decode_serve_profile(tmp_path):
    out = str(tmp_path)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-1.7b", "--shape", "decode_32k", "--out", out],
        capture_output=True, text=True, timeout=560,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.load(open(f"{out}/qwen3-1.7b__decode_32k__pod.json"))
    assert rec["status"] == "ok"
    # weight-stationary serving: per-token collectives far below weights
    assert rec["collectives"]["total_bytes_per_device"] < 2 * 2**30
