"""Beyond-paper extensions: RooflineUCB warm start, sliding-window
SA-UCB under phase change, DRLCap protocol plumbing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    energy_ucb,
    expected_rewards,
    get_app,
    make_env_params,
    run_episode,
    run_repeats,
)
from repro.core.simulator import EnvParams


@pytest.mark.slow
def test_roofline_ucb_warm_start_cuts_exploration():
    """Priors from a (roughly right) cost model => less exploration spend
    than the flat optimistic init."""
    p = make_env_params(get_app("sph_exa"))
    mu = np.asarray(expected_rewards(p))
    # measured (EXPERIMENTS.md): priors must be WEAK (prior_n ~ 1) —
    # confident priors (n>=3) exploit during the noisy early phase and
    # get corrupted faster than flat-optimistic init explores.
    noisy_prior = mu + 0.002 * np.random.default_rng(0).normal(size=mu.shape)
    flat = run_repeats(energy_ucb(), p, jax.random.key(0), 4)
    warm = run_repeats(
        energy_ucb(prior_mu=jnp.asarray(noisy_prior), prior_n=1.0,
                   name="RooflineUCB"),
        p, jax.random.key(0), 4,
    )
    assert warm["energy_kj"].mean() <= flat["energy_kj"].mean() + 0.5


@pytest.mark.slow
def test_sliding_window_adapts_to_phase_change():
    """Swap the environment mid-episode (train -> eval phase): the
    discounted controller re-converges; the stationary one is slower."""
    from repro.core.simulator import env_init

    p1 = make_env_params(get_app("miniswp"))   # memory-bound: low f best
    p2 = make_env_params(get_app("lbm"))       # compute-bound: high f best
    sw = energy_ucb(window_discount=0.995, name="SW")
    st = energy_ucb()

    def run_two_phase(pol, key):
        out1 = run_episode(pol, p1, key, max_steps=4000)
        # carry the learned state into a different reward landscape
        out2 = run_episode(pol, p2, key, max_steps=6000,
                           init_pstate=out1["pstate"])
        arms = np.asarray(out2["arms"])[:int(out2["steps"])]
        tail = arms[len(arms) // 2:]
        mu2 = np.asarray(expected_rewards(p2))
        best2 = int(np.argmax(mu2))
        # tail quality: mean expected reward of chosen arms vs the best
        qual = float(np.mean(mu2[tail])) / float(mu2[best2])
        return np.mean(tail == best2), qual

    frac_sw, q_sw = run_two_phase(sw, jax.random.key(0))
    frac_st, q_st = run_two_phase(st, jax.random.key(0))
    assert frac_sw >= frac_st - 0.05  # no worse at re-identifying the arm
    # rewards negative: qual is the tail-arm reward relative to the best
    # arm (1.0 = optimal, larger = worse); SW must stay near-optimal
    assert q_sw < 1.05


@pytest.mark.slow
def test_drlcap_protocol_energy_accounting():
    from repro.core.rl import drlcap
    from repro.core.rollout import run_drlcap_protocol

    p = make_env_params(get_app("tealeaf"))
    out = run_drlcap_protocol(drlcap, p, jax.random.key(0))
    # 20% at some energy + 1.25 x 80%: must exceed any static total * 0.9
    assert float(out["energy_kj"]) > 90.0


def test_fit_spec_shape_awareness_on_real_cells():
    """B=1 long-context decode must drop batch sharding, not fail."""
    from repro.parallel.sharding import Sharder
    import numpy as np_

    s = Sharder.__new__(Sharder)
    s.mesh = type("M", (), {"axis_names": ("data", "model"),
                            "devices": np_.zeros((16, 16))})()
    from repro.parallel.sharding import rules_for

    s.rules = rules_for("serve")
    from jax.sharding import PartitionSpec as P

    fitted = s._fit_spec_to_shape(P("data", None, None, "model"), (1, 524288, 32, 112))
    assert fitted == P(None, None, None, "model")
