"""Fault-tolerant elastic fleet control plane: lease membership must
degrade (never block) on host death, a SIGKILLed host must resurrect
from its stripe checkpoint bit-exact, and elastic re-stripes stitched
out of per-stripe checkpoints must replay exactly like a fleet launched
at the new size. The single-process run stays the correctness oracle
throughout — fault injection must not cost a single ulp on surviving
stripes.

The subprocess soaks (H=8 kill + resurrect; the H=16 double-kill
nightly variant) are ``slow``: the push/PR ``fault-soak`` CI lane runs
them explicitly (minus ``nightly``), the scheduled slow lane runs
everything. Set ``FAULT_SOAK_ARTIFACTS`` to persist per-host logs and
the checkpoint tree for post-mortem upload (the CI lane does, with
``if: failure()``)."""
import os
import secrets
import signal
import socket
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import get_app, make_env_params
from repro.core.fleet import slice_policy_lanes
from repro.core.policies import energy_ucb
from repro.energy import EnergyController, SimBackend
from repro.parallel.distributed import (
    ClientComm,
    CoordinatorComm,
    DistributedFleetController,
    connect_fleet,
    restore_fleet_controller,
)
from repro.parallel.fleet import host_stripe, stripe_bounds, stripe_map
from repro.train import checkpoint

REPO = Path(__file__).resolve().parent.parent


def _subproc_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env["FLEET_AUTHKEY"] = secrets.token_hex(16)
    return env


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _run_controller(ctl, t):
    arms = []
    for _ in range(t):
        ctl.step()
        arms.append(np.asarray(ctl.last_arms).reshape(-1))
    return np.stack(arms)


ENV = make_env_params(get_app("tealeaf"))


def _stripe_ctl(lo, hi, n_total, ckpt_dir=None, every=0, comm=None):
    return DistributedFleetController(
        slice_policy_lanes(energy_ucb(), lo, hi, n_total),
        SimBackend(ENV, n=hi - lo, seed=0, node_offset=lo),
        comm, stripe=(lo, hi), n_total=n_total, seed=0, interpret=True,
        log_arms=True, checkpoint_dir=ckpt_dir, checkpoint_every=every,
    )


# ---------------------------------------------------------------------------
# comm: lease membership, stale-tolerant folds, rejoin
# ---------------------------------------------------------------------------


def test_connect_fleet_backoff_times_out_with_clear_error():
    """The connect race bugfix: a client dialing a coordinator that
    never comes up must fail at the deadline with a diagnosis, not spin
    forever or die on the first ConnectionRefusedError."""
    t0 = time.monotonic()
    with pytest.raises(TimeoutError, match="not accepting"):
        connect_fleet(2, 1, ("127.0.0.1", _free_port()), timeout_s=1.0)
    assert time.monotonic() - t0 < 10.0


def test_fold_degrades_on_death_and_rejoin_bumps_epoch():
    """An abruptly-dead host's fold slot degrades to None (wire death,
    no blocking); a reconnect under the same id is admitted with a
    rejoined ACK, bumps the epoch, and the next STRICT gather waits for
    the resurrected host and skims its stale re-sent folds."""
    port = _free_port()
    out = {}

    def doomed():
        c = ClientComm(("127.0.0.1", port), 3, 1)
        c.allgather("a1", "start")
        c._conn.close()  # SIGKILL signature: socket closes, no goodbye

    def survivor():
        c = ClientComm(("127.0.0.1", port), 3, 2)
        out["rejoined2"] = c.rejoined
        c.allgather("a2", "start")
        c.fold("f2", "r1")
        out["final2"] = c.allgather("b2", "final")
        c.close()

    def resurrected():
        c = ClientComm(("127.0.0.1", port), 3, 1)
        out["rejoined1"] = c.rejoined
        out["epoch1"] = c.fleet_epoch()
        c.fold("stale", "r0")  # a replayed, long-gone fold tick
        out["final1"] = c.allgather("b1", "final")
        c.close()

    threads = [threading.Thread(target=doomed),
               threading.Thread(target=survivor)]
    for t in threads:
        t.start()
    coord = CoordinatorComm(("127.0.0.1", port), 3, lease_s=2.0, n_total=9)
    with coord:
        assert coord.allgather("a0", "start") == ["a0", "a1", "a2"]
        threads[0].join()  # host 1 is gone
        got = coord.fold("f0", "r1")
        assert got[0] == "f0" and got[1] is None and got[2] == "f2"
        assert coord.dead_hosts() == {1: "connection lost"}
        fe = coord.fleet_epoch()
        assert fe.members == (0, 2)
        assert fe.stripes == stripe_map(9, (0, 2))
        epoch_after_death = fe.epoch
        t3 = threading.Thread(target=resurrected)
        t3.start()
        deadline = time.monotonic() + 30.0
        while 1 not in coord.fleet_epoch().members:
            assert time.monotonic() < deadline, "rejoin was never admitted"
            time.sleep(0.01)
        final = coord.allgather("b0", "final")
        assert final == ["b0", "b1", "b2"]
        t3.join()
        threads[1].join()
    assert out["rejoined2"] is False  # rendezvous join
    assert out["rejoined1"] is True  # mid-run admission
    assert out["epoch1"].epoch > epoch_after_death
    assert out["epoch1"].members == (0, 1, 2)
    assert out["final1"] == ["b0", "b1", "b2"]
    assert out["final2"] == ["b0", "b1", "b2"]


def test_lease_eviction_of_silent_host_is_opt_in():
    """Wire-alive but silent hosts keep membership by default; with
    ``max_missed_folds`` the coordinator evicts them after that many
    consecutive missed fold leases."""
    port = _free_port()
    stop = threading.Event()

    def silent():
        c = ClientComm(("127.0.0.1", port), 2, 1)
        c.allgather("a1", "start")
        stop.wait(30.0)  # never contributes another round
        c.close()

    t = threading.Thread(target=silent)
    t.start()
    coord = CoordinatorComm(("127.0.0.1", port), 2, lease_s=0.2,
                            max_missed_folds=2)
    with coord:
        coord.allgather("a0", "start")
        assert coord.fold("f0", "r1")[1] is None  # miss 1: still a member
        assert coord.fleet_epoch().members == (0, 1)
        coord.fold("f0", "r2")  # miss 2: lease expired
        assert coord.fleet_epoch().members == (0,)
        assert "lease expired" in coord.dead_hosts()[1]
    stop.set()
    t.join()


def test_controller_reports_degrade_and_final_collects_ahead_host():
    """Controller-level integration: a 2-host fleet where host 1
    finishes early and goes quiet. Host 0's periodic folds degrade to
    its own stripe (hosts=1) without blocking, and the final STRICT
    gather still collects host 1's stashed contribution (hosts=2)."""
    port = _free_port()
    n = 4
    (lo0, hi0), (lo1, hi1) = stripe_bounds(n, 2)
    out = {}

    def fast_host():
        comm = ClientComm(("127.0.0.1", port), 2, 1)
        with comm:
            ctl = _stripe_ctl(lo1, hi1, n, comm=comm)
            comm.barrier("start")
            out["final1"] = ctl.run(20)

    t = threading.Thread(target=fast_host)
    t.start()
    comm = CoordinatorComm(("127.0.0.1", port), 2, lease_s=0.3)
    with comm:
        ctl = _stripe_ctl(lo0, hi0, n, comm=comm)
        comm.barrier("start")
        final = ctl.run(20, report_every=5)
    t.join()
    assert final["hosts"] == 2 and final["nodes"] == n
    assert final == out["final1"]
    assert all(r["hosts"] == 1 for r in ctl.reports)


# ---------------------------------------------------------------------------
# stripe checkpoints: crash-restart resume + elastic re-stripe
# ---------------------------------------------------------------------------


def test_checkpoint_resume_is_bit_exact(tmp_path):
    """Crash-restart on one stripe: a fresh process restoring the
    latest checkpoint and replaying forward reproduces the uncrashed
    run's arms and fused-kernel state bit for bit."""
    n, t = 6, 12
    ref = _stripe_ctl(0, n, n)
    for _ in range(t):
        ref.step()
    ref_arms = np.stack(ref.arm_log)

    live = _stripe_ctl(0, n, n, ckpt_dir=str(tmp_path))
    for _ in range(9):  # crash between checkpoints: latest save is step 8
        live.step()
        if live.interval % 4 == 0:
            live.save_checkpoint()  # async, like run()'s cadence tick
    checkpoint.wait_for_saves()
    del live

    back = _stripe_ctl(0, n, n, ckpt_dir=str(tmp_path))
    assert back.try_restore()
    assert back.interval == 8
    for _ in range(t - 8):
        back.step()
    np.testing.assert_array_equal(np.stack(back.arm_log), ref_arms)
    for k in ref.controller.states:
        np.testing.assert_array_equal(
            np.asarray(back.controller.states[k]),
            np.asarray(ref.controller.states[k]),
            err_msg=f"resumed state diverged on {k}")


def test_elastic_restripe_from_checkpoints_matches_oracle(tmp_path):
    """Elastic leave: an H=3 fleet checkpoints, host 1 never returns,
    and the surviving pair rebuilds at the stripe_map(N, {0, 2}) bounds
    via restore_fleet_controller — each new stripe stitched row-wise
    out of the old stripe checkpoints at their common step. The rebuilt
    fleet's arms and state match the single-process oracle exactly."""
    n, t_ck, t = 8, 8, 12
    ref = _stripe_ctl(0, n, n)
    for _ in range(t):
        ref.step()
    ref_arms = np.stack(ref.arm_log)

    for lo, hi in stripe_bounds(n, 3):
        ctl = _stripe_ctl(lo, hi, n, ckpt_dir=str(tmp_path))
        for _ in range(t_ck):
            ctl.step()
        ctl.save_checkpoint(block=True)

    smap = stripe_map(n, [0, 2])
    assert smap == {0: (0, 4), 2: (4, 8)}
    parts = []
    for h, (lo, hi) in sorted(smap.items()):
        ctl = restore_fleet_controller(
            energy_ucb(),
            lambda lo, hi: SimBackend(ENV, n=hi - lo, seed=0, node_offset=lo),
            lo, hi, n, str(tmp_path), seed=0, interpret=True, log_arms=True)
        assert ctl.interval == t_ck
        for _ in range(t - t_ck):
            ctl.step()
        parts.append(ctl)
    arms = np.concatenate([np.stack(p.arm_log) for p in parts], axis=1)
    np.testing.assert_array_equal(arms, ref_arms)
    for k in ref.controller.states:
        got = np.concatenate(
            [np.asarray(p.controller.states[k]) for p in parts])
        np.testing.assert_array_equal(
            got, np.asarray(ref.controller.states[k]),
            err_msg=f"restriped state diverged on {k}")


def test_restore_stripe_picks_latest_common_step(tmp_path):
    """Stitching across stripes whose checkpoint histories differ must
    pick the latest COMMON step (states are only mutually coherent at a
    common interval), and refuse a step any covering stripe lacks."""
    state = lambda lo, hi, v: {
        "striped": {"x": np.arange(lo, hi, dtype=np.int64) * 10 + v},
        "host": {"k": np.int64(v)},
    }
    for step in (4, 8):
        checkpoint.save(checkpoint.stripe_dir(str(tmp_path), 0, 4),
                        step, state(0, 4, step))
    checkpoint.save(checkpoint.stripe_dir(str(tmp_path), 4, 8),
                    4, state(4, 8, 4))
    step, got, _ = checkpoint.restore_stripe(
        str(tmp_path), 1, 7, like=state(1, 7, 0))
    assert step == 4
    np.testing.assert_array_equal(got["striped"]["x"],
                                  np.arange(1, 7) * 10 + 4)
    assert int(got["host"]["k"]) == 4
    with pytest.raises(FileNotFoundError, match="not present in every"):
        checkpoint.restore_stripe(str(tmp_path), 1, 7,
                                  like=state(1, 7, 0), step=8)
    with pytest.raises(FileNotFoundError, match="uncovered"):
        checkpoint.restore_stripe(str(tmp_path), 4, 9,
                                  like=state(4, 9, 0))


# ---------------------------------------------------------------------------
# the soak: H subprocess hosts, SIGKILL + resurrect mid-run
# ---------------------------------------------------------------------------


def _artifact_dir(tmp_path: Path, name: str) -> Path:
    root = os.environ.get("FAULT_SOAK_ARTIFACTS")
    d = (Path(root) if root else tmp_path) / name
    d.mkdir(parents=True, exist_ok=True)
    return d


def _host_cmd(h, hosts, n, t, port, ckpt_dir, out, pace, every):
    return [sys.executable, "-m", "repro.launch.fleet_serve",
            "--nodes", str(n), "--intervals", str(t), "--app", "tealeaf",
            "--num-hosts", str(hosts), "--host-id", str(h),
            "--coordinator", f"127.0.0.1:{port}", "--seed", "0",
            "--interpret", "--pace", str(pace), "--report-every", "10",
            "--checkpoint-dir", str(ckpt_dir),
            "--checkpoint-every", str(every), "--out", str(out)]


def _launch(cmd, log_path, env):
    with open(log_path, "ab") as log:
        return subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT,
                                env=env, cwd=str(REPO))


def _wait_for_checkpoint(stripe_dir, timeout_s=240.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        steps = checkpoint.list_steps(str(stripe_dir))
        if steps:
            return steps[-1]
        time.sleep(0.05)
    raise TimeoutError(f"no checkpoint appeared under {stripe_dir}")


def _soak(tmp_path, name, hosts, n, t, victims, pace=0.25, every=5):
    """Launch H fleet_serve processes, SIGKILL each victim as soon as
    its stripe has a complete checkpoint, relaunch it with the SAME
    command line (the runbook), and require: every process exits 0,
    each victim logs a checkpoint resume, and the gathered (T, N) arms
    + final fused-kernel state match the single-process oracle
    bit-for-bit on EVERY stripe (the resurrected ones included)."""
    art = _artifact_dir(tmp_path, name)
    ckpt_dir, out = art / "ckpt", art / "arms.npz"
    port, env = _free_port(), _subproc_env()
    cmds = {h: _host_cmd(h, hosts, n, t, port, ckpt_dir, out, pace, every)
            for h in range(hosts)}
    logs = {h: art / f"host{h}.log" for h in range(hosts)}
    procs = {h: _launch(cmds[h], logs[h], env) for h in range(hosts)}
    relaunched = {}
    try:
        for v in victims:
            stripe = host_stripe(n, hosts, v)
            step = _wait_for_checkpoint(
                checkpoint.stripe_dir(str(ckpt_dir), *stripe))
            assert procs[v].poll() is None, (
                f"victim {v} already exited (rc={procs[v].poll()}) before "
                f"the kill window — raise --intervals/--pace. Log:\n"
                + logs[v].read_text()[-2000:])
            os.kill(procs[v].pid, signal.SIGKILL)
            procs[v].wait(timeout=30)
            assert step < t, f"victim {v} checkpointed the whole run"
            relaunched[v] = _launch(cmds[v], logs[v], env)
        rcs = {h: p.wait(timeout=420) for h, p in procs.items()}
        rcs.update({h: p.wait(timeout=420) for h, p in relaunched.items()})
    finally:
        for p in [*procs.values(), *relaunched.values()]:
            if p.poll() is None:
                p.kill()
    for v in victims:
        assert rcs[v] == 0, f"victim {v} relaunch failed:\n" + \
            logs[v].read_text()[-4000:]
        assert "resumed stripe" in logs[v].read_text(), (
            f"victim {v} restarted from scratch instead of its checkpoint")
    for h, rc in rcs.items():
        assert rc == 0, f"host {h} rc={rc}:\n" + logs[h].read_text()[-4000:]

    z = np.load(out)
    assert z["missing_hosts"].size == 0, (
        f"hosts {z['missing_hosts']} never made it back into the final "
        "gather")
    ref = EnergyController(energy_ucb(), SimBackend(ENV, n=n, seed=0),
                           seed=0, interpret=True)
    ref_arms = _run_controller(ref, t)
    np.testing.assert_array_equal(z["arms"], ref_arms)
    for leaf in ref.states:
        np.testing.assert_array_equal(
            z[f"state_{leaf}"], np.asarray(ref.states[leaf]),
            err_msg=f"soak state diverged on {leaf}")


@pytest.mark.slow
def test_soak_h8_sigkill_and_resurrect(tmp_path):
    """The acceptance soak: 8 subprocess hosts, one SIGKILLed right
    after its first stripe checkpoint and relaunched with the same
    command line. The fleet's folds degrade while it is down, the
    strict final gather waits for its return, and the full (T, N)
    trajectory still matches the single-process oracle arm for arm."""
    _soak(tmp_path, "h8", hosts=8, n=16, t=80, victims=[3])


@pytest.mark.slow
@pytest.mark.nightly
def test_soak_h16_double_kill(tmp_path):
    """The nightly variant: 16 hosts, two victims killed and
    resurrected one after the other — serial churn, same oracle."""
    _soak(tmp_path, "h16", hosts=16, n=32, t=100, victims=[5, 11])
