"""Pallas kernels vs. pure-jnp oracles (interpret mode on CPU), swept
over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize(
    "b,s,h,kv,hd",
    [(2, 256, 4, 2, 64), (1, 512, 8, 8, 128), (2, 128, 6, 2, 32), (1, 256, 4, 1, 64)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, h, kv, hd, dtype):
    ks = jax.random.split(jax.random.key(s * h + hd), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.ref_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = ops.flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.ref_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=False,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,h,nc,p,n", [(2, 4, 8, 16, 32), (1, 2, 16, 64, 64), (3, 1, 4, 8, 8)])
def test_ssd_chunk_scan(b, h, nc, p, n):
    key = jax.random.key(b * h + nc)
    st = jax.random.normal(jax.random.fold_in(key, 1), (b, h, nc, p, n), jnp.float32)
    dec = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 2), (b, h, nc)))
    init = jax.random.normal(jax.random.fold_in(key, 3), (b, h, p, n), jnp.float32)
    prev, fin = ops.ssd_chunk_scan(st, dec, init, interpret=True)
    rprev, rfin = ref.ref_chunk_scan(st, dec, init)
    np.testing.assert_allclose(np.asarray(prev), np.asarray(rprev), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(rfin), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("n,k,block", [(1024, 9, 256), (4096, 9, 1024), (512, 5, 512)])
def test_fleet_select(n, k, block):
    key = jax.random.key(n + k)
    mu = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    cnt = jax.random.randint(jax.random.fold_in(key, 2), (n, k), 0, 50).astype(jnp.float32)
    prev = jax.random.randint(jax.random.fold_in(key, 3), (n,), 0, k)
    t = jnp.full((n,), 123.0)
    arm = ops.fleet_select(mu, cnt, prev, t, interpret=True)
    want = ref.ref_fleet_select(mu, cnt, prev, t)
    assert bool(jnp.all(arm == want))


def _fleet_state(n, k=9, seed=0):
    key = jax.random.key(seed)
    f = lambda i: jax.random.fold_in(key, i)
    return dict(
        mu=jax.random.normal(f(1), (n, k)) * -1.0,
        n=jax.random.randint(f(2), (n, k), 1, 40).astype(jnp.float32),
        phat=jax.random.uniform(f(3), (n, k), minval=1e-4, maxval=2e-4),
        pn=jax.random.randint(f(4), (n, k), 0, 40).astype(jnp.float32),
        prev=jax.random.randint(f(5), (n,), 0, k),
        t=jax.random.randint(f(6), (n,), 1, 200).astype(jnp.float32),
        arm=jax.random.randint(f(7), (n,), 0, k),
        reward=-jax.random.uniform(f(8), (n,), minval=0.5, maxval=1.5),
        progress=jax.random.uniform(f(9), (n,), minval=1e-4, maxval=2e-4),
        active=(jax.random.uniform(f(10), (n,)) < 0.8).astype(jnp.float32),
        alpha=jax.random.uniform(f(11), (n,), minval=0.05, maxval=0.3),
        lam=jax.random.uniform(f(12), (n,), minval=0.0, maxval=0.05),
    )


# ragged fleet sizes: below one stripe, exactly one, and a non-multiple
@pytest.mark.parametrize("n", [7, 1024, 2049])
def test_fleet_step_matches_ref(n):
    """The fused select+update step (interpret mode) is exact vs the
    pure-jnp oracle, with per-controller hyperparams and inactive
    (frozen) controllers in the batch."""
    s = _fleet_state(n, seed=n)
    args = (s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
            s["reward"], s["progress"], s["active"], s["alpha"], s["lam"])
    got = ops.fleet_step(*args, interpret=True)
    want = ref.ref_fleet_step(*args)
    names = ("mu", "n", "phat", "pn", "prev", "t", "next_arm")
    for nm, g, w in zip(names, got, want):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w), err_msg=f"fleet_step {nm} n={n}")


def _qos_lanes(s, n, k=9):
    """Mixed per-controller QoS lanes: ~half sentinel-off, the rest a
    spread of budgets incl. 0.0; per-node reference arms; and a third of
    the fleet with a sample-free reference arm (the untried-ref rule)."""
    key = jax.random.key(1000 + n)
    f = lambda i: jax.random.fold_in(key, i)
    qos = jnp.where(jax.random.uniform(f(1), (n,)) < 0.5,
                    jax.random.uniform(f(2), (n,), maxval=0.15), -1.0)
    qos = qos.at[: min(4, n)].set(0.0)  # strictest valid budget
    da = jax.random.randint(f(3), (n,), 0, k)
    zero_ref = ((jnp.arange(n) % 3 == 0)[:, None]
                & (jnp.arange(k)[None, :] == da[:, None]))
    s = dict(s, pn=jnp.where(zero_ref, 0.0, s["pn"]))
    return s, qos, da


# ragged fleet sizes again: the QoS lane must survive pad-and-slice
@pytest.mark.parametrize("n", [7, 1024, 2049])
def test_fleet_step_qos_lane_matches_ref(n):
    """The fused step's QoS feasible-set lane (interpret mode) is exact
    vs the oracle on mixed constrained/sentinel-off fleets, including
    controllers whose reference arm has no progress samples yet."""
    s, qos, da = _qos_lanes(_fleet_state(n, seed=n + 1), n)
    args = (s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
            s["reward"], s["progress"], s["active"], s["alpha"], s["lam"])
    got = ops.fleet_step(*args, qos, da, interpret=True)
    want = ref.ref_fleet_step(*args, qos=qos, default_arm=da)
    names = ("mu", "n", "phat", "pn", "prev", "t", "next_arm")
    for nm, g, w in zip(names, got, want):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w), err_msg=f"qos fleet_step {nm} n={n}")


def test_fleet_step_qos_constraint_binds():
    """On a fleet where low arms look best but are too slow, the
    constrained selection must differ from the unconstrained one (the
    lane is live, not decorative) while the sentinel-off rows agree."""
    n = 256
    s = _fleet_state(n, seed=11)
    # progress strongly increasing in arm index; rewards favor arm 0
    k = s["mu"].shape[1]
    s["phat"] = jnp.broadcast_to(jnp.linspace(1e-4, 2e-4, k), (n, k))
    s["mu"] = jnp.broadcast_to(-jnp.linspace(0.2, 1.0, k), (n, k))
    s["pn"] = jnp.full((n, k), 5.0)
    s["n"] = jnp.full((n, k), 5.0)
    da = jnp.full((n,), k - 1, jnp.int32)
    qos_on = jnp.full((n,), 0.05, jnp.float32)
    args = (s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
            s["reward"], s["progress"], s["active"], s["alpha"], s["lam"])
    con = ops.fleet_step(*args, qos_on, da, interpret=True)[-1]
    unc = ops.fleet_step(*args, -jnp.ones((n,)), da, interpret=True)[-1]
    assert not np.array_equal(np.asarray(con), np.asarray(unc))
    # constrained picks satisfy the budget on their estimated slowdown
    phat2 = np.asarray(ops.fleet_step(*args, qos_on, da, interpret=True)[2])
    rows = np.arange(n)
    slow = 1.0 - phat2[rows, np.asarray(con)] / phat2[rows, k - 1]
    assert (slow <= 0.05 + 1e-6).all()


def test_fleet_step_qos_sentinel_matches_unconstrained():
    """An all-sentinel (-1) qos lane reproduces the unconstrained kernel
    bit for bit — one launch serves mixed fleets."""
    n = 130
    s = _fleet_state(n, seed=5)
    args = (s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
            s["reward"], s["progress"], s["active"], s["alpha"], s["lam"])
    got = ops.fleet_step(*args, -jnp.ones((n,)),
                         jnp.zeros((n,), jnp.int32), interpret=True)
    want = ref.ref_fleet_step(*args)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def _ns_lanes(n, k=9):
    """Mixed nonstationary lanes: ~half the fleet sliding-window (a
    spread of gamma < 1 incl. the 0.0 last-sample-only extreme), the
    rest stationary via the gamma >= 1 sentinel; a third on round-robin
    warm-up (optimistic < 0.5); and a nonzero optimistic prior so the
    shrink-to-prior term is exercised off its zero fixed point."""
    key = jax.random.key(2000 + n)
    f = lambda i: jax.random.fold_in(key, i)
    gamma = jnp.where(jax.random.uniform(f(1), (n,)) < 0.5,
                      jax.random.uniform(f(2), (n,), maxval=0.999), 1.0)
    gamma = gamma.at[: min(3, n)].set(0.0)
    optimistic = jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0)
    prior = jax.random.normal(f(3), (n, k)) * 0.1
    return gamma, optimistic, prior


# ragged again: the nonstationary lanes must survive pad-and-slice
@pytest.mark.parametrize("n", [7, 1024, 2049])
def test_fleet_step_nonstationary_lanes_match_ref(n):
    """The fused step's gamma/optimistic lanes (interpret mode) are
    exact vs the oracle on fleets mixing sliding-window, warm-up,
    stationary, QoS-constrained and inactive controllers — the full
    EnergyUCB family in one launch."""
    s, qos, da = _qos_lanes(_fleet_state(n, seed=n + 2), n)
    # decayed effective counts below 1 (stale arms) must round-trip too
    s["n"] = s["n"] * jnp.where(jnp.arange(n) % 2 == 0, 0.013, 1.0)[:, None]
    gamma, optimistic, prior = _ns_lanes(n)
    args = (s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
            s["reward"], s["progress"], s["active"], s["alpha"], s["lam"])
    got = ops.fleet_step(*args, qos, da, gamma, optimistic, prior,
                         interpret=True)
    # jit the oracle: the discounted closed form is a mul-mul-add-div
    # chain XLA contracts into FMA under jit; eager per-op execution
    # rounds the add separately (1 ulp). Same expressions, same
    # compiler, bit-identical results.
    want = jax.jit(ref.ref_fleet_step)(*args, qos=qos, default_arm=da,
                                       gamma=gamma, optimistic=optimistic,
                                       prior_mu=prior)
    names = ("mu", "n", "phat", "pn", "prev", "t", "next_arm")
    for nm, g, w in zip(names, got, want):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w), err_msg=f"ns fleet_step {nm} n={n}")


def test_fleet_step_ns_sentinels_match_stationary_kernel():
    """All-sentinel gamma (>= 1) / optimistic (>= 0.5) lanes reproduce
    the stationary kernel bit for bit — mixed fleets share one launch
    with zero cost to the stationary rows."""
    n = 130
    s = _fleet_state(n, seed=6)
    args = (s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
            s["reward"], s["progress"], s["active"], s["alpha"], s["lam"])
    got = ops.fleet_step(*args, -jnp.ones((n,)), jnp.zeros((n,), jnp.int32),
                         jnp.full((n,), 1.5), jnp.ones((n,)),
                         jax.random.normal(jax.random.key(0), (n, 9)),
                         interpret=True)
    want = ref.ref_fleet_step(*args)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_fleet_step_sw_discounts_counts_and_progress():
    """A gamma < 1 row decays EVERY arm's reward and progress counts by
    gamma before the new sample lands; stationary rows are untouched."""
    n, k = 4, 9
    s = _fleet_state(n, seed=9)
    s["active"] = jnp.ones((n,), jnp.float32)
    gamma = jnp.asarray([0.9, 1.0, 0.9, 1.0], jnp.float32)
    out = ops.fleet_step(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        s["reward"], s["progress"], s["active"], s["alpha"], s["lam"],
        -jnp.ones((n,)), jnp.zeros((n,), jnp.int32), gamma, jnp.ones((n,)),
        jnp.zeros((n, k)), interpret=True)
    onehot = np.eye(k, dtype=np.float32)[np.asarray(s["arm"])]
    for name, new, old in (("n", out[1], s["n"]), ("pn", out[3], s["pn"])):
        want = np.where(np.asarray(gamma)[:, None] < 1.0,
                        np.asarray(old) * np.asarray(gamma)[:, None],
                        np.asarray(old)) + onehot
        np.testing.assert_allclose(np.asarray(new), want, rtol=1e-6,
                                   err_msg=f"discounted {name}")


def test_fleet_step_warmup_lane_round_robins_untried():
    """optimistic < 0.5 rows sweep untried arms lowest-index-first (the
    'w/o Opt. Ini.' ablation), while optimistic rows keep the SA-UCB
    argmax; once every arm is tried the warm-up lane is inert."""
    n, k = 6, 9
    s = _fleet_state(n, seed=12)
    s["active"] = jnp.ones((n,), jnp.float32)
    s["n"] = jnp.full((n, k), 5.0).at[0, 4].set(0.0).at[0, 2].set(0.0) \
        .at[1, 7].set(0.0)
    opt = jnp.asarray([0.0, 0.0, 0.0, 1.0, 1.0, 1.0], jnp.float32)
    # keep the just-pulled arm's count clear of the probe zeros
    s["arm"] = jnp.zeros((n,), jnp.int32)
    out = ops.fleet_step(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        s["reward"], s["progress"], s["active"], s["alpha"], s["lam"],
        -jnp.ones((n,)), jnp.zeros((n,), jnp.int32), jnp.ones((n,)), opt,
        jnp.zeros((n, k)), interpret=True)
    nxt = np.asarray(out[-1])
    assert nxt[0] == 2, "warm-up must take the lowest-index untried arm"
    assert nxt[1] == 7
    # row 2 warm-up with nothing untried, rows 3-5 optimistic: plain SA
    want = ref.ref_fleet_step(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        s["reward"], s["progress"], s["active"], s["alpha"], s["lam"])[-1]
    np.testing.assert_array_equal(nxt[2:], np.asarray(want)[2:])


def test_fleet_step_frozen_controllers_keep_state():
    """Inactive controllers ride through untouched — including
    sliding-window rows, whose discount must NOT decay a finished job's
    state (the vmapped path freezes whole rows the same way)."""
    s = _fleet_state(64, seed=3)
    s["active"] = jnp.zeros((64,), jnp.float32)
    gamma = jnp.where(jnp.arange(64) % 2 == 0, 0.9, 1.0)
    got = ops.fleet_step(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        s["reward"], s["progress"], s["active"], s["alpha"], s["lam"],
        gamma=gamma, interpret=True,
    )
    for nm, g in zip(("mu", "n", "phat", "pn", "prev", "t"), got):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(s[nm]),
                                      err_msg=f"inactive fleet mutated {nm}")


def test_flash_attention_used_by_layers_dispatch():
    """layers.attention(impl='pallas') falls back to chunked off-TPU but
    must stay numerically consistent with the dense path."""
    from repro.models import layers as L

    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    a = L.attention(q, k, v, causal=True, impl="pallas")
    b = L.attention(q, k, v, causal=True, impl="dense")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)
