"""EnergyBackend control-plane parity: the same counter/actuator surface
must tell the same story whether the telemetry comes from the pure-JAX
env (SimBackend), the GEOPM-shaped node simulator, or a recorded trace —
and the streaming controller must derive real observations (including
the switched bit) from counter deltas alone."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    energy_ucb,
    expected_rewards,
    get_app,
    make_env_params,
    static_policy,
)
from repro.energy import (
    EnergyController,
    SimBackend,
    SimulatedGEOPM,
    StepEnergyModel,
    TraceReplayBackend,
    derive_obs,
    env_params_from_roofline,
    make_backend,
    record_trace,
)

MODEL = StepEnergyModel(t_compute_s=0.2, t_memory_s=0.4, t_collective_s=0.1,
                        n_chips=4, steps_total=200)


def noise_free_params():
    return env_params_from_roofline(
        MODEL, noise_energy=0.0, noise_util=0.0, early_noise=0.0
    )


def drive_static(backend, arm: int, t: int):
    """Apply a constant arm for t intervals; return counter snapshots."""
    rows = [backend.read_counters()]
    arms = np.full((backend.n_nodes,), arm, np.int32)
    for _ in range(t):
        backend.apply_arms(arms)
        backend.advance()
        rows.append(backend.read_counters())
    return rows


# ---------------------------------------------------------------------------
# sim / GEOPM / expected-rewards parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arm", [0, 4, 8])
def test_sim_geopm_expected_reward_parity(arm):
    """Per-interval energy rate / uc / uu / reward derived from GEOPM
    counter deltas match the SimBackend derivation and the simulator's
    noise-free expected rewards, arm by arm."""
    params = noise_free_params()
    exp_r = np.asarray(expected_rewards(params))

    geo = SimulatedGEOPM(model=MODEL)
    sim = SimBackend(params, n=1)
    rows_g = drive_static(geo, arm, 6)
    rows_s = drive_static(sim, arm, 6)
    # interval 0 pays the initial switch off the default arm; compare
    # steady-state intervals
    for i in range(2, 6):
        og = derive_obs(rows_g[i], rows_g[i + 1], geo.reward_scale,
                        geo.interval_s)
        os_ = derive_obs(rows_s[i], rows_s[i + 1], params.reward_scale)
        np.testing.assert_allclose(np.asarray(og.uc), np.asarray(os_.uc),
                                   rtol=1e-4)
        np.testing.assert_allclose(np.asarray(og.uu), np.asarray(os_.uu),
                                   rtol=1e-4)
        # energy rates (J per wall-second) agree across interval shapes
        d_t_g = float(rows_g[i + 1].timestamp_s[0] - rows_g[i].timestamp_s[0])
        d_t_s = float(rows_s[i + 1].timestamp_s[0] - rows_s[i].timestamp_s[0])
        np.testing.assert_allclose(
            float(og.energy_j[0]) / d_t_g, float(os_.energy_j[0]) / d_t_s,
            rtol=1e-4,
        )
        np.testing.assert_allclose(np.asarray(og.reward), exp_r[arm], rtol=1e-4)
        np.testing.assert_allclose(np.asarray(os_.reward), exp_r[arm], rtol=1e-4)
        assert not bool(np.asarray(og.switched)[0])
        assert not bool(np.asarray(os_.switched)[0])


def test_sim_backend_ragged_fleet_matches_expected_rewards():
    """A ragged (non-stripe-multiple) fleet of N=7 noise-free nodes all
    report the per-arm expected reward through the counter surface."""
    params = noise_free_params()
    exp_r = np.asarray(expected_rewards(params))
    for arm in (1, 5):
        sim = SimBackend(params, n=7)
        rows = drive_static(sim, arm, 4)
        obs = derive_obs(rows[3], rows[4], params.reward_scale)
        assert obs.reward.shape == (7,)
        np.testing.assert_allclose(
            np.asarray(obs.reward), np.full(7, exp_r[arm]), rtol=1e-4
        )


def test_sim_backend_interval_matches_env_constants():
    """SimBackend counter deltas reproduce the env's per-interval energy
    table exactly (switch-free steady state)."""
    params = noise_free_params()
    sim = SimBackend(params, n=1)
    rows = drive_static(sim, 3, 5)
    d_e = float(rows[4].energy_j[0] - rows[3].energy_j[0])
    np.testing.assert_allclose(
        d_e, float(params.e_interval_kj[3]) * 1e3, rtol=1e-5
    )


# ---------------------------------------------------------------------------
# the streaming controller: switched bit, fused dispatch, N=1 semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["geopm", "sim"])
def test_controller_switched_matches_backend_switch_count(kind):
    """The live loop's switched observations must sum to the backend's
    cumulative switch counter (regression: the legacy runtime reported
    switched=False unconditionally)."""
    backend = make_backend(MODEL, kind=kind)
    ctl = EnergyController(energy_ucb(), backend, seed=1)
    for _ in range(60):
        ctl.step()
    hist_switches = sum(int(np.sum(h["switched"])) for h in ctl.history)
    counted = int(np.sum(np.asarray(backend.read_counters().switches)))
    assert hist_switches == counted
    assert counted > 0, "a fresh UCB run must explore (and therefore switch)"


def test_controller_forced_switch_every_interval():
    """Alternating arms must flag switched on every post-warmup interval."""
    params = noise_free_params()
    sim = SimBackend(params, n=1)
    rows = [sim.read_counters()]
    for i in range(6):
        sim.apply_arms(np.asarray([i % 2], np.int32))
        sim.advance()
        rows.append(sim.read_counters())
    flags = [
        bool(np.asarray(derive_obs(rows[i], rows[i + 1], 1.0).switched)[0])
        for i in range(6)
    ]
    assert flags == [True] * 6


def test_fleet_controller_fused_dispatch_matches_vmapped():
    """The streaming path's fused Pallas fleet step (interpret mode) is
    bit-identical to the vmapped PolicyFns path, on a ragged fleet."""
    p = make_env_params(get_app("tealeaf"))
    n = 7
    fused = EnergyController(energy_ucb(), SimBackend(p, n=n, seed=5),
                             seed=2, interpret=True)
    assert fused.use_kernel, "N>1 kernel-exact policy must auto-dispatch"
    plain = EnergyController(energy_ucb(), SimBackend(p, n=n, seed=5),
                             seed=2, use_kernel=False)
    for _ in range(8):
        rf = fused.step()
        rv = plain.step()
        np.testing.assert_array_equal(rf["arm"], rv["arm"])
        np.testing.assert_allclose(rf["reward"], rv["reward"], rtol=1e-6)
    for leaf in fused.states:
        np.testing.assert_array_equal(
            np.asarray(fused.states[leaf]), np.asarray(plain.states[leaf]),
            err_msg=f"streaming fused path diverged on {leaf}",
        )


def test_controller_kernel_gating():
    """N=1 stays on the plain path; non-kernel-exact policies never
    dispatch the fused step even for N>1 — but every EnergyUCB variant
    now DOES: QoS-constrained (PR 3) and sliding-window/warm-up (PR 5)
    all ride kernel lanes."""
    from repro.core import energy_ts

    p = make_env_params(get_app("tealeaf"))
    assert not EnergyController(energy_ucb(), SimBackend(p, n=1),
                                interpret=True).use_kernel
    assert EnergyController(energy_ucb(qos_delta=0.05),
                            SimBackend(p, n=4), interpret=True).use_kernel
    assert EnergyController(energy_ucb(window_discount=0.99),
                            SimBackend(p, n=4), interpret=True).use_kernel
    assert EnergyController(energy_ucb(optimistic_init=False),
                            SimBackend(p, n=4), interpret=True).use_kernel
    assert not EnergyController(energy_ts(), SimBackend(p, n=4),
                                interpret=True).use_kernel


def test_fleet_controller_qos_fused_dispatch_matches_vmapped():
    """Constrained streaming fleets auto-dispatch the fused kernel and
    stay bit-identical to the vmapped PolicyFns path on a ragged N."""
    p = make_env_params(get_app("miniswp"))
    n = 5
    pol = energy_ucb(qos_delta=0.05)
    fused = EnergyController(pol, SimBackend(p, n=n, seed=5), seed=2,
                             interpret=True)
    assert fused.use_kernel, "constrained N>1 fleet must auto-dispatch"
    plain = EnergyController(pol, SimBackend(p, n=n, seed=5), seed=2,
                             use_kernel=False)
    for _ in range(25):
        rf = fused.step()
        rv = plain.step()
        np.testing.assert_array_equal(rf["arm"], rv["arm"])
        np.testing.assert_allclose(rf["reward"], rv["reward"], rtol=1e-6)
    for leaf in fused.states:
        np.testing.assert_array_equal(
            np.asarray(fused.states[leaf]), np.asarray(plain.states[leaf]),
            err_msg=f"constrained streaming fused path diverged on {leaf}",
        )


def test_controller_constrained_fleet_respects_budget():
    """Fig. 5b end to end through the streaming control plane: once the
    warm-up exploration has sampled every arm, a constrained fleet only
    actuates arms within the slowdown budget (true slowdown, from the
    calibrated t_rel ladder), while the unconstrained fleet keeps
    visiting over-budget arms on this memory-bound app."""
    p = make_env_params(get_app("miniswp"))
    true_slow = 1.0 - np.asarray(p.t_rel)[-1] / np.asarray(p.t_rel)
    delta = 0.05

    def post_warmup_slowdowns(policy):
        # seed chosen so the noisy progress estimates resolve the
        # borderline 0.059-slowdown arm correctly within the horizon
        # (feasibility works on estimates; a stale reference-arm sample
        # can admit a just-over-budget arm on unlucky noise draws)
        ctl = EnergyController(policy, SimBackend(p, n=4, seed=0), seed=2,
                               interpret=True)
        for _ in range(400):
            ctl.step()
        arms = np.stack([np.asarray(h["arm"]) for h in ctl.history])
        return true_slow[arms[50:]]

    con = post_warmup_slowdowns(energy_ucb(qos_delta=delta))
    unc = post_warmup_slowdowns(energy_ucb())
    assert (con <= delta + 1e-6).all(), (
        f"constrained fleet exceeded budget: max {con.max():.4f}")
    # the budget binds: unconstrained picks over-budget arms here
    assert (unc > delta + 1e-6).mean() > 0.05
    assert con.mean() < unc.mean()
    # strictest valid budget --qos 0.0 pins the fleet to ~f_max (small
    # tolerance: feasibility works on noisy progress estimates)
    z = post_warmup_slowdowns(energy_ucb(qos_delta=0.0))
    assert z.mean() <= 2e-3 and z.max() <= 0.01


def test_record_trace_broadcasts_1d_schedule_over_fleet():
    """Regression: a 1-D arm schedule used to hard-reshape to (T, 1) and
    crash SimBackend.apply_arms for N>1 fleets; it now means 'this arm
    for the whole fleet each interval'."""
    params = noise_free_params()
    trace = record_trace(SimBackend(params, n=3), np.array([2, 5, 2, 7]))
    assert trace.n_nodes == 3 and len(trace) == 4
    # all three nodes saw the same actuation each interval
    sw = np.asarray(trace.trace.switches)
    assert sw.shape == (5, 3)
    np.testing.assert_array_equal(sw[:, 0], sw[:, 1])


def test_sim_backend_heterogeneous_ladder_guard():
    """Stacked per-node EnvParams with DIFFERENT frequency ladders must
    raise from ladder_ghz instead of silently returning node 0's."""
    from repro.energy import stack_env_params

    p = noise_free_params()
    p_shift = p._replace(freqs=p.freqs + 0.1)
    hetero = SimBackend(stack_env_params([p, p_shift]))
    with pytest.raises(ValueError, match="heterogeneous"):
        hetero.ladder_ghz
    homo = SimBackend(stack_env_params([p, p]))
    np.testing.assert_allclose(homo.ladder_ghz, np.asarray(p.freqs))


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------


def test_trace_replay_reproduces_live_run(tmp_path):
    """Offline evaluation: a controller replaying a recorded counter log
    re-derives the live run's observations and (deterministic-policy)
    decisions exactly — through a save/load round trip."""
    params = noise_free_params()
    live = EnergyController(energy_ucb(), SimBackend(params, n=2, seed=9),
                            seed=4)
    for _ in range(12):
        live.step()
    schedule = np.stack([np.asarray(h["arm"]) for h in live.history])

    trace = record_trace(SimBackend(params, n=2, seed=9), schedule)
    path = str(tmp_path / "trace.npz")
    trace.save(path)
    replay = TraceReplayBackend.load(path)
    assert len(replay) == 12 and replay.n_nodes == 2

    offline = EnergyController(energy_ucb(), replay, seed=4)
    for _ in range(len(replay)):
        offline.step()
    for h_live, h_off in zip(live.history, offline.history):
        np.testing.assert_array_equal(h_live["arm"], h_off["arm"])
        np.testing.assert_allclose(h_live["reward"], h_off["reward"],
                                   rtol=1e-6)
        np.testing.assert_array_equal(h_live["switched"], h_off["switched"])
    # actuation requests were logged, not actuated
    assert len(replay.requested_arms) == 12
    with pytest.raises(RuntimeError, match="exhausted"):
        offline.step()


def test_summary_without_baseline_degrades_gracefully():
    """A bare trace (or a hardware backend with no declared baseline)
    still yields the counter-derived summary fields."""
    params = noise_free_params()
    src = record_trace(SimBackend(params, n=1), np.full((5, 1), 3))
    bare = TraceReplayBackend(src.trace, ladder_ghz=src.ladder_ghz,
                              interval_s=src.interval_s,
                              reward_scale=np.asarray(src.reward_scale))
    ctl = EnergyController(static_policy(3), bare)
    for _ in range(len(bare)):
        ctl.step()
    s = ctl.summary()
    assert s["steps"] == 5 and s["energy_j"] > 0
    assert "baseline_energy_j" not in s and "saved_energy_pct" not in s


def test_fleet_stream_without_history():
    """record_history=False keeps the streaming path free of per-interval
    host records while summary() still reads the counters."""
    p = make_env_params(get_app("tealeaf"))
    ctl = EnergyController(energy_ucb(), SimBackend(p, n=4),
                           record_history=False)
    for _ in range(6):
        out = ctl.step()
        assert set(out) == {"work"}
    assert ctl.history == []
    s = ctl.summary()
    assert s["steps"] == 6 and s["nodes"] == 4 and s["energy_j"] > 0


def test_record_trace_static_schedule_matches_expected():
    """Recorded GEOPM traces replay with the same reward landscape."""
    params = noise_free_params()
    exp_r = np.asarray(expected_rewards(params))
    trace = record_trace(SimulatedGEOPM(model=MODEL), np.full((8, 1), 2))
    assert trace.variable_interval
    ctl = EnergyController(static_policy(2), trace)
    for _ in range(len(trace)):
        ctl.step()
    np.testing.assert_allclose(
        [h["reward"] for h in ctl.history[2:]], exp_r[2], rtol=1e-4
    )


# ---------------------------------------------------------------------------
# legacy surface
# ---------------------------------------------------------------------------


def test_runtime_shim_removed():
    """The one-release EnergyAwareRuntime shim is gone: the module and
    the re-export no longer exist."""
    import repro.energy as en

    assert not hasattr(en, "EnergyAwareRuntime")
    with pytest.raises(ImportError):
        from repro.energy.runtime import EnergyAwareRuntime  # noqa: F401


def test_make_backend_factory():
    assert isinstance(make_backend(MODEL), SimulatedGEOPM)
    sim = make_backend(MODEL, kind="sim", n=3)
    assert isinstance(sim, SimBackend) and sim.n_nodes == 3
    with pytest.raises(ValueError):
        make_backend(MODEL, kind="geopm", n=2)
    with pytest.raises(ValueError):
        make_backend(MODEL, kind="nope")
