"""Launcher CLI -> policy wiring. Regression for the silent --qos 0.0
drop (both launchers used `if args.qos`, falsy for 0.0, discarding the
strictest valid slowdown budget), and for the nonstationary flags that
simply did not exist: --window-discount / --warmup now reach the policy
on every launcher (same `is not None` dispatch class of bug)."""
import numpy as np
import pytest

from repro.launch import fleet_serve, serve, train

ALL_LAUNCHERS = [serve, train, fleet_serve]
ALL_IDS = ["serve", "train", "fleet_serve"]


@pytest.mark.parametrize("mod", [serve, train], ids=["serve", "train"])
def test_qos_zero_reaches_policy_as_binding_constraint(mod):
    args = mod.parse_args(["--energy", "--qos", "0.0"])
    pol = mod.build_policy(args)
    # qos_delta == 0.0 (not the -1.0 'off' sentinel): the constraint binds
    assert float(pol.params.qos_delta) == 0.0
    assert "QoS" in pol.name
    # and a 0.0-budget policy is feasibility-restricted: with accurate
    # progress estimates it must refuse any arm slower than the reference
    import jax
    import jax.numpy as jnp

    state = pol.init(jax.random.key(0))
    k = state["mu"].shape[0]
    state = {
        **state,
        "mu": -jnp.linspace(0.1, 1.0, k),  # slowest arm looks best
        "n": jnp.full((k,), 5.0),
        "phat": jnp.linspace(1e-4, 2e-4, k),  # but IS 2x slower
        "pn": jnp.full((k,), 5.0),
        "t": jnp.float32(45.0),
    }
    arm = int(pol.select(state, jax.random.key(1)))
    assert arm == k - 1, f"qos=0.0 must pin to f_max, picked {arm}"


@pytest.mark.parametrize("mod", [serve, train], ids=["serve", "train"])
def test_qos_default_and_value(mod):
    assert mod.parse_args([]).qos is None
    assert float(mod.build_policy(mod.parse_args([])).params.qos_delta) < 0.0
    pol = mod.build_policy(mod.parse_args(["--qos", "0.05"]))
    np.testing.assert_allclose(float(pol.params.qos_delta), 0.05)


@pytest.mark.parametrize("mod", ALL_LAUNCHERS, ids=ALL_IDS)
def test_window_discount_reaches_policy(mod):
    """--window-discount must produce a sliding-window (gamma < 1)
    policy — the nonstationary variants simply were not launchable
    before. 0.0 is a valid (last-sample-only) window: `is not None`
    dispatch, never truthiness."""
    assert mod.parse_args([]).window_discount is None
    assert float(mod.build_policy(mod.parse_args([])).params.gamma) == 1.0
    pol = mod.build_policy(mod.parse_args(["--window-discount", "0.97"]))
    np.testing.assert_allclose(float(pol.params.gamma), 0.97)
    assert "SW" in pol.name
    zero = mod.build_policy(mod.parse_args(["--window-discount", "0.0"]))
    assert float(zero.params.gamma) == 0.0


@pytest.mark.parametrize("mod", ALL_LAUNCHERS, ids=ALL_IDS)
def test_warmup_flag_reaches_policy(mod):
    """--warmup selects the round-robin warm-up ablation (optimistic
    init off) on every launcher."""
    assert float(mod.build_policy(mod.parse_args([])).params.optimistic) == 1.0
    pol = mod.build_policy(mod.parse_args(["--warmup"]))
    assert float(pol.params.optimistic) == 0.0
    assert "noOptInit" in pol.name


@pytest.mark.parametrize("mod", ALL_LAUNCHERS, ids=ALL_IDS)
def test_nonstationary_policies_stay_kernel_exact(mod):
    """The launched nonstationary variants must dispatch the fused
    kernel — the silent fall-off-the-fast-path this PR fixes."""
    from repro.core.fleet import kernel_compatible

    args = mod.parse_args(["--window-discount", "0.95", "--warmup",
                           "--qos", "0.05"])
    assert kernel_compatible(mod.build_policy(args))


def test_fleet_serve_drift_flags_build_phase_schedule():
    """--drift wires a cycling phase schedule into the host's SimBackend
    stripe (and is refused for recorded-trace replay)."""
    args = fleet_serve.parse_args(
        ["--nodes", "6", "--app", "miniswp", "--drift", "tealeaf,lbm",
         "--drift-every", "50"])
    backend = fleet_serve.build_local_backend(args, 0, 3)
    assert backend.n_nodes == 3
    assert len(backend._phases) == 3 and backend._drift_every == 50
    assert backend.active_phase() == 0
    with pytest.raises(ValueError, match="drift_every"):
        fleet_serve.build_local_backend(
            fleet_serve.parse_args(["--drift", "tealeaf"]), 0, 2)
    with pytest.raises(ValueError, match="--trace"):
        fleet_serve.build_local_backend(
            fleet_serve.parse_args(["--trace", "x.npz", "--drift", "tealeaf",
                                    "--drift-every", "10"]), 0, 2)
