"""Launcher CLI -> policy wiring. Regression for the silent --qos 0.0
drop: both launchers used `if args.qos` (falsy for 0.0), discarding the
strictest valid slowdown budget a user can ask for."""
import numpy as np
import pytest

from repro.launch import serve, train


@pytest.mark.parametrize("mod", [serve, train], ids=["serve", "train"])
def test_qos_zero_reaches_policy_as_binding_constraint(mod):
    args = mod.parse_args(["--energy", "--qos", "0.0"])
    pol = mod.build_policy(args)
    # qos_delta == 0.0 (not the -1.0 'off' sentinel): the constraint binds
    assert float(pol.params.qos_delta) == 0.0
    assert "QoS" in pol.name
    # and a 0.0-budget policy is feasibility-restricted: with accurate
    # progress estimates it must refuse any arm slower than the reference
    import jax
    import jax.numpy as jnp

    state = pol.init(jax.random.key(0))
    k = state["mu"].shape[0]
    state = {
        **state,
        "mu": -jnp.linspace(0.1, 1.0, k),  # slowest arm looks best
        "n": jnp.full((k,), 5.0),
        "phat": jnp.linspace(1e-4, 2e-4, k),  # but IS 2x slower
        "pn": jnp.full((k,), 5.0),
        "t": jnp.float32(45.0),
    }
    arm = int(pol.select(state, jax.random.key(1)))
    assert arm == k - 1, f"qos=0.0 must pin to f_max, picked {arm}"


@pytest.mark.parametrize("mod", [serve, train], ids=["serve", "train"])
def test_qos_default_and_value(mod):
    assert mod.parse_args([]).qos is None
    assert float(mod.build_policy(mod.parse_args([])).params.qos_delta) < 0.0
    pol = mod.build_policy(mod.parse_args(["--qos", "0.05"]))
    np.testing.assert_allclose(float(pol.params.qos_delta), 0.05)
