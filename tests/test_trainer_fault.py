"""Fault tolerance: injected failures + restart-from-checkpoint must
reproduce the exact no-failure trajectory (bitwise-deterministic data +
full optimizer state in the checkpoint)."""
import shutil

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.train.trainer import Trainer, TrainerConfig, run_with_restarts

SH = ShapeConfig("tiny", 32, 8, "train")


def _mk(ckpt_dir, steps=30):
    cfg = get_reduced("qwen2.5-3b")
    bundle = build_model(cfg)
    return Trainer(
        bundle,
        SH,
        tcfg=TrainerConfig(
            total_steps=steps, ckpt_every=10, ckpt_dir=ckpt_dir,
            async_ckpt=False, log_every=steps,
        ),
    )


@pytest.mark.slow
def test_restart_matches_clean_run(tmp_path):
    clean_dir = str(tmp_path / "clean")
    crash_dir = str(tmp_path / "crash")

    clean = _mk(clean_dir)
    res_clean = clean.run()
    loss_clean = res_clean["metrics"][-1]["loss"]

    res_crash, restarts = run_with_restarts(
        lambda: _mk(crash_dir), fail_at_steps=[13, 27]
    )
    assert restarts == 2
    loss_crash = res_crash["metrics"][-1]["loss"]
    assert loss_clean == pytest.approx(loss_crash, rel=1e-5)


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    tr = _mk(str(tmp_path / "ck"), steps=60)
    res = tr.run()
    first = res["metrics"][0]["loss"]
    last = res["metrics"][-1]["loss"]
    assert last < first - 0.3, f"loss did not improve: {first} -> {last}"
