"""Per-arch REDUCED-config smoke tests: one forward/train step on CPU,
asserting shapes + finiteness; serving consistency for the transformer
family (prefill+decode matches a longer forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.configs.base import ShapeConfig
from repro.launch.input_specs import make_batch
from repro.models import build_model

SH = ShapeConfig("smoke", 32, 2, "train")


@pytest.mark.parametrize("name", list_archs())
def test_train_step_smoke(name):
    cfg = get_reduced(name)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(cfg, SH, kind="train")
    loss = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert 3.0 < float(loss) < 9.0  # ~ln(V) at init


@pytest.mark.parametrize("name", list_archs())
def test_prefill_decode_smoke(name):
    cfg = get_reduced(name)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    logits, cache = jax.jit(m.prefill)(params, make_batch(cfg, SH, kind="prefill"))
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    db = make_batch(cfg, SH, kind="decode")
    lg, cache1 = jax.jit(m.decode)(params, m.init_cache(2, 32), db)
    assert lg.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert jax.tree.structure(cache1) == jax.tree.structure(m.init_cache(2, 32))


@pytest.mark.parametrize("name", ["qwen3-1.7b", "starcoder2-15b", "mamba2-2.7b", "zamba2-7b"])
def test_decode_matches_forward(name):
    """Greedy next-token from (prefill -> decode) must match running
    prefill on the extended sequence (KV-cache correctness)."""
    cfg = get_reduced(name)
    m = build_model(cfg)
    params = m.init(jax.random.key(1))
    S = 16
    toks = jax.random.randint(jax.random.key(2), (2, S), 0, cfg.vocab_size, jnp.int32)
    lg_a, cache = jax.jit(m.prefill)(params, {"tokens": toks})
    nxt = jnp.argmax(lg_a[:, : cfg.vocab_size], -1).astype(jnp.int32)
    # path 1: decode one step from the cache
    if cfg.family == "ssm":
        cache_p = cache
    else:
        # pad cache to S+8 on the seq axis
        def pad(x):
            shape = list(x.shape)
            if S in shape:
                ax = shape.index(S)
                pads = [(0, 0)] * len(shape)
                pads[ax] = (0, 8)
                return jnp.pad(x, pads)
            return x

        if cfg.family == "hybrid":
            cache_p = {"ssm": cache["ssm"], "k": pad(cache["k"]), "v": pad(cache["v"])}
        else:
            cache_p = jax.tree.map(pad, cache)
    lg_b, _ = jax.jit(m.decode)(
        params, cache_p, {"token": nxt, "index": jnp.int32(S)}
    )
    # path 2: prefill on the extended sequence
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    lg_c, _ = jax.jit(m.prefill)(params, {"tokens": toks2})
    np.testing.assert_allclose(
        np.asarray(lg_b, np.float32), np.asarray(lg_c, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_models_api_imports_first():
    """Regression: `from repro.train import checkpoint` at distributed.py
    module scope closed an import cycle (models.api -> transformer ->
    parallel -> distributed -> train.train_step -> models.api), so any
    process whose FIRST repro import was models.api — e.g. `python -m
    repro.launch.dryrun` — died with a partially-initialized ImportError.
    The checkpoint import is deferred now; a fresh subprocess importing
    models.api first must succeed."""
    import subprocess
    import sys

    r = subprocess.run(
        [sys.executable, "-c",
         "import repro.models.api; import repro.parallel.distributed"],
        capture_output=True, text=True, timeout=120,
    )
    assert r.returncode == 0, r.stderr[-2000:]
