"""Factored (core x uncore) action-space parity suite.

Two anchors pin the product-ladder refactor (ISSUE 8):

- DEGENERACY: ``k_unc == 1`` IS the scalar ladder. The factored policy
  factory returns the scalar function-set singleton, so streaming and
  scanned episodes are bit-exact vs the pre-refactor scalar path — the
  refactor cannot have moved a single ulp for every existing config.
- PARITY: on real factored ladders (``k_unc > 1``) the fused Pallas
  step/episode kernels (interpret mode on CPU), the vmapped
  per-controller path, and the pure-jnp ``kernels.ref`` oracles agree
  bit for bit on ragged N with MIXED lanes — per-node QoS budgets,
  sliding windows, warm-up ablation, and mixed-sign ``lam_unc``
  (sentinel < 0 = one shared switching penalty, >= 0 = per-dimension
  split) all in one launch.

All oracles are jitted (same expressions, same compiler => bit
identity; the un-jitted oracle would differ by FMA-contraction ulps).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy_ucb, get_app, make_env_params
from repro.core.fleet import Fleet, kernel_compatible
from repro.core.policies import (
    UCB_FNS,
    ActionSpace,
    factored_energy_ucb,
    factored_ucb_fns,
    ucb_family_k_unc,
)
from repro.core.simulator import Obs, make_factored_env_params
from repro.energy import EnergyController, SimBackend
from repro.kernels import ops, ref
from repro.kernels.episode_scan import EnvRows, make_scan_env

SPACE = ActionSpace(3, 3)  # 9 flat arms: every (N, 9) helper reusable


# ---------------------------------------------------------------------------
# degeneracy: k_unc == 1 is bit-exactly the scalar ladder
# ---------------------------------------------------------------------------


def test_kunc1_is_the_scalar_family():
    """The degenerate factorization returns the scalar singletons, so
    jit sees the SAME function identities (one trace, zero new code on
    the scalar path) and kernel dispatch reads k_unc = 1."""
    assert factored_ucb_fns(9, 1) is UCB_FNS
    assert ucb_family_k_unc(UCB_FNS) == 1
    assert ucb_family_k_unc(factored_ucb_fns(3, 3)) == 3
    pol = factored_energy_ucb(ActionSpace(9, 1))
    assert pol.fns is UCB_FNS
    for got, want in zip(pol.params, energy_ucb().params):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert kernel_compatible(pol)
    assert kernel_compatible(factored_energy_ucb(SPACE))
    assert Fleet(factored_energy_ucb(SPACE), 4, interpret=True).k_unc == 3


def test_action_space_flat_split_roundtrip():
    space = ActionSpace(9, 3)
    assert space.k == 27
    core = np.arange(27) // 3
    unc = np.arange(27) % 3
    np.testing.assert_array_equal(np.asarray(space.flat(core, unc)),
                                  np.arange(27))
    c, u = space.split(jnp.arange(27))
    np.testing.assert_array_equal(np.asarray(c), core)
    np.testing.assert_array_equal(np.asarray(u), unc)
    # flat K-1 is the (f_max core, max uncore) corner — the default-arm
    # and QoS-reference convention everywhere
    assert int(space.flat(space.k_core - 1, space.k_unc - 1)) == space.k - 1


@pytest.mark.parametrize("scanned", [False, True])
def test_kunc1_controller_bit_exact_vs_scalar(scanned):
    """A k_unc == 1 factored controller reproduces the scalar
    controller's arms AND state bit for bit, streaming and as one
    scanned episode — on a nontrivial config (QoS budget + sliding
    window) so every kernel lane is exercised, not just defaults."""
    n, tt = 16, 9
    mk = lambda pol: EnergyController(
        pol, SimBackend(make_env_params(get_app("tealeaf")), n=n, seed=9),
        seed=2, record_history=False)
    scalar = mk(energy_ucb(qos_delta=0.05, window_discount=0.97))
    fact = mk(factored_energy_ucb(ActionSpace(9, 1), qos_delta=0.05,
                                  window_discount=0.97))
    if scanned:
        scalar.run_scanned(tt)
        fact.run_scanned(tt)
        np.testing.assert_array_equal(
            np.asarray(scalar.last_episode_arms),
            np.asarray(fact.last_episode_arms),
            err_msg="k_unc=1 scanned arm trace diverged from scalar")
    else:
        for i in range(tt):
            scalar.step()
            fact.step()
            np.testing.assert_array_equal(
                np.asarray(scalar.last_arms), np.asarray(fact.last_arms),
                err_msg=f"k_unc=1 streaming arms diverged at interval {i}")
    for nm in scalar.states:
        np.testing.assert_array_equal(
            np.asarray(scalar.states[nm]), np.asarray(fact.states[nm]),
            err_msg=f"k_unc=1 states[{nm}] diverged (scanned={scanned})")


# ---------------------------------------------------------------------------
# factored parity: fused vs vmapped vs ref oracle, mixed lanes, ragged N
# ---------------------------------------------------------------------------


def _synth_obs(n, key, frac_active=0.85):
    f = lambda i: jax.random.fold_in(key, i)
    return Obs(
        energy_j=jax.random.uniform(f(0), (n,), minval=10.0, maxval=30.0),
        uc=jax.random.uniform(f(1), (n,), minval=0.5, maxval=1.0),
        uu=jax.random.uniform(f(2), (n,), minval=0.1, maxval=0.5),
        progress=jax.random.uniform(f(3), (n,), minval=1e-4, maxval=2e-4),
        reward=-jax.random.uniform(f(4), (n,), minval=0.5, maxval=1.5),
        switched=jnp.zeros((n,), bool),
        active=jax.random.uniform(f(5), (n,)) < frac_active,
    )


def _factored_lanes(n, k, seed=0):
    """Per-controller lanes mixing every fused variant PLUS mixed-sign
    lam_unc: ~half the fleet on the shared-penalty sentinel (< 0), the
    rest on a spread of per-dimension uncore penalties."""
    key = jax.random.key(6000 + seed)
    f = lambda i: jax.random.fold_in(key, i)
    qos = jnp.where(jax.random.uniform(f(1), (n,)) < 0.5,
                    jax.random.uniform(f(2), (n,), maxval=0.15), -1.0)
    gamma = jnp.where(jax.random.uniform(f(3), (n,)) < 0.5,
                      jax.random.uniform(f(4), (n,), maxval=0.999), 1.0)
    lam_unc = jnp.where(jnp.arange(n) % 2 == 0,
                        jax.random.uniform(f(5), (n,), maxval=0.05), -1.0)
    return dict(
        alpha=jax.random.uniform(f(6), (n,), minval=0.05, maxval=0.3),
        lam=jax.random.uniform(f(7), (n,), minval=0.0, maxval=0.05),
        qos=qos,
        da=jax.random.randint(f(8), (n,), 0, k),
        gamma=gamma,
        optimistic=jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0),
        prior=jax.random.normal(f(9), (n, k)) * 0.1,
        lam_unc=lam_unc,
    )


def _fleet_state(n, k, seed=0):
    key = jax.random.key(seed)
    f = lambda i: jax.random.fold_in(key, i)
    return dict(
        mu=jax.random.normal(f(1), (n, k)) * -1.0,
        n=jax.random.randint(f(2), (n, k), 1, 40).astype(jnp.float32),
        phat=jax.random.uniform(f(3), (n, k), minval=1e-4, maxval=2e-4),
        pn=jax.random.randint(f(4), (n, k), 0, 40).astype(jnp.float32),
        prev=jax.random.randint(f(5), (n,), 0, k),
        t=jax.random.randint(f(6), (n,), 1, 200).astype(jnp.float32),
        arm=jax.random.randint(f(7), (n,), 0, k),
    )


def _factored_policy(n, seed=0):
    la = _factored_lanes(n, SPACE.k, seed)
    base = factored_energy_ucb(SPACE)
    return base.with_params(base.params._replace(
        alpha=la["alpha"], lam=la["lam"], qos_delta=la["qos"],
        default_arm=la["da"], gamma=la["gamma"],
        optimistic=la["optimistic"], prior_mu=la["prior"],
        lam_unc=la["lam_unc"],
    )), la


_STATE7 = ("mu", "n", "phat", "pn", "prev", "t", "next_arm")


def _assert_state_equal(got, want, names, msg):
    for nm, g, w in zip(names, got, want):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w), err_msg=f"{msg} {nm}")


# 7 = sub-stripe, 1024 = one stripe, 2049 = ragged pad-and-slice
@pytest.mark.parametrize("n", [7, 1024, 2049])
def test_factored_fleet_step_fused_matches_vmapped(n):
    """A factored fleet mixing every lane (QoS / sliding-window /
    warm-up / mixed-sign lam_unc) dispatches ONE fused launch and stays
    bit-identical to the vmapped per-controller path over several
    desynchronizing intervals — the tentpole's one-trace invariant."""
    pol, _ = _factored_policy(n, seed=n)
    fused = Fleet(pol, n, interpret=True)
    assert fused.use_kernel and fused.k_unc == SPACE.k_unc
    vmapped = Fleet(pol, n, use_kernel=False)
    s_k = s_v = vmapped.init(jax.random.key(0))
    a_k = a_v = vmapped.select(s_v, jax.random.key(1))
    for i in range(4):
        obs = _synth_obs(n, jax.random.key(90 + i))
        s_k, a_k = fused.step(s_k, a_k, obs)
        s_v, a_v = vmapped.step(s_v, a_v, obs, jax.random.key(95 + i))
        np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_v),
                                      err_msg=f"arms diverged at step {i}")
        for leaf in s_k:
            np.testing.assert_array_equal(
                np.asarray(s_k[leaf]), np.asarray(s_v[leaf]),
                err_msg=f"factored fused step diverged on {leaf} "
                        f"(n={n}, step {i})")


@pytest.mark.parametrize("n", [7, 1024, 2049])
def test_factored_fleet_step_matches_ref_oracle(n):
    """ops.fleet_step with static k_unc = 3 vs the pure-jnp
    ref_fleet_step oracle: per-dimension UCB bonuses over marginal
    counts and split switching penalties, bit for bit."""
    s = _fleet_state(n, SPACE.k, seed=n)
    la = _factored_lanes(n, SPACE.k, seed=n)
    obs = _synth_obs(n, jax.random.key(n))
    got = ops.fleet_step(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        obs.reward, obs.progress, obs.active.astype(jnp.float32),
        la["alpha"], la["lam"], la["qos"], la["da"], la["gamma"],
        la["optimistic"], la["prior"], la["lam_unc"],
        k_unc=SPACE.k_unc, interpret=True,
    )
    rfn = jax.jit(ref.ref_fleet_step, static_argnames=("k_unc",))
    want = rfn(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        obs.reward, obs.progress, obs.active.astype(jnp.float32),
        la["alpha"], la["lam"], qos=la["qos"], default_arm=la["da"],
        gamma=la["gamma"], optimistic=la["optimistic"],
        prior_mu=la["prior"], lam_unc=la["lam_unc"], k_unc=SPACE.k_unc,
    )
    _assert_state_equal(got, want, _STATE7, f"factored step n={n}")


def test_factored_shared_sentinel_matches_scalar_penalty_math():
    """lam_unc < 0 on a factored ladder charges ONE shared penalty on
    any move — the select scores coincide with running the scalar
    (k_unc=1) penalty math over the same flat ladder, so pre-refactor
    traces replayed on factored fleets price switches unchanged."""
    n = 33
    s = _fleet_state(n, SPACE.k, seed=5)
    a_fact = ops.fleet_select(s["mu"], s["n"], s["prev"], s["t"],
                              alpha=0.2, lam=0.04, lam_unc=-1.0,
                              k_unc=SPACE.k_unc, interpret=True)
    a_scal = ops.fleet_select(s["mu"], s["n"], s["prev"], s["t"],
                              alpha=0.2, lam=0.04, interpret=True)
    # the shared penalty is identical; only the UCB bonus differs
    # (marginal vs joint counts), so force fully-pulled counts where
    # both bonus forms are monotone-identical in rank is NOT guaranteed
    # — compare against the ref oracle instead of the scalar kernel
    want = ref.ref_fleet_select(s["mu"], s["n"], s["prev"], s["t"],
                                alpha=0.2, lam=0.04, lam_unc=-1.0,
                                k_unc=SPACE.k_unc)
    np.testing.assert_array_equal(np.asarray(a_fact), np.asarray(want))
    assert a_scal.shape == a_fact.shape  # same flat ladder either way


# ragged N x ragged T, trace-fed
@pytest.mark.parametrize("n,tt", [(7, 13), (1024, 6), (2049, 9)])
def test_factored_trace_scan_matches_ref_and_repeated_steps(n, tt):
    """The factored episode megakernel (trace-fed, interpret mode) is
    bit-exact vs BOTH the jitted lax.scan oracle and T repeated fused
    fleet_step launches — the scan adds no math at k_unc > 1."""
    s = _fleet_state(n, SPACE.k, seed=n + tt)
    la = _factored_lanes(n, SPACE.k, seed=n)
    key = jax.random.key(7000 + n)
    f = lambda i: jax.random.fold_in(key, i)
    reward = -jax.random.uniform(f(1), (tt, n), minval=0.5, maxval=1.5)
    progress = jax.random.uniform(f(2), (tt, n), minval=1e-4, maxval=2e-4)
    active = (jax.random.uniform(f(3), (tt, n)) < 0.85).astype(jnp.float32)
    got, arms = ops.episode_scan_trace(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        reward, progress, active, la["alpha"], la["lam"], la["qos"],
        la["da"], la["gamma"], la["optimistic"], la["prior"],
        la["lam_unc"], k_unc=SPACE.k_unc, interpret=True,
    )
    rfn = jax.jit(ref.ref_episode_scan, static_argnames=("k_unc",))
    want, warms = rfn(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        reward, progress, active, la["alpha"], la["lam"], qos=la["qos"],
        default_arm=la["da"], gamma=la["gamma"],
        optimistic=la["optimistic"], prior_mu=la["prior"],
        lam_unc=la["lam_unc"], k_unc=SPACE.k_unc,
    )
    _assert_state_equal(got, want, _STATE7, f"factored trace scan n={n}")
    np.testing.assert_array_equal(np.asarray(arms), np.asarray(warms))
    # one scanned launch == T repeated fused steps
    cur = (s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"])
    for t in range(tt):
        cur = ops.fleet_step(
            *cur, reward[t], progress[t], active[t],
            la["alpha"], la["lam"], la["qos"], la["da"], la["gamma"],
            la["optimistic"], la["prior"], la["lam_unc"],
            k_unc=SPACE.k_unc, interpret=True,
        )
    _assert_state_equal(got, cur, _STATE7,
                        f"factored scan vs repeated steps n={n}")


def test_factored_xla_fallback_matches_ref():
    """The interpret=False CPU route (the XLA lax.scan fallback this
    container's production path hits) runs the factored math too, bit-
    exact vs the oracle. The fallback DONATES state — oracle first,
    inputs rebuilt for the fallback call."""
    n, tt = 161, 11
    la = _factored_lanes(n, SPACE.k, seed=3)
    key = jax.random.key(8000)
    f = lambda i: jax.random.fold_in(key, i)
    reward = -jax.random.uniform(f(1), (tt, n), minval=0.5, maxval=1.5)
    progress = jax.random.uniform(f(2), (tt, n), minval=1e-4, maxval=2e-4)
    active = (jax.random.uniform(f(3), (tt, n)) < 0.85).astype(jnp.float32)
    rfn = jax.jit(ref.ref_episode_scan, static_argnames=("k_unc",))
    s = _fleet_state(n, SPACE.k, seed=3)
    want, warms = rfn(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        reward, progress, active, la["alpha"], la["lam"], qos=la["qos"],
        default_arm=la["da"], gamma=la["gamma"],
        optimistic=la["optimistic"], prior_mu=la["prior"],
        lam_unc=la["lam_unc"], k_unc=SPACE.k_unc,
    )
    s = _fleet_state(n, SPACE.k, seed=3)  # fresh: fallback donates
    got, arms = ops.episode_scan_trace(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        reward, progress, active, la["alpha"], la["lam"], la["qos"],
        la["da"], la["gamma"], la["optimistic"], la["prior"],
        la["lam_unc"], k_unc=SPACE.k_unc, interpret=False,
    )
    _assert_state_equal(got, want, _STATE7, "factored xla fallback")
    np.testing.assert_array_equal(np.asarray(arms), np.asarray(warms))


# sim-fused over a factored environment (K = 9 core x 3 unc = 27 flat
# arms), drift-phase boundary crossed mid-scan
@pytest.mark.parametrize("n,tt", [(7, 12), (1024, 6), (2049, 8)])
def test_factored_sim_scan_matches_ref(n, tt):
    k_unc = 3
    phases = [make_factored_env_params(get_app(a))
              for a in ("tealeaf", "lbm")]
    k = len(phases[0].freqs)
    assert k == 27 and k % k_unc == 0
    s = _fleet_state(n, k, seed=n)
    la = _factored_lanes(n, k, seed=n + 1)
    key = jax.random.key(9000 + n)
    f = lambda i: jax.random.fold_in(key, i)
    rem = jax.random.uniform(f(1), (n,), minval=0.0, maxval=1.0)
    rem = rem.at[:: max(n // 7, 1)].set(0.0)
    env = EnvRows(
        remaining=rem,
        prev_arm=jax.random.randint(f(2), (n,), 0, k),
        t=jax.random.randint(f(3), (n,), 0, 300),
        energy_kj=jax.random.uniform(f(4), (n,), maxval=5.0),
        time_s=jax.random.uniform(f(5), (n,), maxval=30.0),
        switches=jax.random.randint(f(6), (n,), 0, 40),
        core_s=jax.random.uniform(f(7), (n,), maxval=20.0),
        uncore_s=jax.random.uniform(f(8), (n,), maxval=20.0),
    )
    z = tuple(jax.random.normal(f(10 + i), (tt, n)) for i in range(4))
    senv = make_scan_env(phases)
    got, genv, arms = ops.episode_scan_sim(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        env, z, senv, la["alpha"], la["lam"], la["qos"], la["da"],
        la["gamma"], la["optimistic"], la["prior"], la["lam_unc"],
        k_unc=k_unc, t_start=3, drift_every=5, interpret=True,
    )
    rfn = jax.jit(ref.ref_episode_scan_sim,
                  static_argnames=("t_start", "drift_every", "counter_obs",
                                   "k_unc"))
    want, wenv, warms = rfn(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        env, z, senv, la["alpha"], la["lam"], qos=la["qos"],
        default_arm=la["da"], gamma=la["gamma"],
        optimistic=la["optimistic"], prior_mu=la["prior"],
        lam_unc=la["lam_unc"], t_start=3, drift_every=5, k_unc=k_unc,
    )
    msg = f"factored sim scan n={n} T={tt}"
    _assert_state_equal(got, want, _STATE7, msg)
    _assert_state_equal(genv, wenv, EnvRows._fields, msg + " env")
    np.testing.assert_array_equal(np.asarray(arms), np.asarray(warms))


def test_factored_controller_streaming_matches_scanned():
    """End to end over the calibrated factored environment: the live
    factored EnergyController streaming loop and run_scanned agree
    arm-for-arm and on integer/count state (the invariant the scalar
    suite pins, now at k_unc = 3)."""
    n, tt = 16, 9
    p = make_factored_env_params(get_app("tealeaf"))
    space = ActionSpace(9, 3)
    mk = lambda: EnergyController(
        factored_energy_ucb(space, uncore_penalty=0.01, qos_delta=0.08),
        SimBackend(p, n=n, seed=4), seed=6, record_history=False)
    live, scan = mk(), mk()
    arms_live = []
    for _ in range(tt):
        live.step()
        arms_live.append(np.asarray(live.last_arms))
    scan.run_scanned(tt)
    np.testing.assert_array_equal(
        np.stack(arms_live), np.asarray(scan.last_episode_arms),
        err_msg="factored scanned arm trace diverged from streaming")
    for nm in ("n", "pn", "prev", "t"):
        np.testing.assert_array_equal(
            np.asarray(live.states[nm]), np.asarray(scan.states[nm]),
            err_msg=f"factored states[{nm}]")
    for nm in ("mu", "phat"):
        np.testing.assert_allclose(
            np.asarray(live.states[nm]), np.asarray(scan.states[nm]),
            rtol=1e-5, atol=1e-6, err_msg=f"factored states[{nm}]")
