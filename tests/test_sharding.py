import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    DEFAULT_RULES,
    FSDP_RULES,
    Sharder,
    spec_for_axes,
)


def test_spec_basic():
    sp = spec_for_axes(("batch", None, "heads"), DEFAULT_RULES, ("data", "model"))
    assert sp == P("data", None, "model")


def test_spec_multipod_batch():
    sp = spec_for_axes(("batch", None), DEFAULT_RULES, ("pod", "data", "model"))
    assert sp == P(("pod", "data"))


def test_spec_dedup_axis():
    # seq and heads both want "model": first wins, second degrades
    sp = spec_for_axes(("seq", "heads"), DEFAULT_RULES, ("data", "model"))
    assert sp == P("model")


def test_fsdp_profile_spans_pod():
    sp = spec_for_axes(("batch",), FSDP_RULES, ("data", "model"))
    assert sp == P(("data", "model"))
    sp = spec_for_axes(("heads",), FSDP_RULES, ("data", "model"))
    assert sp == P()


def test_trailing_nones_trimmed():
    sp = spec_for_axes(("batch", None, None), DEFAULT_RULES, ("data", "model"))
    assert sp == P("data")


def test_sharder_noop_without_mesh():
    s = Sharder(None)
    import jax.numpy as jnp

    x = jnp.zeros((4, 4))
    assert s.act(x, "batch", None) is x


def test_fit_spec_to_shape_degrades():
    import numpy as np
    from jax.sharding import Mesh

    devs = np.asarray(jax.devices() * 1)[:1]
    # fake 1-device mesh: every axis has size 1, so everything divides;
    # exercise the arithmetic directly instead
    s = Sharder.__new__(Sharder)
    s.mesh = type(
        "M", (), {"axis_names": ("data", "model"), "devices": np.zeros((16, 16))}
    )()
    s.rules = dict(DEFAULT_RULES)
    fitted = s._fit_spec_to_shape(P("data", "model"), (8, 64))
    assert fitted == P(None, "model")  # 8 % 16 != 0 -> dropped
    fitted = s._fit_spec_to_shape(P(("data", "model")), (64,))
    assert fitted == P("data")  # 64 % 16 ok, 64 % 256 not
    fitted = s._fit_spec_to_shape(P("data"), (32,))
    assert fitted == P("data")


def test_rank_mismatch_raises():
    s = Sharder(None)
    import jax.numpy as jnp

    # no mesh => no-op even on mismatch? No: act() checks only with mesh.
    x = jnp.zeros((2, 2))
    assert s.act(x, "batch", None) is x


# ---------------------------------------------------------------------------
# sharded fleet step: (N, K) controller state over the mesh's data axis
# ---------------------------------------------------------------------------


def _fleet_step_args(n, k=9, seed=0):
    """Full mixed-lane argument set: per-node alpha/lam, mixed QoS
    budgets, sliding-window gamma lanes (half the fleet), warm-up
    optimistic lanes (a third), and a nonzero prior."""
    import jax.numpy as jnp

    key = jax.random.key(seed)
    f = lambda i: jax.random.fold_in(key, i)
    return (
        jax.random.normal(f(1), (n, k)) * -1.0,
        jax.random.randint(f(2), (n, k), 1, 40).astype(jnp.float32),
        jax.random.uniform(f(3), (n, k), minval=1e-4, maxval=2e-4),
        jax.random.randint(f(4), (n, k), 0, 40).astype(jnp.float32),
        jax.random.randint(f(5), (n,), 0, k),
        jax.random.randint(f(6), (n,), 1, 200).astype(jnp.float32),
        jax.random.randint(f(7), (n,), 0, k),
        -jax.random.uniform(f(8), (n,), minval=0.5, maxval=1.5),
        jax.random.uniform(f(9), (n,), minval=1e-4, maxval=2e-4),
        (jax.random.uniform(f(10), (n,)) < 0.8).astype(jnp.float32),
        jax.random.uniform(f(11), (n,), minval=0.05, maxval=0.3),
        jax.random.uniform(f(12), (n,), minval=0.0, maxval=0.05),
        jnp.where(jnp.arange(n) % 2 == 0, 0.05, -1.0).astype(jnp.float32),
        jnp.full((n,), k - 1, jnp.int32),
        jnp.where(jnp.arange(n) % 2 == 0,
                  jax.random.uniform(f(13), (n,), minval=0.5, maxval=0.999),
                  1.0).astype(jnp.float32),
        jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0).astype(jnp.float32),
        jax.random.normal(f(14), (n, k)) * 0.1,
    )


@pytest.mark.parametrize("n", [7, 256])
def test_sharded_fleet_step_matches_single_device(n):
    """shard_map'ed fleet step == the plain fused kernel, bit for bit,
    on the host mesh (pure row parallelism, ragged N padded)."""
    import numpy as np

    from repro.kernels import ops
    from repro.parallel import fleet_mesh, make_sharded_fleet_step

    args = _fleet_step_args(n, seed=n)
    step = make_sharded_fleet_step(fleet_mesh(), interpret=True)
    got = step(*args)
    want = ops.fleet_step(*args, interpret=True)
    for nm, g, w in zip(("mu", "n", "phat", "pn", "prev", "t", "next"),
                        got, want):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w),
            err_msg=f"sharded fleet step {nm} (n={n})")


@pytest.mark.slow
def test_sharded_fleet_step_multi_device_parity():
    """Same parity on a real 8-way data mesh (forced host devices in a
    subprocess so the fake device count never leaks into this run),
    with a ragged N and mixed QoS + sliding-window/warm-up lanes — the
    Aurora-scale config."""
    prog = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        assert jax.device_count() == 8, jax.device_count()
        from repro.kernels import ops
        from repro.parallel import fleet_mesh, make_sharded_fleet_step
        n, k = 2049, 9
        key = jax.random.key(7)
        f = lambda i: jax.random.fold_in(key, i)
        args = (
            jax.random.normal(f(1), (n, k)) * -1.0,
            jax.random.randint(f(2), (n, k), 1, 40).astype(jnp.float32),
            jax.random.uniform(f(3), (n, k), minval=1e-4, maxval=2e-4),
            jax.random.randint(f(4), (n, k), 0, 40).astype(jnp.float32),
            jax.random.randint(f(5), (n,), 0, k),
            jax.random.randint(f(6), (n,), 1, 200).astype(jnp.float32),
            jax.random.randint(f(7), (n,), 0, k),
            -jax.random.uniform(f(8), (n,), minval=0.5, maxval=1.5),
            jax.random.uniform(f(9), (n,), minval=1e-4, maxval=2e-4),
            (jax.random.uniform(f(10), (n,)) < 0.8).astype(jnp.float32),
            jnp.float32(0.1), jnp.float32(0.02),
            jnp.where(jnp.arange(n) % 2 == 0, 0.05, -1.0),
            jnp.full((n,), k - 1, jnp.int32),
            jnp.where(jnp.arange(n) % 2 == 0, 0.95, 1.0),
            jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0),
            jax.random.normal(f(11), (n, k)) * 0.1,
        )
        mesh = fleet_mesh()
        assert mesh.shape["data"] == 8
        got = make_sharded_fleet_step(mesh, interpret=True)(*args)
        want = ops.fleet_step(*args, interpret=True)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        print("OK")
    """)
    import os

    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
