import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    DEFAULT_RULES,
    FSDP_RULES,
    Sharder,
    spec_for_axes,
)


def test_spec_basic():
    sp = spec_for_axes(("batch", None, "heads"), DEFAULT_RULES, ("data", "model"))
    assert sp == P("data", None, "model")


def test_spec_multipod_batch():
    sp = spec_for_axes(("batch", None), DEFAULT_RULES, ("pod", "data", "model"))
    assert sp == P(("pod", "data"))


def test_spec_dedup_axis():
    # seq and heads both want "model": first wins, second degrades
    sp = spec_for_axes(("seq", "heads"), DEFAULT_RULES, ("data", "model"))
    assert sp == P("model")


def test_fsdp_profile_spans_pod():
    sp = spec_for_axes(("batch",), FSDP_RULES, ("data", "model"))
    assert sp == P(("data", "model"))
    sp = spec_for_axes(("heads",), FSDP_RULES, ("data", "model"))
    assert sp == P()


def test_trailing_nones_trimmed():
    sp = spec_for_axes(("batch", None, None), DEFAULT_RULES, ("data", "model"))
    assert sp == P("data")


def test_sharder_noop_without_mesh():
    s = Sharder(None)
    import jax.numpy as jnp

    x = jnp.zeros((4, 4))
    assert s.act(x, "batch", None) is x


def test_fit_spec_to_shape_degrades():
    import numpy as np
    from jax.sharding import Mesh

    devs = np.asarray(jax.devices() * 1)[:1]
    # fake 1-device mesh: every axis has size 1, so everything divides;
    # exercise the arithmetic directly instead
    s = Sharder.__new__(Sharder)
    s.mesh = type(
        "M", (), {"axis_names": ("data", "model"), "devices": np.zeros((16, 16))}
    )()
    s.rules = dict(DEFAULT_RULES)
    fitted = s._fit_spec_to_shape(P("data", "model"), (8, 64))
    assert fitted == P(None, "model")  # 8 % 16 != 0 -> dropped
    fitted = s._fit_spec_to_shape(P(("data", "model")), (64,))
    assert fitted == P("data")  # 64 % 16 ok, 64 % 256 not
    fitted = s._fit_spec_to_shape(P("data"), (32,))
    assert fitted == P("data")


def test_rank_mismatch_raises():
    s = Sharder(None)
    import jax.numpy as jnp

    # no mesh => no-op even on mismatch? No: act() checks only with mesh.
    x = jnp.zeros((2, 2))
    assert s.act(x, "batch", None) is x
