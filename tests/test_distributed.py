"""Multi-process fleet control plane: per-host backend stripes + striped
controller state must be BIT-identical to one process owning the whole
fleet (the single-process sharded step is the correctness oracle), with
zero per-interval collectives and fleet aggregates that match what the
single process would report."""
import os
import subprocess
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import get_app, make_env_params
from repro.core.fleet import slice_policy_lanes
from repro.core.policies import energy_ucb, make_policy_params
from repro.energy import (
    EnergyController,
    SimBackend,
    TraceReplayBackend,
    record_trace,
    reduce_summaries,
    slice_counters,
    stack_env_params,
)
from repro.parallel.distributed import (
    ClientComm,
    CoordinatorComm,
    DistributedFleetController,
    NullComm,
    connect_fleet,
    parse_address,
)
from repro.parallel.fleet import host_stripe, stripe_bounds

REPO = Path(__file__).resolve().parent.parent


def _subproc_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    return env


def _run_controller(ctl, t):
    arms = []
    for _ in range(t):
        ctl.step()
        arms.append(np.asarray(ctl.last_arms).reshape(-1))
    return np.stack(arms)


# ---------------------------------------------------------------------------
# stripe assignment
# ---------------------------------------------------------------------------


def test_stripe_bounds_cover_and_balance():
    for n, h in [(10, 2), (7, 3), (63_720, 6), (5, 5), (8, 1)]:
        bounds = stripe_bounds(n, h)
        assert bounds[0][0] == 0 and bounds[-1][1] == n
        widths = [hi - lo for lo, hi in bounds]
        assert sum(widths) == n and max(widths) - min(widths) <= 1
        for (_, a), (b, _) in zip(bounds, bounds[1:]):
            assert a == b  # contiguous, disjoint
    assert host_stripe(10, 2, 1) == (5, 10)
    with pytest.raises(ValueError):
        stripe_bounds(4, 5)
    with pytest.raises(ValueError):
        host_stripe(4, 2, 2)


def test_slice_policy_lanes():
    n, k = 6, 9
    pol = energy_ucb().with_params(make_policy_params(k=k)._replace(
        alpha=jnp.linspace(0.05, 0.3, n),
        qos_delta=jnp.where(jnp.arange(n) % 2 == 0, 0.05, -1.0),
        gamma=jnp.where(jnp.arange(n) % 2 == 0, 0.95, 1.0),
        optimistic=jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0),
    ))
    sub = slice_policy_lanes(pol, 2, 5, n)
    for lane in ("alpha", "qos_delta", "gamma", "optimistic"):
        np.testing.assert_allclose(
            np.asarray(getattr(sub.params, lane)),
            np.asarray(getattr(pol.params, lane))[2:5],
            err_msg=f"lane {lane}")
    # scalar lanes and the (K,) prior pass through untouched
    assert np.ndim(sub.params.lam) == 0
    assert sub.params.prior_mu.shape == (k,)


# ---------------------------------------------------------------------------
# backend sharding protocol
# ---------------------------------------------------------------------------


def test_sim_backend_local_slice_bit_parity():
    """A stripe backend advanced in lockstep reproduces the full-fleet
    backend's counter rows [lo:hi) bit for bit — noise included (the
    per-node streams are keyed by global node id, not local row)."""
    p = make_env_params(get_app("miniswp"))
    n, t = 7, 9
    full = SimBackend(p, n=n, seed=4)
    slices = [full.local_slice(lo, hi) for lo, hi in stripe_bounds(n, 3)]
    rng = np.random.default_rng(0)
    for _ in range(t):
        arms = rng.integers(0, 9, size=n).astype(np.int32)
        full.apply_arms(arms)
        full.advance()
        for (lo, hi), b in zip(stripe_bounds(n, 3), slices):
            b.apply_arms(arms[lo:hi])
            b.advance()
    want = full.read_counters()
    for (lo, hi), b in zip(stripe_bounds(n, 3), slices):
        got = b.read_counters()
        for f, g, w in zip(got._fields, got, slice_counters(want, lo, hi)):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w),
                err_msg=f"stripe [{lo},{hi}) counter {f}")


def test_sim_backend_local_slice_stacked_params():
    """Heterogeneous fleets: stacked per-node EnvParams slice rowwise,
    so each host sees exactly its nodes' apps (and reward scales)."""
    pa = make_env_params(get_app("tealeaf"))
    pb = make_env_params(get_app("miniswp"))
    full = SimBackend(stack_env_params([pa, pa, pb, pb]), seed=1)
    right = full.local_slice(2, 4)
    assert right.n_nodes == 2
    np.testing.assert_allclose(np.asarray(right.params.reward_scale),
                               np.asarray(pb.reward_scale)[None].repeat(2))
    full.advance()
    right.advance()
    got = right.read_counters()
    want = slice_counters(full.read_counters(), 2, 4)
    np.testing.assert_array_equal(np.asarray(got.energy_j),
                                  np.asarray(want.energy_j))


def test_local_slice_bounds_checked():
    p = make_env_params(get_app("tealeaf"))
    sim = SimBackend(p, n=4)
    with pytest.raises(ValueError):
        sim.local_slice(2, 5)
    trace = record_trace(SimBackend(p, n=3), np.array([1, 2]))
    with pytest.raises(ValueError):
        trace.local_slice(3, 4)


# ---------------------------------------------------------------------------
# the socket coordinator
# ---------------------------------------------------------------------------


def _free_port():
    import socket

    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def test_comm_allgather_rounds():
    """Host 0 + two client threads run tagged gather rounds; every host
    sees the same host-ordered payload list, and H=1 degenerates. The
    coordinator's constructor blocks until the whole fleet checks in,
    so the clients start first and retry-connect."""
    port = _free_port()
    results = {}

    def client(h):
        with ClientComm(("127.0.0.1", port), 3, h) as c:
            results[h] = [c.allgather({"h": h, "r": r}, f"round-{r}")
                          for r in range(3)]

    threads = [threading.Thread(target=client, args=(h,)) for h in (1, 2)]
    for th in threads:
        th.start()
    with CoordinatorComm(("127.0.0.1", port), 3) as coord:
        results[0] = [coord.allgather({"h": 0, "r": r}, f"round-{r}")
                      for r in range(3)]
    for th in threads:
        th.join(timeout=30)
    for h in range(3):
        for r in range(3):
            assert [d["h"] for d in results[h][r]] == [0, 1, 2]
            assert all(d["r"] == r for d in results[h][r])
    assert NullComm().allgather("x", "t") == ["x"]
    assert connect_fleet(1, 0).num_hosts == 1
    assert parse_address("10.0.0.1:7733") == ("10.0.0.1", 7733)
    assert parse_address("7733") == ("127.0.0.1", 7733)


def test_coordinator_rendezvous_times_out():
    """A peer that never connects fails the rendezvous fast with a
    diagnostic instead of hanging host 0 until the CI job timeout."""
    with pytest.raises(TimeoutError, match="1/2 hosts"):
        CoordinatorComm(("127.0.0.1", _free_port()), 2, timeout_s=0.5)


def test_drain_stashes_under_lock():
    """Regression (found by repro-lint RPL005): _drain used to stash
    off-tag strict payloads WITHOUT holding comm._lock, racing the
    acceptor thread's _admit — a rejoining host's `_stash.pop(peer)`
    could interleave with the setdefault and orphan the inner dict,
    silently dropping a barrier payload. The stash write must happen
    while the lock is held."""
    with CoordinatorComm(("127.0.0.1", 0), 1) as comm:  # H=1: no peers

        class LockAssertingStash(dict):
            def setdefault(self, *a, **kw):
                assert comm._lock.locked(), \
                    "_drain wrote the stash without holding comm._lock"
                return dict.setdefault(self, *a, **kw)

        comm._stash = LockAssertingStash()

        class FakeConn:
            """One queued off-tag strict payload from host 3."""
            def __init__(self):
                self.queued = [(3, "tag-b", "payload", True)]

            def recv(self):
                return self.queued.pop(0)

            def poll(self, _timeout=0):
                return bool(self.queued)

        got = comm._drain(3, FakeConn(), "tag-a", strict=True)
        assert got is None  # off-tag payload is stashed, not returned
        assert dict(comm._stash) == {3: {"tag-b": "payload"}}


# ---------------------------------------------------------------------------
# striped controllers: in-process parity + aggregates
# ---------------------------------------------------------------------------


def test_striped_controllers_match_single_process():
    """H=3 in-process stripe controllers (mixed fused/vmapped: stripe
    widths differ, so dispatch differs per host) reproduce the single-
    process fleet's arm trajectory and summary exactly — including
    per-node alpha/QoS AND sliding-window/warm-up hyperparameter
    lanes (the nonstationary lanes must survive striping)."""
    p = make_env_params(get_app("tealeaf"))
    n, t = 8, 30
    pol = energy_ucb().with_params(make_policy_params()._replace(
        alpha=jnp.linspace(0.05, 0.3, n),
        qos_delta=jnp.where(jnp.arange(n) % 2 == 0, 0.1, -1.0),
        gamma=jnp.where(jnp.arange(n) % 2 == 0, 0.97, 1.0),
        optimistic=jnp.where(jnp.arange(n) % 4 == 0, 0.0, 1.0),
    ))
    ref = EnergyController(pol, SimBackend(p, n=n, seed=7), seed=0,
                           interpret=True)
    assert ref.use_kernel
    ref_arms = _run_controller(ref, t)

    full = SimBackend(p, n=n, seed=7)
    got = np.zeros_like(ref_arms)
    locals_ = []
    for lo, hi in stripe_bounds(n, 3):
        ctl = DistributedFleetController(
            slice_policy_lanes(pol, lo, hi, n), full.local_slice(lo, hi),
            stripe=(lo, hi), n_total=n, seed=0, interpret=True,
            log_arms=True)
        for _ in range(t):
            ctl.step()
        got[:, lo:hi] = np.stack(ctl.arm_log)
        locals_.append(ctl)
    np.testing.assert_array_equal(got, ref_arms)
    # state parity too
    for leaf in ref.states:
        merged = np.concatenate(
            [np.asarray(c.controller.states[leaf]) for c in locals_])
        np.testing.assert_array_equal(
            merged, np.asarray(ref.states[leaf]),
            err_msg=f"striped state diverged on {leaf}")
    # fleet aggregate == the single process's own summary
    agg = reduce_summaries([c.local_summary() for c in locals_])
    ref_sum = ref.summary()
    for f in ("energy_j", "switches", "baseline_energy_j", "time_s"):
        np.testing.assert_allclose(agg[f], ref_sum[f], rtol=1e-6,
                                   err_msg=f"aggregate {f}")
    np.testing.assert_allclose(agg["saved_energy_pct"],
                               ref_sum["saved_energy_pct"], rtol=1e-5)


def test_trace_replay_striped_across_hosts(tmp_path):
    """Satellite: a recorded single-process trace, saved to npz, sliced
    per host through the new local_slice path, reproduces the same arms
    as a single process replaying the whole file."""
    p = make_env_params(get_app("tealeaf"))
    n, t = 4, 12
    live = EnergyController(energy_ucb(), SimBackend(p, n=n, seed=9), seed=0)
    schedule = np.stack([np.asarray(live.step()["arm"]) for _ in range(t)])

    trace = record_trace(SimBackend(p, n=n, seed=9), schedule)
    path = str(tmp_path / "fleet_trace.npz")
    trace.save(path)

    single = EnergyController(energy_ucb(), TraceReplayBackend.load(path),
                              seed=0)
    want = _run_controller(single, t)

    got = np.zeros_like(want)
    parts = []
    for lo, hi in stripe_bounds(n, 2):
        shard = TraceReplayBackend.load(path).local_slice(lo, hi)
        assert shard.n_nodes == hi - lo and len(shard) == t
        # column-sliced loading (the O(N/H) per-host path the launcher
        # uses) yields the same shard as full-load + local_slice
        direct = TraceReplayBackend.load(path, nodes=(lo, hi))
        np.testing.assert_array_equal(np.asarray(direct.trace.energy_j),
                                      np.asarray(shard.trace.energy_j))
        np.testing.assert_array_equal(direct.baseline_interval()[0],
                                      shard.baseline_interval()[0])
        ctl = DistributedFleetController(energy_ucb(), shard,
                                         stripe=(lo, hi), n_total=n,
                                         seed=0, log_arms=True)
        for _ in range(t):
            ctl.step()
        got[:, lo:hi] = np.stack(ctl.arm_log)
        # actuations were logged per shard, never applied
        assert len(shard.requested_arms) == t
        parts.append(ctl.local_summary())
    np.testing.assert_array_equal(got, want)
    # and the npz round trip preserved the per-shard baseline, so the
    # fleet aggregate still reports energy savings
    agg = reduce_summaries(parts)
    np.testing.assert_allclose(agg["energy_j"], single.summary()["energy_j"],
                               rtol=1e-6)
    assert "saved_energy_pct" in agg


# ---------------------------------------------------------------------------
# the real thing: 2 controller PROCESSES vs the single-process sharded step
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_two_process_fleet_matches_single_process_sharded_step(tmp_path):
    """The acceptance oracle: H=2 subprocess hosts — each owning a local
    SimBackend stripe and its share of fused-kernel controller state,
    rendezvousing over the socket coordinator — produce arm AND state
    trajectories identical to the single-process
    ``make_sharded_fleet_step`` run on the same fleet."""
    n, t = 10, 40
    out = tmp_path / "arms.npz"
    cmd = [sys.executable, "-m", "repro.launch.fleet_serve", "--spawn",
           "--num-hosts", "2", "--nodes", str(n), "--intervals", str(t),
           "--app", "tealeaf", "--seed", "0", "--interpret",
           "--out", str(out)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env=_subproc_env(), cwd=str(REPO))
    assert r.returncode == 0, (r.stdout + r.stderr)[-3000:]
    z = np.load(out)
    np.testing.assert_array_equal(z["stripe_lo"], [0, 5])
    np.testing.assert_array_equal(z["stripe_hi"], [5, 10])

    from repro.parallel import fleet_mesh

    p = make_env_params(get_app("tealeaf"))
    ref = EnergyController(energy_ucb(), SimBackend(p, n=n, seed=0), seed=0,
                           interpret=True, mesh=fleet_mesh())
    assert ref.use_kernel and ref.fleet._sharded_step is not None
    ref_arms = _run_controller(ref, t)
    np.testing.assert_array_equal(z["arms"], ref_arms)
    for leaf in ref.states:
        np.testing.assert_array_equal(
            z[f"state_{leaf}"], np.asarray(ref.states[leaf]),
            err_msg=f"2-process state diverged on {leaf}")


@pytest.mark.slow
def test_two_process_nonstationary_drift_matches_single_process(tmp_path):
    """The nonstationary acceptance oracle: a sliding-window fleet on a
    DRIFTING workload (miniswp -> tealeaf, phase schedule keyed by
    global interval index) run as H=2 subprocess hosts reproduces the
    single-process sharded-step trajectory exactly — nonstationary
    lanes and phase boundaries both survive striping."""
    n, t, every = 10, 36, 12
    out = tmp_path / "arms_sw.npz"
    cmd = [sys.executable, "-m", "repro.launch.fleet_serve", "--spawn",
           "--num-hosts", "2", "--nodes", str(n), "--intervals", str(t),
           "--app", "miniswp", "--drift", "tealeaf",
           "--drift-every", str(every), "--window-discount", "0.97",
           "--seed", "0", "--interpret", "--out", str(out)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env=_subproc_env(), cwd=str(REPO))
    assert r.returncode == 0, (r.stdout + r.stderr)[-3000:]
    z = np.load(out)

    from repro.parallel import fleet_mesh

    pa = make_env_params(get_app("miniswp"))
    pb = make_env_params(get_app("tealeaf"))
    ref = EnergyController(
        energy_ucb(window_discount=0.97),
        SimBackend(pa, n=n, seed=0, drift_params=[pb], drift_every=every),
        seed=0, interpret=True, mesh=fleet_mesh())
    assert ref.use_kernel, "sliding-window fleets must dispatch fused"
    ref_arms = _run_controller(ref, t)
    np.testing.assert_array_equal(z["arms"], ref_arms)
    for leaf in ref.states:
        np.testing.assert_array_equal(
            z[f"state_{leaf}"], np.asarray(ref.states[leaf]),
            err_msg=f"2-process nonstationary state diverged on {leaf}")


@pytest.mark.slow
def test_two_process_factored_matches_single_process(tmp_path):
    """The factored acceptance oracle: a (core x uncore) product-ladder
    fleet (--uncore-ladder, 9x3 = 27 flat arms, per-dimension uncore
    penalty) striped across H=2 subprocess hosts reproduces the
    single-process sharded-step trajectory exactly — observation-
    determined striping stays deterministic at k_unc > 1."""
    from repro.core.policies import ActionSpace, factored_energy_ucb
    from repro.core.simulator import make_factored_env_params

    n, t = 10, 40
    out = tmp_path / "arms_factored.npz"
    cmd = [sys.executable, "-m", "repro.launch.fleet_serve", "--spawn",
           "--num-hosts", "2", "--nodes", str(n), "--intervals", str(t),
           "--app", "tealeaf", "--uncore-ladder", "0.6,0.8,1.0",
           "--lam-unc", "0.01", "--seed", "0", "--interpret",
           "--out", str(out)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env=_subproc_env(), cwd=str(REPO))
    assert r.returncode == 0, (r.stdout + r.stderr)[-3000:]
    z = np.load(out)

    from repro.parallel import fleet_mesh

    p = make_factored_env_params(get_app("tealeaf"),
                                 unc_freqs=(0.6, 0.8, 1.0))
    ref = EnergyController(
        factored_energy_ucb(ActionSpace(9, 3), uncore_penalty=0.01,
                            qos_delta=None),
        SimBackend(p, n=n, seed=0), seed=0, interpret=True,
        mesh=fleet_mesh())
    assert ref.use_kernel, "factored fleets must dispatch fused"
    assert ref.fleet.k_unc == 3
    ref_arms = _run_controller(ref, t)
    np.testing.assert_array_equal(z["arms"], ref_arms)
    assert ref.states["mu"].shape == (n, 27)
    for leaf in ref.states:
        np.testing.assert_array_equal(
            z[f"state_{leaf}"], np.asarray(ref.states[leaf]),
            err_msg=f"2-process factored state diverged on {leaf}")
