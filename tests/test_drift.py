"""Drifting-workload scenarios: the phase-changing Aurora loads the
sliding-window (gamma < 1) EnergyUCB exists for, now first-class through
the whole stack — SimBackend phase schedules keyed by global interval
index (so distributed stripes switch at the same boundary), fused-kernel
nonstationary lanes, and the QoS feasible set re-learning slowdowns
after a phase change."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import get_app, make_env_params
from repro.core.simulator import expected_rewards
from repro.energy import EnergyController, SimBackend, slice_counters
from repro.parallel.fleet import stripe_bounds


def _params(name):
    return make_env_params(get_app(name))


# ---------------------------------------------------------------------------
# the phase schedule itself
# ---------------------------------------------------------------------------


def test_drift_backend_cycles_phases():
    """Phase p is active for intervals [p*every, (p+1)*every) and the
    cycle wraps; counters reflect the active phase's energy table (a
    synthetic 3x-energy phase B, far beyond the 3% counter noise)."""
    pa = _params("miniswp")
    pb = pa._replace(e_interval_kj=pa.e_interval_kj * 3.0)
    b = SimBackend(pa, n=2, seed=0, drift_params=[pb], drift_every=3)
    assert b.active_phase() == 0
    phases, d_e = [], []
    last = np.asarray(b.read_counters().energy_j).copy()
    b.apply_arms(np.zeros(2, np.int32))
    for _ in range(12):
        phases.append(b.active_phase())
        b.advance()
        now = np.asarray(b.read_counters().energy_j).copy()
        d_e.append(float((now - last).mean()))
        last = now
    assert phases == [0, 0, 0, 1, 1, 1, 0, 0, 0, 1, 1, 1]
    d_e = np.asarray(d_e)
    assert d_e[3:6].mean() > 2.0 * d_e[:3].mean()
    assert d_e[6:9].mean() < 0.6 * d_e[3:6].mean()


def test_drift_backend_validates_schedule():
    pa, pb = _params("miniswp"), _params("lbm")
    with pytest.raises(ValueError, match="drift_every"):
        SimBackend(pa, n=2, drift_params=[pb])
    bad = pb._replace(freqs=pb.freqs * 2.0)
    with pytest.raises(ValueError, match="frequency ladder"):
        SimBackend(pa, n=2, drift_params=[bad], drift_every=5)


def test_drift_backend_local_slice_bit_parity():
    """Stripes of a drifting fleet, advanced in lockstep, reproduce the
    full backend's counter rows bit for bit — each stripe counts its own
    advances, so the phase boundary lands on the same global interval."""
    pa, pb = _params("miniswp"), _params("lbm")
    n, t = 7, 11
    full = SimBackend(pa, n=n, seed=4, drift_params=[pb], drift_every=4)
    stripes = [full.local_slice(lo, hi) for lo, hi in stripe_bounds(n, 3)]
    rng = np.random.default_rng(1)
    for _ in range(t):
        arms = rng.integers(0, 9, size=n).astype(np.int32)
        full.apply_arms(arms)
        full.advance()
        for (lo, hi), s in zip(stripe_bounds(n, 3), stripes):
            s.apply_arms(arms[lo:hi])
            s.advance()
    want = full.read_counters()
    for (lo, hi), s in zip(stripe_bounds(n, 3), stripes):
        assert s.active_phase() == full.active_phase()
        got = s.read_counters()
        for f, g, w in zip(got._fields, got, slice_counters(want, lo, hi)):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w),
                err_msg=f"drift stripe [{lo},{hi}) counter {f}")


# ---------------------------------------------------------------------------
# regret under drift: sliding-window recovers, stationary does not
# ---------------------------------------------------------------------------


def _tail_quality(policy, *, seed, n=4, phase_len=250, tail=100):
    """Mean true expected reward (phase-B landscape, normalized so the
    best arm is -1.0-ish) of the arms actuated over the last ``tail``
    intervals of phase B (miniswp -> lbm)."""
    pa, pb = _params("miniswp"), _params("lbm")
    ctl = EnergyController(
        policy, SimBackend(pa, n=n, seed=seed, drift_params=[pb],
                           drift_every=phase_len),
        seed=1, interpret=True)
    for _ in range(2 * phase_len):
        ctl.step()
    arms = np.stack([np.asarray(h["arm"]) for h in ctl.history])
    mu_b = np.asarray(expected_rewards(pb))
    return float(np.mean(mu_b[arms[-tail:]])), ctl


def test_sliding_window_recovers_after_phase_change():
    """The acceptance scenario: after miniswp (memory-bound, arm 0 best)
    drifts into lbm (compute-bound, arm 0 is 40% worse than best), the
    sliding-window fleet re-converges to near-best arms while the
    stationary fleet is still paying for its stale estimates. Both run
    the SAME fused kernel launch path."""
    from repro.core import energy_ucb

    q_sw, ctl_sw = _tail_quality(energy_ucb(window_discount=0.97), seed=0)
    q_st, ctl_st = _tail_quality(energy_ucb(), seed=0)
    assert ctl_sw.use_kernel and ctl_st.use_kernel, \
        "nonstationary fleets must dispatch the fused kernel now"
    # lbm best arm is -0.9976; the stationary fleet sits near its stale
    # phase-A arms (mu ~ -1.3); the window fleet must recover most of it
    assert q_sw > q_st + 0.1, (q_sw, q_st)
    assert q_sw > -1.1, f"sliding window failed to re-converge: {q_sw}"


def test_constrained_drift_respects_budget_post_warmup():
    """QoS x sliding-window: after miniswp (every arm within a 10%
    budget) drifts into tealeaf (whose energy-BEST arm runs 27.7% slow),
    the feasible set is recomputed from the now-discounted progress
    estimates — before this PR ``ucb_update`` left phat/pn stationary
    under gamma < 1, so the mask was computed from stale phase-A
    slowdowns. The constrained window fleet must respect the budget in
    phase-B steady state (up to the sparse re-exploration the decayed
    counts deliberately re-admit); the unconstrained window fleet parks
    on the over-budget energy optimum, proving the budget binds."""
    from repro.core import energy_ucb

    pa, pb = _params("miniswp"), _params("tealeaf")
    delta, phase_len, transient = 0.10, 250, 120
    true_slow_b = 1.0 - np.asarray(pb.t_rel)[-1] / np.asarray(pb.t_rel)

    def phase_b_violations(policy, seed=0):
        ctl = EnergyController(
            policy, SimBackend(pa, n=4, seed=seed, drift_params=[pb],
                               drift_every=phase_len),
            seed=1, interpret=True)
        assert ctl.use_kernel, "drifting fleets must dispatch fused"
        for _ in range(2 * phase_len):
            ctl.step()
        arms = np.stack([np.asarray(h["arm"]) for h in ctl.history])
        # phase-B steady state: skip the re-estimation transient after
        # the boundary, judge against phase B's true slowdown ladder
        steady = arms[phase_len + transient:]
        return (true_slow_b[steady] > delta + 1e-6).mean()

    v_con = phase_b_violations(energy_ucb(qos_delta=delta,
                                          window_discount=0.99))
    v_unc = phase_b_violations(energy_ucb(window_discount=0.99))
    assert v_con < 0.1, f"constrained window fleet violation rate {v_con}"
    assert v_unc > 0.5, f"budget should bind: unconstrained rate {v_unc}"
    assert v_con < v_unc / 10
