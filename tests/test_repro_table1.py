"""The paper's headline claims as assertions (Table 1 + §4.2):
EnergyUCB saves energy vs. the 1.6 GHz default, stays within small
energy-regret of the best static arm, and beats the dynamic baselines.
"""
import jax
import numpy as np
import pytest

from repro.core import (
    TABLE1_KJ,
    app_names,
    energy_ts,
    energy_ucb,
    eps_greedy,
    get_app,
    make_env_params,
    rr_freq,
    run_repeats,
)

APPS = ("tealeaf", "miniswp", "sph_exa")  # one per regime, keeps CI fast


@pytest.mark.parametrize("name", APPS)
def test_saves_energy_vs_default(name):
    p = make_env_params(get_app(name))
    out = run_repeats(energy_ucb(), p, jax.random.key(0), 5)
    assert out["completed"].all()
    e = out["energy_kj"].mean()
    default = TABLE1_KJ[name][-1]
    assert e < default, f"{name}: {e:.1f} !< default {default:.1f}"


@pytest.mark.parametrize("name", APPS)
def test_energy_regret_small(name):
    p = make_env_params(get_app(name))
    e = run_repeats(energy_ucb(), p, jax.random.key(0), 5)["energy_kj"].mean()
    best = TABLE1_KJ[name].min()
    assert (e - best) / best < 0.03, f"{name}: regret {(e-best)/best:.3f}"


@pytest.mark.slow
@pytest.mark.parametrize("name", APPS)
def test_beats_dynamic_baselines(name):
    p = make_env_params(get_app(name))
    key = jax.random.key(0)
    e_ucb = run_repeats(energy_ucb(), p, key, 5)["energy_kj"].mean()
    for mk in (rr_freq, eps_greedy, energy_ts):
        e_b = run_repeats(mk(), p, key, 5)["energy_kj"].mean()
        assert e_ucb <= e_b * 1.005, f"{name}: UCB {e_ucb:.1f} vs {mk().name} {e_b:.1f}"


@pytest.mark.slow
def test_beats_rl_baselines():
    from repro.core import rl_power
    from repro.core.rl import drlcap
    from repro.core.rollout import run_drlcap_protocol

    name = "miniswp"
    p = make_env_params(get_app(name))
    key = jax.random.key(0)
    e_ucb = run_repeats(energy_ucb(), p, key, 5)["energy_kj"].mean()
    e_rl = run_repeats(rl_power(), p, key, 3)["energy_kj"].mean()
    assert e_ucb < e_rl
    e_drl = float(run_drlcap_protocol(drlcap, p, key)["energy_kj"])
    assert e_ucb < e_drl
