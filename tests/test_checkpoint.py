import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


@pytest.fixture()
def tdir(tmp_path):
    return str(tmp_path / "ck")


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "w": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
        "scalar": jnp.float32(3.5),
    }


def test_roundtrip(tdir):
    s = _state()
    ckpt.save(tdir, 7, s, extra={"data": {"step": 7, "seed": 0}})
    step, s2, extra = ckpt.restore(tdir, s)
    assert step == 7 and extra["data"]["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), s, s2)


def test_latest_and_prune(tdir):
    s = _state()
    for i in (1, 2, 3, 4, 5):
        ckpt.save(tdir, i, s, keep_last=3)
    assert ckpt.latest_step(tdir) == 5
    kept = sorted(d for d in os.listdir(tdir) if d.startswith("step_"))
    assert len(kept) == 3


def test_no_partial_checkpoint_visible(tdir):
    """tmp dirs must never be mistaken for checkpoints."""
    s = _state()
    ckpt.save(tdir, 1, s)
    os.makedirs(os.path.join(tdir, ".tmp_step_00000009"))
    assert ckpt.latest_step(tdir) == 1


def test_async_save_then_restore(tdir):
    s = _state(3)
    ckpt.async_save(tdir, 11, s, extra={"data": {"step": 11, "seed": 0}})
    ckpt.wait_for_saves(tdir)
    step, s2, _ = ckpt.restore(tdir, s)
    assert step == 11
    np.testing.assert_array_equal(np.asarray(s["w"]), np.asarray(s2["w"]))


def test_elastic_restore_with_shardings(tdir):
    """Restore re-places leaves per provided shardings (the elastic
    path: save under mesh A, restore under mesh B)."""
    s = _state(4)
    ckpt.save(tdir, 2, s)
    shardings = jax.tree.map(lambda _: None, s)
    step, s2, _ = ckpt.restore(tdir, s, shardings=shardings)
    assert step == 2
    assert s2["w"].shape == (8, 16)


def test_restore_missing_raises(tdir):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tdir, {"a": jnp.zeros(1)})


def test_controller_stripe_async_roundtrip(tdir):
    """The distributed control plane's checkpoint contract end to end:
    async_save of the fused-kernel (N, K) controller stripe state on
    every interval, wait_for_saves, then restore into a FRESH process's
    controller — latest_step must pick the newest save surviving
    keep_last pruning, and the restored stripe must actuate the exact
    arms and counters the uncrashed run would on every later interval."""
    from repro.core import get_app, make_env_params
    from repro.core.policies import energy_ucb
    from repro.energy import SimBackend
    from repro.parallel.distributed import DistributedFleetController

    env = make_env_params(get_app("tealeaf"))
    make = lambda: DistributedFleetController(
        energy_ucb(), SimBackend(env, n=6, seed=0), seed=0, interpret=True,
        log_arms=True)
    ctl = make()
    for step in range(1, 6):  # 5 saves, keep_last=2: steps 4 and 5 survive
        ctl.step()
        ckpt.async_save(tdir, step, ctl.state_dict(), keep_last=2)
    ckpt.wait_for_saves(tdir)
    assert ckpt.list_steps(tdir) == [4, 5]
    assert ckpt.latest_step(tdir) == 5
    for _ in range(3):  # the uncrashed run continues to interval 8
        ctl.step()

    back = make()
    step, state, _ = ckpt.restore(tdir, like=back.state_dict())
    assert step == 5
    back.load_state_dict(state)
    assert back.interval == 5
    for _ in range(3):
        back.step()
    np.testing.assert_array_equal(np.stack(back.arm_log),
                                  np.stack(ctl.arm_log))
    for k, v in ctl.controller.states.items():
        np.testing.assert_array_equal(
            np.asarray(back.controller.states[k]), np.asarray(v),
            err_msg=f"restored (N, K) state diverged on {k}")
