"""Episode megakernel (kernels.episode_scan) parity suite.

Three independent anchors pin the scanned paths:

- the Pallas megakernel (interpret mode on CPU) vs the pure-jnp
  ``ref_episode_scan`` oracle, on ragged N / ragged T with mixed
  stationary / sliding-window / QoS / warm-up lanes — the acceptance
  criterion for the one-launch-per-episode path;
- the megakernel vs T repeated fused ``fleet_step`` launches — the
  scan must be bitwise indistinguishable from the per-interval kernel
  it replaces;
- the live ``EnergyController`` streaming loop vs ``run_scanned`` —
  env counters, RNG/key streams and arm trajectories bit-exact over a
  ``SimBackend`` (including drift-phase boundaries crossed mid-scan
  and chunked episodes that resume streaming), and trace replay
  reproducing a live run arm-for-arm.

All oracles are wrapped in ``jax.jit``: the un-jitted oracle evaluates
op-by-op while the kernels run fused, and FMA contraction differences
show up as ulp noise. Same expressions, same compiler, bit-identical.
"""
import importlib.util
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    energy_ts,
    energy_ucb,
    get_app,
    make_env_params,
    run_fleet_episode,
    run_sweep,
    sweep_policy_params,
)
from repro.energy import EnergyController, SimBackend
from repro.energy.backend import TraceReplayBackend, record_trace
from repro.kernels import ops, ref
from repro.kernels.episode_scan import (
    EnvRows,
    env_rows_init,
    make_scan_env,
)


def _fleet_state(n, k=9, seed=0):
    key = jax.random.key(seed)
    f = lambda i: jax.random.fold_in(key, i)
    return dict(
        mu=jax.random.normal(f(1), (n, k)) * -1.0,
        n=jax.random.randint(f(2), (n, k), 1, 40).astype(jnp.float32),
        phat=jax.random.uniform(f(3), (n, k), minval=1e-4, maxval=2e-4),
        pn=jax.random.randint(f(4), (n, k), 0, 40).astype(jnp.float32),
        prev=jax.random.randint(f(5), (n,), 0, k),
        t=jax.random.randint(f(6), (n,), 1, 200).astype(jnp.float32),
        arm=jax.random.randint(f(7), (n,), 0, k),
    )


def _mixed_lanes(n, k=9, seed=0):
    """Per-controller lanes mixing every fused-step variant in one
    fleet: spread alpha/lam, ~half QoS-constrained (incl. 0.0 budgets),
    ~half sliding-window (incl. gamma = 0.0), a third on round-robin
    warm-up, and a nonzero prior."""
    key = jax.random.key(3000 + seed)
    f = lambda i: jax.random.fold_in(key, i)
    qos = jnp.where(jax.random.uniform(f(1), (n,)) < 0.5,
                    jax.random.uniform(f(2), (n,), maxval=0.15), -1.0)
    qos = qos.at[: min(4, n)].set(0.0)
    da = jax.random.randint(f(3), (n,), 0, k)
    gamma = jnp.where(jax.random.uniform(f(4), (n,)) < 0.5,
                      jax.random.uniform(f(5), (n,), maxval=0.999), 1.0)
    gamma = gamma.at[: min(3, n)].set(0.0)
    optimistic = jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0)
    prior = jax.random.normal(f(6), (n, k)) * 0.1
    alpha = jax.random.uniform(f(7), (n,), minval=0.05, maxval=0.3)
    lam = jax.random.uniform(f(8), (n,), minval=0.0, maxval=0.05)
    return dict(alpha=alpha, lam=lam, qos=qos, da=da, gamma=gamma,
                optimistic=optimistic, prior=prior)


def _obs_cols(tt, n, seed=0):
    key = jax.random.key(4000 + seed)
    f = lambda i: jax.random.fold_in(key, i)
    return (
        -jax.random.uniform(f(1), (tt, n), minval=0.5, maxval=1.5),
        jax.random.uniform(f(2), (tt, n), minval=1e-4, maxval=2e-4),
        (jax.random.uniform(f(3), (tt, n)) < 0.85).astype(jnp.float32),
    )


def _assert_state_equal(got, want, names, msg):
    for nm, g, w in zip(names, got, want):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w), err_msg=f"{msg} {nm}")


_STATE7 = ("mu", "n", "phat", "pn", "prev", "t", "next_arm")


# ragged N (below one stripe / exactly one / pad-and-slice) x ragged T
@pytest.mark.parametrize("n,tt", [(7, 13), (1024, 6), (2049, 9)])
def test_trace_megakernel_matches_ref(n, tt):
    """Pallas trace-fed episode scan (interpret mode) is bit-exact vs
    the jitted lax.scan oracle on mixed lanes — the acceptance test."""
    s = _fleet_state(n, seed=n + tt)
    la = _mixed_lanes(n, seed=n)
    reward, progress, active = _obs_cols(tt, n, seed=n)
    got, arms = ops.episode_scan_trace(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        reward, progress, active, la["alpha"], la["lam"], la["qos"],
        la["da"], la["gamma"], la["optimistic"], la["prior"],
        interpret=True, block_n=1024,
    )
    want, warms = jax.jit(ref.ref_episode_scan)(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        reward, progress, active, la["alpha"], la["lam"], qos=la["qos"],
        default_arm=la["da"], gamma=la["gamma"],
        optimistic=la["optimistic"], prior_mu=la["prior"],
    )
    _assert_state_equal(got, want, _STATE7, f"trace scan n={n} T={tt}")
    np.testing.assert_array_equal(np.asarray(arms), np.asarray(warms))
    assert np.array_equal(np.asarray(arms[0]), np.asarray(s["arm"]))


@pytest.mark.parametrize("n,tt", [(7, 13), (1024, 6), (2049, 9)])
def test_trace_megakernel_matches_repeated_fleet_step(n, tt):
    """One scanned launch == T repeated fused ``fleet_step`` launches,
    bit for bit (the per-interval kernel the megakernel replaces)."""
    s = _fleet_state(n, seed=n + tt)
    la = _mixed_lanes(n, seed=n)
    reward, progress, active = _obs_cols(tt, n, seed=n)
    got, arms = ops.episode_scan_trace(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        reward, progress, active, la["alpha"], la["lam"], la["qos"],
        la["da"], la["gamma"], la["optimistic"], la["prior"],
        interpret=True,
    )
    state = (s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"],
             s["arm"])
    arms_run = []
    for i in range(tt):
        arms_run.append(state[6])  # the arm held ENTERING interval i
        state = ops.fleet_step(
            *state, reward[i], progress[i], active[i], la["alpha"],
            la["lam"], la["qos"], la["da"], la["gamma"], la["optimistic"],
            la["prior"], interpret=True,
        )
    _assert_state_equal(got, state, _STATE7,
                        f"scan vs repeated step n={n} T={tt}")
    np.testing.assert_array_equal(
        np.asarray(arms), np.stack([np.asarray(a) for a in arms_run]))


def _sim_inputs(n, tt, phases, seed=0):
    """Random-but-plausible env rows (some nodes finished, some fresh)
    plus (T, N) noise streams and the stacked phase tables."""
    key = jax.random.key(5000 + seed)
    f = lambda i: jax.random.fold_in(key, i)
    rem = jax.random.uniform(f(1), (n,), minval=0.0, maxval=1.0)
    rem = rem.at[:: max(n // 7, 1)].set(0.0)  # finished (frozen) nodes
    env = EnvRows(
        remaining=rem,
        prev_arm=jax.random.randint(f(2), (n,), 0, 9),
        t=jax.random.randint(f(3), (n,), 0, 300),
        energy_kj=jax.random.uniform(f(4), (n,), maxval=5.0),
        time_s=jax.random.uniform(f(5), (n,), maxval=30.0),
        switches=jax.random.randint(f(6), (n,), 0, 40),
        core_s=jax.random.uniform(f(7), (n,), maxval=20.0),
        uncore_s=jax.random.uniform(f(8), (n,), maxval=20.0),
    )
    z = tuple(jax.random.normal(f(10 + i), (tt, n)) for i in range(4))
    return env, z, make_scan_env(phases)


@pytest.mark.parametrize("counter_obs", [True, False])
@pytest.mark.parametrize(
    "n,tt,t_start", [(193, 33, 5), (2049, 9, 11)],
)
def test_sim_megakernel_matches_ref(n, tt, t_start, counter_obs):
    """Pallas sim-fused episode scan (interpret mode) is bit-exact vs
    the jitted oracle with drift-phase boundaries crossed MID-SCAN
    (P=3 phases, drift_every=7, episode starting mid-phase), in both
    observation conventions (controller counter-deltas and the rollout
    engine's direct obs)."""
    phases = [make_env_params(get_app(a))
              for a in ("tealeaf", "lbm", "clvleaf")]
    s = _fleet_state(n, seed=n)
    la = _mixed_lanes(n, seed=n + 1)
    env, z, senv = _sim_inputs(n, tt, phases, seed=n)
    got, genv, arms = ops.episode_scan_sim(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        env, z, senv, la["alpha"], la["lam"], la["qos"], la["da"],
        la["gamma"], la["optimistic"], la["prior"],
        t_start=t_start, drift_every=7, counter_obs=counter_obs,
        interpret=True,
    )
    rfn = jax.jit(ref.ref_episode_scan_sim,
                  static_argnames=("t_start", "drift_every", "counter_obs"))
    want, wenv, warms = rfn(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        env, z, senv, la["alpha"], la["lam"], qos=la["qos"],
        default_arm=la["da"], gamma=la["gamma"],
        optimistic=la["optimistic"], prior_mu=la["prior"],
        # ops folds t_start modulo the P * drift_every schedule period
        t_start=t_start % (7 * len(phases)), drift_every=7,
        counter_obs=counter_obs,
    )
    msg = f"sim scan n={n} T={tt} counter_obs={counter_obs}"
    _assert_state_equal(got, want, _STATE7, msg)
    _assert_state_equal(genv, wenv, EnvRows._fields, msg + " env")
    np.testing.assert_array_equal(np.asarray(arms), np.asarray(warms))


def test_xla_fallback_matches_ref():
    """The interpret=False CPU route (the XLA lax.scan fallback that
    production hits on this container) is bit-exact vs the jitted
    oracle in both modes. The fallback DONATES the scanned state, so
    oracle results are computed first and inputs rebuilt."""
    n, tt = 161, 21
    phases = [make_env_params(get_app(a)) for a in ("tealeaf", "lbm")]
    la = _mixed_lanes(n, seed=7)
    reward, progress, active = _obs_cols(tt, n, seed=7)
    env, z, senv = _sim_inputs(n, tt, phases, seed=7)

    s = _fleet_state(n, seed=7)
    want, warms = jax.jit(ref.ref_episode_scan)(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        reward, progress, active, la["alpha"], la["lam"], qos=la["qos"],
        default_arm=la["da"], gamma=la["gamma"],
        optimistic=la["optimistic"], prior_mu=la["prior"],
    )
    s = _fleet_state(n, seed=7)  # fresh buffers: the fallback donates
    got, arms = ops.episode_scan_trace(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        reward, progress, active, la["alpha"], la["lam"], la["qos"],
        la["da"], la["gamma"], la["optimistic"], la["prior"],
    )
    _assert_state_equal(got, want, _STATE7, "xla trace fallback")
    np.testing.assert_array_equal(np.asarray(arms), np.asarray(warms))

    s = _fleet_state(n, seed=7)
    rfn = jax.jit(ref.ref_episode_scan_sim,
                  static_argnames=("t_start", "drift_every", "counter_obs"))
    want, wenv, warms = rfn(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        env, z, senv, la["alpha"], la["lam"], qos=la["qos"],
        default_arm=la["da"], gamma=la["gamma"],
        optimistic=la["optimistic"], prior_mu=la["prior"],
        t_start=3, drift_every=4, counter_obs=True,
    )
    s = _fleet_state(n, seed=7)
    got, genv, arms = ops.episode_scan_sim(
        s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"], s["arm"],
        env, z, senv, la["alpha"], la["lam"], la["qos"], la["da"],
        la["gamma"], la["optimistic"], la["prior"],
        t_start=3, drift_every=4, counter_obs=True,
    )
    _assert_state_equal(got, want, _STATE7, "xla sim fallback")
    _assert_state_equal(genv, wenv, EnvRows._fields, "xla sim fallback env")
    np.testing.assert_array_equal(np.asarray(arms), np.asarray(warms))


# ---------------------------------------------------------------------------
# live controller: streaming vs scanned
# ---------------------------------------------------------------------------


def _mk_pair(n=48, seed=3, drifting=False):
    pa = make_env_params(get_app("tealeaf"))
    kw = {}
    if drifting:
        kw = dict(drift_params=[make_env_params(get_app("lbm"))],
                  drift_every=4)
    pol = energy_ucb(qos_delta=0.08, window_discount=0.97)
    mk = lambda: EnergyController(
        pol, SimBackend(pa, n=n, seed=9, **kw), seed=2,
        record_history=False)
    return mk(), mk()


def _counters_equal(a, b, msg):
    for la, lb, nm in zip(a, b, type(a)._fields):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{msg} counter {nm}")


@pytest.mark.parametrize("drifting", [False, True])
def test_run_scanned_matches_streaming(drifting):
    """One scanned episode == the streamed loop over a live SimBackend:
    arms in lockstep, env counters and RNG key streams bit-exact, and
    streaming resumes seamlessly after the scan (the drifting case
    crosses phase boundaries mid-scan AND resumes mid-phase)."""
    tt = 11
    live, scan = _mk_pair(drifting=drifting)
    arms_live = []
    for _ in range(tt):
        live.step()
        arms_live.append(np.asarray(live.last_arms))
    scan.run_scanned(tt)
    np.testing.assert_array_equal(
        np.stack(arms_live), np.asarray(scan.last_episode_arms),
        err_msg="scanned arm trace diverged from streaming")
    _counters_equal(live._last, scan._last, "post-episode")
    np.testing.assert_array_equal(
        jax.random.key_data(live._key), jax.random.key_data(scan._key),
        err_msg="controller key stream diverged")
    assert live.backend.interval_index == scan.backend.interval_index
    # controller means agree to float round-off (streaming derives obs
    # eagerly, the scan fuses the same expressions: FMA ulps only) and
    # the integer/count state is bit-exact
    for nm in ("n", "pn", "prev", "t"):
        np.testing.assert_array_equal(
            np.asarray(live.states[nm]), np.asarray(scan.states[nm]),
            err_msg=f"states[{nm}]")
    for nm in ("mu", "phat"):
        np.testing.assert_allclose(
            np.asarray(live.states[nm]), np.asarray(scan.states[nm]),
            rtol=1e-5, atol=1e-6, err_msg=f"states[{nm}]")
    # resume both STREAMING: identical arms for 5 more intervals
    for i in range(5):
        live.step()
        scan.step()
        np.testing.assert_array_equal(
            np.asarray(live.last_arms), np.asarray(scan.last_arms),
            err_msg=f"post-episode streaming step {i} diverged")


def test_run_scanned_chunks_compose():
    """Two scanned chunks (7 then 10, phase boundaries mid-chunk) land
    exactly where one 17-interval scan does — t_start threading and
    ``absorb_episode`` keep the schedule and counters seamless."""
    one, two = _mk_pair(drifting=True)
    one.run_scanned(17)
    two.run_scanned(7)
    two.run_scanned(10)
    _counters_equal(one._last, two._last, "chunked episode")
    np.testing.assert_array_equal(
        jax.random.key_data(one._key), jax.random.key_data(two._key))
    np.testing.assert_array_equal(np.asarray(one._arms),
                                  np.asarray(two._arms))
    for nm in ("n", "pn", "prev", "t"):
        np.testing.assert_array_equal(
            np.asarray(one.states[nm]), np.asarray(two.states[nm]),
            err_msg=f"chunked states[{nm}]")


def test_trace_replay_scan_matches_live():
    """Record a live streamed run, replay it as ONE scanned episode:
    the replayed controller requests the same arm at every interval."""
    tt, n = 9, 32
    live, _ = _mk_pair(n=n)
    arms_live = []
    for _ in range(tt):
        live.step()
        arms_live.append(np.asarray(live.last_arms))
    pa = make_env_params(get_app("tealeaf"))
    trace = record_trace(SimBackend(pa, n=n, seed=9), np.stack(arms_live))
    assert isinstance(trace, TraceReplayBackend) and len(trace) == tt
    pol = energy_ucb(qos_delta=0.08, window_discount=0.97)
    rep = EnergyController(pol, trace, seed=2, record_history=False)
    rep.run_scanned(tt)
    np.testing.assert_array_equal(
        np.stack(trace.requested_arms[-tt:]), np.stack(arms_live),
        err_msg="trace replay diverged from the live run arm-for-arm")
    with pytest.raises(RuntimeError, match="intervals left"):
        rep.run_scanned(1)  # trace exhausted


# ---------------------------------------------------------------------------
# engine lanes (run_sweep / run_fleet_episode) + error paths
# ---------------------------------------------------------------------------


def test_run_sweep_episode_scan_matches_legacy():
    """The one-launch sweep lane reproduces the per-step engine on all
    output keys (mixed QoS/sliding-window configs)."""
    params = make_env_params(get_app("tealeaf"))
    stacked = sweep_policy_params([0.1, 0.2], [0.0, 0.02],
                                  qos_delta=0.1, window_discount=0.98)
    key = jax.random.key(5)
    legacy = run_sweep(energy_ucb(), stacked, params, key, n_repeats=2,
                       max_steps=40)
    scanned = run_sweep(energy_ucb(), stacked, params, key, n_repeats=2,
                        max_steps=40, episode_scan=True)
    assert set(legacy) == set(scanned)
    for k in ("switches", "steps", "completed"):
        np.testing.assert_array_equal(legacy[k], scanned[k],
                                      err_msg=f"sweep {k}")
    for k in ("energy_kj", "time_s", "cum_regret"):
        np.testing.assert_allclose(legacy[k], scanned[k], rtol=1e-5,
                                   atol=1e-5, err_msg=f"sweep {k}")


def test_run_fleet_episode_scan_matches_legacy():
    params = make_env_params(get_app("tealeaf"))
    key = jax.random.key(6)
    legacy = run_fleet_episode(energy_ucb(), params, key, n_nodes=6,
                               max_steps=50)
    scanned = run_fleet_episode(energy_ucb(), params, key, n_nodes=6,
                                max_steps=50, episode_scan=True)
    np.testing.assert_array_equal(np.asarray(legacy["switches"]),
                                  np.asarray(scanned["switches"]))
    for k in ("energy_kj", "gang_time_s"):
        np.testing.assert_allclose(np.asarray(legacy[k]),
                                   np.asarray(scanned[k]),
                                   rtol=1e-5, atol=1e-5, err_msg=k)


def test_episode_scan_error_paths():
    params = make_env_params(get_app("tealeaf"))
    stacked = sweep_policy_params([0.1], [0.0])
    key = jax.random.key(0)
    with pytest.raises(ValueError, match="not kernel-exact"):
        run_sweep(energy_ts(), stacked, params, key, episode_scan=True)
    with pytest.raises(NotImplementedError, match="reward_fn"):
        run_sweep(energy_ucb(), stacked, params, key,
                  reward_fn=lambda obs: obs.reward, episode_scan=True)
    with pytest.raises(NotImplementedError, match="coordinated"):
        run_fleet_episode(energy_ucb(), params, key, n_nodes=4,
                          max_steps=10, coordinated=True,
                          episode_scan=True)
    # drifting phase tables demand an explicit schedule period
    pb = make_env_params(get_app("lbm"))
    senv = make_scan_env([params, pb])
    s = _fleet_state(4)
    env, z, _ = _sim_inputs(4, 3, [params], seed=1)
    with pytest.raises(ValueError, match="drift_every"):
        ops.episode_scan_sim(
            s["mu"], s["n"], s["phat"], s["pn"], s["prev"], s["t"],
            s["arm"], env, z, senv)
    # per-node stacked EnvParams keep the streaming path
    stacked_env = jax.tree.map(lambda a, b: jnp.stack([a, b]), params, pb)
    with pytest.raises(ValueError, match="stacked"):
        make_scan_env([stacked_env])
    # non-kernel-exact policies can't enter the controller's scan lane
    ctl = EnergyController(energy_ts(), SimBackend(params, n=4),
                           record_history=False)
    with pytest.raises(ValueError, match="fused-UCB"):
        ctl.run_scanned(3)
    # a reward-scale override would silently diverge from streaming
    ctl = EnergyController(energy_ucb(), SimBackend(params, n=4),
                           reward_scale=2.0, record_history=False)
    with pytest.raises(ValueError, match="reward_scale"):
        ctl.run_scanned(3)


# ---------------------------------------------------------------------------
# bench regression guard (scripts/bench_check.py)
# ---------------------------------------------------------------------------


def _bench_check():
    path = pathlib.Path(__file__).resolve().parents[1] / "scripts" / \
        "bench_check.py"
    spec = importlib.util.spec_from_file_location("bench_check", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _rows_json(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps({"rows": rows}))
    return str(p)


def test_bench_check_guard(tmp_path):
    bc = _bench_check()
    base = [
        {"name": "a", "us_per_call": 100.0},
        {"name": "b", "us_per_call": 50.0},
        {"name": "c", "us_per_call": 10.0},
        {"name": "old_only", "us_per_call": 1.0},
        {"name": "emu", "us_per_call": 5.0,
         "derived": "interpret mode on CPU"},
    ]
    bp = _rows_json(tmp_path, "base.json", base)
    ok = [
        {"name": "a", "us_per_call": 110.0},
        {"name": "b", "us_per_call": 45.0},
        {"name": "c", "us_per_call": 12.0},
        {"name": "old_only", "us_per_call": 1.0},
        {"name": "new_only", "us_per_call": 2.0},
        # interpret rows may swing arbitrarily without tripping the guard
        {"name": "emu", "us_per_call": 500.0,
         "derived": "interpret mode on CPU"},
    ]
    assert bc.main([_rows_json(tmp_path, "ok.json", ok),
                    "--baseline", bp]) == 0
    # coverage is part of the contract: dropping a baseline row FAILS...
    dropped = [r for r in ok if r["name"] != "old_only"]
    dp = _rows_json(tmp_path, "dropped.json", dropped)
    assert bc.main([dp, "--baseline", bp]) == 1
    # ...unless the row belongs to another invocation's scope (the CI
    # layout: one committed baseline, several benchmark JSONs)
    assert bc.main([dp, "--baseline", bp, "--scope", "a", "--scope", "b",
                    "--scope", "c", "--scope", "emu"]) == 0
    # a scoped run still fails when a row IN scope is missing
    assert bc.main([dp, "--baseline", bp, "--scope", "old_"]) == 1
    bad = [
        {"name": "a", "us_per_call": 100.0},
        {"name": "b", "us_per_call": 50.0},
        {"name": "c", "us_per_call": 45.0},  # 4.5x on one row
        {"name": "old_only", "us_per_call": 1.0},
        {"name": "emu", "us_per_call": 5.0,
         "derived": "interpret mode on CPU"},
    ]
    assert bc.main([_rows_json(tmp_path, "bad.json", bad),
                    "--baseline", bp]) == 1
    # a uniformly slower machine is NOT a regression (median rescale)
    slow = [{"name": r["name"],
             "us_per_call": r["us_per_call"] * 3,
             **({"derived": r["derived"]} if "derived" in r else {})}
            for r in base]
    assert bc.main([_rows_json(tmp_path, "slow.json", slow),
                    "--baseline", bp]) == 0
    broken = [{"name": "a", "us_per_call": "120 us"}]
    with pytest.raises(SystemExit, match="non-numeric"):
        bc.main([_rows_json(tmp_path, "broken.json", broken),
                 "--baseline", bp])
