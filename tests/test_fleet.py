"""Fleet control plane: vmapped controllers, coordinated gang mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy_ucb, get_app, make_env_params
from repro.core.fleet import Fleet, run_fleet_episode


def test_fleet_vmap_states():
    f = Fleet(energy_ucb(), n=32)
    states = f.init(jax.random.key(0))
    assert states["mu"].shape == (32, 9)
    arms = f.select(states, jax.random.key(1))
    assert arms.shape == (32,)
    assert ((arms >= 0) & (arms < 9)).all()


def test_coordinated_fewer_gang_switches_and_time():
    p = make_env_params(get_app("miniswp"))
    n = 8
    steps = 3000
    ind = run_fleet_episode(energy_ucb(), p, jax.random.key(0), n, steps, coordinated=False)
    coo = run_fleet_episode(energy_ucb(), p, jax.random.key(0), n, steps, coordinated=True)
    # coordinated gang never pays max-over-nodes exploration time
    assert float(coo["gang_time_s"]) <= float(ind["gang_time_s"]) * 1.01
    assert float(coo["switches"]) <= float(ind["switches"])
    # both should save energy vs default on a memory-bound app
    from repro.core import static_energy_kj

    e_def = static_energy_kj(p, 8) * n
    assert float(coo["energy_kj"]) < e_def


def test_fleet_kernel_matches_policy_select():
    """The fused Pallas fleet_select agrees with per-controller select."""
    from repro.kernels import ops

    pol = energy_ucb(alpha=0.2, switching_penalty=0.05)
    f = Fleet(pol, n=64)
    states = f.init(jax.random.key(0))
    # simulate some observations to desynchronize controllers
    states = {
        **states,
        "mu": jax.random.normal(jax.random.key(1), (64, 9)) * -1.0,
        "n": jax.random.randint(jax.random.key(2), (64, 9), 1, 30).astype(jnp.float32),
        "t": jnp.full((64,), 50.0),
        "prev": jax.random.randint(jax.random.key(3), (64,), 0, 9),
    }
    arms_policy = f.select(states, jax.random.key(4))
    arms_kernel = ops.fleet_select(
        states["mu"], states["n"], states["prev"], states["t"],
        alpha=0.2, lam=0.05, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(arms_policy), np.asarray(arms_kernel))
