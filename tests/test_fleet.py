"""Fleet control plane: vmapped controllers, coordinated gang mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy_ucb, get_app, make_env_params
from repro.core.fleet import Fleet, run_fleet_episode


def test_fleet_vmap_states():
    f = Fleet(energy_ucb(), n=32)
    states = f.init(jax.random.key(0))
    assert states["mu"].shape == (32, 9)
    arms = f.select(states, jax.random.key(1))
    assert arms.shape == (32,)
    assert ((arms >= 0) & (arms < 9)).all()


def test_coordinated_fewer_gang_switches_and_time():
    p = make_env_params(get_app("miniswp"))
    n = 8
    steps = 3000
    ind = run_fleet_episode(energy_ucb(), p, jax.random.key(0), n, steps, coordinated=False)
    coo = run_fleet_episode(energy_ucb(), p, jax.random.key(0), n, steps, coordinated=True)
    # coordinated gang never pays max-over-nodes exploration time
    assert float(coo["gang_time_s"]) <= float(ind["gang_time_s"]) * 1.01
    assert float(coo["switches"]) <= float(ind["switches"])
    # both should save energy vs default on a memory-bound app
    from repro.core import static_energy_kj

    e_def = static_energy_kj(p, 8) * n
    assert float(coo["energy_kj"]) < e_def


def test_fleet_kernel_matches_policy_select():
    """The fused Pallas fleet_select agrees with per-controller select."""
    from repro.kernels import ops

    pol = energy_ucb(alpha=0.2, switching_penalty=0.05)
    f = Fleet(pol, n=64)
    states = f.init(jax.random.key(0))
    # simulate some observations to desynchronize controllers
    states = {
        **states,
        "mu": jax.random.normal(jax.random.key(1), (64, 9)) * -1.0,
        "n": jax.random.randint(jax.random.key(2), (64, 9), 1, 30).astype(jnp.float32),
        "t": jnp.full((64,), 50.0),
        "prev": jax.random.randint(jax.random.key(3), (64,), 0, 9),
    }
    arms_policy = f.select(states, jax.random.key(4))
    arms_kernel = ops.fleet_select(
        states["mu"], states["n"], states["prev"], states["t"],
        alpha=0.2, lam=0.05, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(arms_policy), np.asarray(arms_kernel))


def _synth_obs(n, key, frac_active=0.85):
    from repro.core.simulator import Obs

    f = lambda i: jax.random.fold_in(key, i)
    return Obs(
        energy_j=jax.random.uniform(f(0), (n,), minval=10.0, maxval=30.0),
        uc=jax.random.uniform(f(1), (n,), minval=0.5, maxval=1.0),
        uu=jax.random.uniform(f(2), (n,), minval=0.1, maxval=0.5),
        progress=jax.random.uniform(f(3), (n,), minval=1e-4, maxval=2e-4),
        reward=-jax.random.uniform(f(4), (n,), minval=0.5, maxval=1.5),
        switched=jnp.zeros((n,), bool),
        active=jax.random.uniform(f(5), (n,)) < frac_active,
    )


# 7 = sub-stripe, 1024 = one stripe, 2049 = Aurora's 63,720 capped small
# (ragged: forces the pad-and-slice path)
@pytest.mark.parametrize("n", [7, 1024, 2049])
def test_fleet_dispatches_fused_step_matching_vmap(n):
    """Fleet.step through the fused Pallas kernel (interpret mode) is
    exact vs the vmapped per-controller update-then-select path."""
    pol = energy_ucb()
    fused = Fleet(pol, n, interpret=True)
    assert fused.use_kernel, "kernel-compatible policy must auto-dispatch"
    vmapped = Fleet(pol, n, use_kernel=False)
    states = fused.init(jax.random.key(0))
    arms = fused.select(states, jax.random.key(1))
    # advance a few desynchronizing intervals through the reference path
    for i in range(3):
        states, arms = vmapped.step(states, arms, _synth_obs(n, jax.random.key(10 + i)),
                                    jax.random.key(20 + i))
    obs = _synth_obs(n, jax.random.key(2))
    s_k, a_k = fused.step(states, arms, obs)
    s_v, a_v = vmapped.step(states, arms, obs, jax.random.key(3))
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_v))
    for leaf in states:
        np.testing.assert_array_equal(
            np.asarray(s_k[leaf]), np.asarray(s_v[leaf]),
            err_msg=f"fused fleet step diverged on {leaf} (n={n})")


def test_fleet_per_node_alpha_lanes():
    """Hyperparams-as-data across the fleet itself: per-controller
    alpha/lam lanes work on both the vmapped and fused paths and agree."""
    n = 33
    base = energy_ucb()
    pol = base.with_params(base.params._replace(
        alpha=jnp.linspace(0.05, 0.3, n), lam=jnp.linspace(0.0, 0.05, n)))
    fused = Fleet(pol, n, interpret=True)
    assert fused.use_kernel
    vmapped = Fleet(pol, n, use_kernel=False)
    states = vmapped.init(jax.random.key(0))
    arms = vmapped.select(states, jax.random.key(1))
    for i in range(4):
        states, arms = vmapped.step(states, arms,
                                    _synth_obs(n, jax.random.key(30 + i)),
                                    jax.random.key(40 + i))
    obs = _synth_obs(n, jax.random.key(5))
    s_k, a_k = fused.step(states, arms, obs)
    s_v, a_v = vmapped.step(states, arms, obs, jax.random.key(6))
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_v))
    for leaf in states:
        np.testing.assert_array_equal(np.asarray(s_k[leaf]), np.asarray(s_v[leaf]))


def test_fleet_step_vmap_path_requires_key():
    from repro.core import eps_greedy

    pol = eps_greedy()  # not kernel-compatible -> vmap path
    f = Fleet(pol, 4)
    states = f.init(jax.random.key(0))
    arms = f.select(states, jax.random.key(1))
    with pytest.raises(ValueError, match="per-interval key"):
        f.step(states, arms, _synth_obs(4, jax.random.key(2)))


def test_fleet_kernel_dispatch_gating():
    """Only exact-kernel policies may route to the fused step — which is
    now the ENTIRE EnergyUCB family: the QoS feasible-set lane (PR 3)
    plus the nonstationary gamma/optimistic lanes (PR 5) cover every
    variant; only non-UCB families and config-stacked params vmap."""
    from repro.core.fleet import kernel_compatible
    from repro.core.policies import stack_policy_params, make_policy_params

    assert kernel_compatible(energy_ucb())
    assert kernel_compatible(energy_ucb(qos_delta=0.05))
    assert kernel_compatible(energy_ucb(qos_delta=0.0))  # strictest budget
    # the nonstationary fleets used to silently fall off the fast path
    assert kernel_compatible(energy_ucb(window_discount=0.99))
    assert kernel_compatible(energy_ucb(window_discount=0.0))
    assert kernel_compatible(energy_ucb(optimistic_init=False))
    assert kernel_compatible(
        energy_ucb(window_discount=0.95, optimistic_init=False,
                   qos_delta=0.05))
    from repro.core import rr_freq

    assert not kernel_compatible(rr_freq())
    # extra batch axes (beyond per-node lanes) are not fleet policies
    batched = energy_ucb().with_params(
        make_policy_params()._replace(alpha=jnp.zeros((4, 2))))
    assert not kernel_compatible(batched)
    assert Fleet(energy_ucb(qos_delta=0.05), 8, interpret=True).use_kernel
    assert Fleet(energy_ucb(window_discount=0.99), 8,
                 interpret=True).use_kernel
    assert Fleet(energy_ucb(optimistic_init=False), 8,
                 interpret=True).use_kernel


# ragged sub-stripe and a non-multiple above one stripe
@pytest.mark.parametrize("n", [7, 1030])
def test_fleet_mixed_nonstationary_lanes_fused_matches_vmapped(n):
    """The acceptance oracle: a fleet MIXING stationary, sliding-window
    (spread of gamma < 1), round-robin warm-up, per-node alpha and QoS
    lanes dispatches one fused launch and stays bit-identical to the
    vmapped per-controller path across several desynchronizing steps."""
    base = energy_ucb()
    gamma = jnp.where(jnp.arange(n) % 2 == 0,
                      jnp.linspace(0.9, 0.999, n).astype(jnp.float32), 1.0)
    pol = base.with_params(base.params._replace(
        gamma=gamma,
        optimistic=jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0),
        alpha=jnp.linspace(0.05, 0.3, n).astype(jnp.float32),
        qos_delta=jnp.where(jnp.arange(n) % 4 == 0, 0.05, -1.0),
    ))
    fused = Fleet(pol, n, interpret=True)
    assert fused.use_kernel, "nonstationary fleets must dispatch fused now"
    vmapped = Fleet(pol, n, use_kernel=False)
    states = vmapped.init(jax.random.key(0))
    arms = vmapped.select(states, jax.random.key(1))
    s_k, s_v = states, states
    a_k, a_v = arms, arms
    for i in range(6):
        obs = _synth_obs(n, jax.random.key(70 + i))
        s_k, a_k = fused.step(s_k, a_k, obs)
        s_v, a_v = vmapped.step(s_v, a_v, obs, jax.random.key(80 + i))
        np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_v),
                                      err_msg=f"arms diverged at step {i}")
        for leaf in s_k:
            np.testing.assert_array_equal(
                np.asarray(s_k[leaf]), np.asarray(s_v[leaf]),
                err_msg=f"mixed-lane fused step diverged on {leaf} "
                        f"(n={n}, step {i})")


def test_fleet_per_node_gamma_lane_only_discounts_its_rows():
    """A per-node gamma lane is honored row-by-row on the vmapped path
    (regression: _params_axes used to broadcast gamma, so a (N,) lane
    would have collided with the (K,) arm axis inside ucb_update)."""
    n = 5
    base = energy_ucb()
    pol = base.with_params(base.params._replace(
        gamma=jnp.asarray([0.9, 1.0, 0.5, 1.0, 0.99], jnp.float32)))
    f = Fleet(pol, n, use_kernel=False)
    states = f.init(jax.random.key(0))
    states = {**states, "n": jnp.full((n, 9), 4.0)}
    obs = _synth_obs(n, jax.random.key(1), frac_active=1.0)
    arms = jnp.zeros((n,), jnp.int32)
    new = f.update(states, arms, obs)
    tot = np.asarray(new["n"]).sum(axis=1)
    # discounted rows: every arm decays to 4*gamma, then the pulled arm
    # gains the new sample; stationary rows just gain the sample
    want = np.asarray([36 * 0.9 + 1, 36 + 1, 36 * 0.5 + 1, 36 + 1,
                       36 * 0.99 + 1])
    np.testing.assert_allclose(tot, want, rtol=1e-6)


# ragged sub-stripe and a non-multiple above one stripe
@pytest.mark.parametrize("n", [7, 1030])
def test_fleet_qos_lanes_fused_matches_vmapped(n):
    """Constrained fleets dispatch fused and stay bit-identical to the
    vmapped path, with MIXED per-node budgets: sentinel-off (-1), a 0.0
    strictest budget, and a spread of positive deltas, plus per-node
    reference arms."""
    base = energy_ucb(qos_delta=0.05)
    qos = jnp.where(jnp.arange(n) % 3 == 0, -1.0,
                    jnp.linspace(0.0, 0.1, n).astype(jnp.float32))
    da = (jnp.arange(n) % 9).astype(jnp.int32)
    pol = base.with_params(base.params._replace(qos_delta=qos, default_arm=da))
    fused = Fleet(pol, n, interpret=True)
    assert fused.use_kernel, "constrained fleets must dispatch fused now"
    vmapped = Fleet(pol, n, use_kernel=False)
    states = vmapped.init(jax.random.key(0))
    arms = vmapped.select(states, jax.random.key(1))
    for i in range(5):
        states, arms = vmapped.step(states, arms,
                                    _synth_obs(n, jax.random.key(50 + i)),
                                    jax.random.key(60 + i))
    obs = _synth_obs(n, jax.random.key(7))
    s_k, a_k = fused.step(states, arms, obs)
    s_v, a_v = vmapped.step(states, arms, obs, jax.random.key(8))
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_v))
    for leaf in states:
        np.testing.assert_array_equal(
            np.asarray(s_k[leaf]), np.asarray(s_v[leaf]),
            err_msg=f"constrained fused step diverged on {leaf} (n={n})")
