import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, get_reduced, list_archs

EXPECTED_PARAMS_B = {
    "starcoder2-15b": (14, 18),
    "qwen2.5-3b": (2.5, 3.6),
    "llama3-405b": (390, 420),
    "qwen3-1.7b": (1.4, 2.1),
    "mamba2-2.7b": (2.4, 3.1),
    "llama4-maverick-400b-a17b": (380, 420),
    "granite-moe-1b-a400m": (1.0, 1.7),
    "seamless-m4t-large-v2": (1.2, 2.4),
    "pixtral-12b": (11, 14),
    "zamba2-7b": (6, 8),
}


def test_all_ten_archs_registered():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("name", list(EXPECTED_PARAMS_B))
def test_param_counts_match_model_names(name):
    lo, hi = EXPECTED_PARAMS_B[name]
    n = get_arch(name).param_count() / 1e9
    assert lo <= n <= hi, f"{name}: {n:.1f}B outside [{lo},{hi}]"


def test_active_params_moe():
    c = get_arch("llama4-maverick-400b-a17b")
    assert 14 <= c.active_param_count() / 1e9 <= 20
    g = get_arch("granite-moe-1b-a400m")
    assert 0.25 <= g.active_param_count() / 1e9 <= 0.6


def test_padded_vocab_divisible():
    for a in list_archs():
        c = get_arch(a)
        assert c.padded_vocab % 256 == 0
        assert c.padded_vocab >= c.vocab_size


def test_shape_registry():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_only_subquadratic():
    long_archs = [a for a in list_archs() if "long_500k" in get_arch(a).supported_shapes()]
    assert sorted(long_archs) == ["mamba2-2.7b", "zamba2-7b"]


def test_cell_count():
    cells = sum(len(get_arch(a).supported_shapes()) for a in list_archs())
    assert cells == 32  # 10*3 + 2 long-context


def test_reduced_configs_are_small():
    for a in list_archs():
        r = get_reduced(a)
        assert r.d_model <= 128 and r.num_layers <= 8


def test_layout_overrides_apply():
    c = get_arch("qwen3-1.7b")
    assert c.layout_for("train_4k").parallelism == "fsdp"
    assert c.layout_for("decode_32k").parallelism == "serve"
    assert c.layout_for("decode_32k").decode_logits_bf16
    assert get_arch("llama3-405b").layout_for("decode_32k").parallelism == "serve2d"
