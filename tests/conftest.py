# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single device; only the dry-run
# launcher forces 512 (in its own process).
import os

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
