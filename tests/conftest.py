# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single device; only the dry-run
# launcher forces 512 (in its own process).
import os

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    # Implicit rank promotion (e.g. a (N,) lane silently broadcasting
    # against a (N, K) table) is the apply_arms hard-reshape class of
    # bug: shapes line up by accident and the wrong axis gets the data.
    # Raise on it everywhere in the test suite; production code must
    # broadcast explicitly. (The sanitize lane additionally sets this
    # via JAX_NUMPY_RANK_PROMOTION for non-pytest entry points.)
    import jax

    jax.config.update("jax_numpy_rank_promotion", "raise")
