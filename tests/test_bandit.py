"""EnergyUCB behavior: optimism, convergence, switching suppression,
QoS feasibility, ablations (paper §4.2-4.6 claims as assertions)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    energy_ucb,
    eps_greedy,
    energy_ts,
    expected_rewards,
    get_app,
    make_env_params,
    rr_freq,
    run_episode,
    run_repeats,
    TABLE1_KJ,
)


def test_optimistic_init_tries_all_arms():
    p = make_env_params(get_app("clvleaf"))
    out = run_episode(energy_ucb(), p, jax.random.key(0), max_steps=2000)
    arms = np.asarray(out["arms"])[: int(out["steps"])]
    assert len(np.unique(arms)) == 9  # every frequency explored


def test_converges_to_best_arm():
    name = "miniswp"
    p = make_env_params(get_app(name))
    out = run_episode(energy_ucb(), p, jax.random.key(0))
    arms = np.asarray(out["arms"])[: int(out["steps"])]
    tail = arms[len(arms) // 2 :]
    best = int(np.argmin(TABLE1_KJ[name]))
    frac_best = np.mean(tail == best)
    assert frac_best > 0.8, f"tail fraction on best arm {frac_best:.2f}"


@pytest.mark.slow
def test_switching_penalty_reduces_switches():
    p = make_env_params(get_app("llama"))
    with_pen = run_repeats(energy_ucb(switching_penalty=0.05), p, jax.random.key(1), 3)
    no_pen = run_repeats(energy_ucb(switching_penalty=0.0), p, jax.random.key(1), 3)
    ratio = no_pen["switches"].mean() / max(with_pen["switches"].mean(), 1)
    assert ratio > 3.0, f"penalty only cut switches {ratio:.1f}x (paper: 6.7x)"


def test_regret_sublinear_vs_rrfreq():
    # miniswp has clear per-arm gaps; tealeaf's are sub-1% (flat landscape)
    p = make_env_params(get_app("miniswp"))
    ucb = run_episode(energy_ucb(), p, jax.random.key(0))
    rr = run_episode(rr_freq(), p, jax.random.key(0))
    T = int(min(ucb["steps"], rr["steps"])) - 1
    cu, cr = np.asarray(ucb["cum_regret"]), np.asarray(rr["cum_regret"])
    assert cu[T] < 0.2 * cr[T]
    # sublinear: second-half regret growth much smaller than first half
    assert (cu[T] - cu[T // 2]) < 0.6 * cu[T // 2]


def test_regret_beats_rrfreq_even_on_flat_landscape():
    p = make_env_params(get_app("tealeaf"))
    ucb = run_episode(energy_ucb(), p, jax.random.key(0))
    rr = run_episode(rr_freq(), p, jax.random.key(0))
    T = int(min(ucb["steps"], rr["steps"])) - 1
    assert np.asarray(ucb["cum_regret"])[T] < 0.4 * np.asarray(rr["cum_regret"])[T]


def test_qos_constrained_respects_budget():
    name = "clvleaf"  # strongly compute-bound: unconstrained slows a lot
    p = make_env_params(get_app(name))
    delta = 0.05
    out = run_repeats(energy_ucb(qos_delta=delta), p, jax.random.key(0), 5)
    t_base = float(p.t_ref_s)
    slowdown = out["time_s"].mean() / t_base - 1.0
    assert slowdown <= delta + 0.02, f"slowdown {slowdown:.3f} > budget {delta}"
    # and still saves energy vs f_max default
    assert out["energy_kj"].mean() <= TABLE1_KJ[name][-1] * 1.01


def test_qos_all_feasible_until_reference_arm_sampled():
    """Regression: with no progress samples on the reference arm,
    p_ref = inf gave every TRIED arm slowdown 1.0 (infeasible), so the
    controller could only ever pick untried arms. Until the reference
    arm has >= 1 sample the whole ladder must stay feasible."""
    import jax.numpy as jnp

    pol = energy_ucb(qos_delta=0.05)
    state = pol.init(jax.random.key(0))
    k = state["mu"].shape[0]
    # arms 0..k-2 tried and accurately estimated, arm 0 clearly best;
    # the reference arm (k-1) has NO progress samples yet
    state = {
        **state,
        "mu": jnp.where(jnp.arange(k) == 0, -0.1, -1.0),
        "n": jnp.where(jnp.arange(k) < k - 1, 20.0, 0.0),
        "phat": jnp.where(jnp.arange(k) < k - 1, 2e-4, 0.0),
        "pn": jnp.where(jnp.arange(k) < k - 1, 20.0, 0.0),
        "prev": jnp.int32(0),
        "t": jnp.float32(150.0),
    }
    arm = int(pol.select(state, jax.random.key(1)))
    assert arm == 0, (
        f"select picked {arm}: tried arms must stay feasible while the "
        "reference arm is unsampled")


def test_unconstrained_beats_constrained_on_energy():
    p = make_env_params(get_app("clvleaf"))
    unc = run_repeats(energy_ucb(), p, jax.random.key(2), 3)["energy_kj"].mean()
    con = run_repeats(energy_ucb(qos_delta=0.05), p, jax.random.key(2), 3)[
        "energy_kj"
    ].mean()
    assert unc <= con * 1.02


@pytest.mark.slow
def test_ablation_optimistic_init_helps():
    p = make_env_params(get_app("sph_exa"))
    with_oi = run_repeats(energy_ucb(), p, jax.random.key(3), 3)["energy_kj"].mean()
    without = run_repeats(
        energy_ucb(optimistic_init=False), p, jax.random.key(3), 3
    )["energy_kj"].mean()
    assert with_oi <= without + 1.0  # kJ


def test_policies_state_invariants():
    p = make_env_params(get_app("weather"))
    out = run_episode(energy_ucb(), p, jax.random.key(0), max_steps=500)
    st = out["pstate"]
    n = np.asarray(st["n"])
    assert n.sum() == pytest.approx(float(st["t"]), abs=0.5)
    assert (n >= 0).all()
    mu = np.asarray(st["mu"])
    assert (mu <= 0.05).all()  # rewards are negative


@pytest.mark.parametrize("mk", [eps_greedy, energy_ts])
def test_dynamic_baselines_complete(mk):
    p = make_env_params(get_app("weather"))
    out = run_repeats(mk(), p, jax.random.key(0), 2)
    assert out["completed"].all()
    assert (out["energy_kj"] > 0).all()
