"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYP = True
except Exception:  # hypothesis not installed
    HAVE_HYP = False
    # The @settings/@given decorators below run at import time, so a
    # skipif mark alone still crashes collection — skip the module
    # before any decorator is evaluated.
    pytest.skip("hypothesis unavailable", allow_module_level=True)

pytestmark = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis unavailable")

from repro.core import energy_ucb, get_app, make_env_params, env_init, env_step
from repro.core.simulator import Obs
from repro.parallel.sharding import DEFAULT_RULES, spec_for_axes


@settings(max_examples=30, deadline=None)
@given(
    rewards=st.lists(st.floats(-3.0, -0.01), min_size=5, max_size=40),
    arms=st.lists(st.integers(0, 8), min_size=5, max_size=40),
)
def test_ucb_counts_and_means_bounded(rewards, arms):
    n = min(len(rewards), len(arms))
    pol = energy_ucb()
    s = pol.init(jax.random.key(0))
    for r, a in zip(rewards[:n], arms[:n]):
        obs = Obs(
            energy_j=jnp.float32(1.0), uc=jnp.float32(0.9), uu=jnp.float32(0.3),
            progress=jnp.float32(1e-4), reward=jnp.float32(r),
            switched=jnp.bool_(False), active=jnp.bool_(True),
        )
        s = pol.update(s, jnp.int32(a), obs)
    cnt = np.asarray(s["n"])
    assert cnt.sum() == pytest.approx(n)
    mu = np.asarray(s["mu"])
    seen = np.unique(np.asarray(arms[:n]))
    lo, hi = min(rewards[:n]), max(rewards[:n])
    for a in seen:
        assert lo - 1e-5 <= mu[a] <= hi + 1e-5 or mu[a] == 0.0


@settings(max_examples=20, deadline=None)
@given(delta=st.floats(0.0, 0.5))
def test_feasible_set_monotone_in_delta(delta):
    """A larger slowdown budget never shrinks the feasible set."""
    pol_a = energy_ucb(qos_delta=delta)
    pol_b = energy_ucb(qos_delta=min(delta + 0.1, 0.9))
    s = pol_a.init(jax.random.key(0))
    # fabricate progress estimates
    phat = jnp.linspace(0.5, 1.0, 9)
    s = {**s, "phat": phat, "pn": jnp.ones(9)}
    slow = 1.0 - phat / phat[8]
    feas_a = (slow <= delta)
    feas_b = (slow <= min(delta + 0.1, 0.9))
    assert bool(jnp.all(feas_b | ~feas_a))


@settings(max_examples=25, deadline=None)
@given(
    arm=st.integers(0, 8),
    seed=st.integers(0, 2**30),
)
def test_env_step_invariants(arm, seed):
    p = make_env_params(get_app("pot3d"))
    s = env_init(p)
    s2, obs = env_step(p, s, jnp.int32(arm), jax.random.key(seed))
    assert float(obs.energy_j) > 0
    assert 0 < float(obs.uc) <= 1
    assert 0 < float(obs.uu) <= 1
    assert float(obs.reward) < 0
    assert float(s2.remaining) <= 1.0
    assert float(s2.energy_kj) >= 0


@settings(max_examples=40, deadline=None)
@given(
    axes=st.lists(
        st.sampled_from([None, "batch", "heads", "tp", "vocab", "embed_fsdp", "seq"]),
        min_size=1,
        max_size=4,
    )
)
def test_spec_never_reuses_mesh_axis(axes):
    spec = spec_for_axes(axes, DEFAULT_RULES, ("pod", "data", "model"))
    used = []
    for e in tuple(spec):
        if e is None:
            continue
        used.extend(e if isinstance(e, tuple) else (e,))
    assert len(used) == len(set(used))
