import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


@pytest.mark.parametrize("name", ["qwen3-1.7b", "mamba2-2.7b"])
def test_engine_generates(name):
    cfg = get_reduced(name)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    eng = ServeEngine(bundle, params, n_slots=2, max_len=64)
    reqs = [
        Request(0, np.arange(5, dtype=np.int32) + 3, max_new=4),
        Request(1, np.arange(7, dtype=np.int32) + 11, max_new=6),
        Request(2, np.arange(3, dtype=np.int32) + 2, max_new=3),
    ]
    done = eng.generate(reqs)
    assert [len(r.out) for r in done] == [4, 6, 3]
    for r in done:
        assert all(0 <= t < cfg.padded_vocab for t in r.out)


def test_engine_greedy_matches_prefill_path():
    """First generated token == argmax of the prefill logits (greedy)."""
    cfg = get_reduced("starcoder2-15b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(1))
    prompt = np.arange(6, dtype=np.int32) + 1
    eng = ServeEngine(bundle, params, n_slots=1, max_len=32)
    [req] = eng.generate([Request(0, prompt, max_new=2)])
    logits, _ = jax.jit(bundle.prefill)(params, {"tokens": jnp.asarray(prompt)[None]})
    want = int(jnp.argmax(logits[0, : cfg.vocab_size]))
    assert req.out[0] == want


def test_engine_with_energy_controller():
    from repro.core.policies import energy_ucb
    from repro.energy import EnergyController, StepEnergyModel, make_backend

    cfg = get_reduced("qwen2.5-3b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    m = StepEnergyModel(t_compute_s=0.02, t_memory_s=0.08, t_collective_s=0.01,
                        n_chips=1, steps_total=100)
    ctl = EnergyController(energy_ucb(), make_backend(m))
    eng = ServeEngine(bundle, params, n_slots=2, max_len=32, controller=ctl)
    eng.generate([Request(0, np.arange(4, dtype=np.int32), max_new=5)])
    assert len(ctl.history) >= 5


def test_engine_stats_telemetry():
    """The upgraded stats surface: decode tokens, per-wave wall time,
    and queue depth — and the removed energy_runtime kwarg is gone."""
    import pytest

    cfg = get_reduced("qwen2.5-3b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    eng = ServeEngine(bundle, params, n_slots=2, max_len=32)
    done = eng.generate(
        [Request(i, np.arange(4, dtype=np.int32), max_new=5) for i in range(3)]
    )
    st = eng.stats
    assert st["decode_tokens"] == sum(len(r.out) for r in done) > 0
    assert st["wave_time_s"] >= st["last_wave_s"] > 0
    assert st["queue_depth"] == 0  # drained
    with pytest.raises(TypeError):
        ServeEngine(bundle, params, n_slots=2, max_len=32,
                    energy_runtime=None)
