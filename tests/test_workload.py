"""Tests for the workload subsystem: traffic determinism, the
ServingBackend's EnergyBackend contract, phase-split lanes, trace
round-trips, and the serving headline claims at small scale."""
import numpy as np
import pytest

import jax

from repro.core import (
    energy_ucb,
    interleave_policy_params,
    make_policy_params,
    phase_policy,
    static_policy,
)
from repro.core.calibration import FREQS_GHZ
from repro.core.fleet import kernel_compatible, slice_policy_lanes
from repro.energy import EnergyController, TraceReplayBackend
from repro.energy.backend import record_trace
from repro.workload import (
    ServingBackend,
    TrafficGen,
    bursty_diurnal_traffic,
    bursty_traffic,
    concat_intervals,
    poisson_traffic,
)

K = len(FREQS_GHZ)
MODEL = "qwen2.5-3b"


# ---------------------------------------------------------------------------
# traffic determinism
# ---------------------------------------------------------------------------


def _rows_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.offsets_s, rb.offsets_s)
        np.testing.assert_array_equal(ra.prompt_len, rb.prompt_len)
        np.testing.assert_array_equal(ra.output_len, rb.output_len)


@pytest.mark.parametrize("cfg", [poisson_traffic(8.0),
                                 bursty_diurnal_traffic(5.0, seed=3)])
def test_traffic_chunked_vs_oneshot_bit_identical(cfg):
    one = TrafficGen(cfg, node_id=1).take(50)
    for chunks in ([7, 13, 1, 29], [50], [25, 25]):
        gen = TrafficGen(cfg, node_id=1)
        rows = []
        for c in chunks:
            rows.extend(gen.take(c))
        _rows_equal(rows, one)


def test_traffic_skip_matches_generate():
    cfg = bursty_traffic(6.0, seed=7)
    full = TrafficGen(cfg, node_id=0).take(40)
    gen = TrafficGen(cfg, node_id=0, start_interval=25)
    assert gen.interval_index == 25
    _rows_equal(gen.take(15), full[25:])


def test_traffic_nodes_are_distinct_streams():
    cfg = poisson_traffic(20.0, seed=1)
    a = concat_intervals(TrafficGen(cfg, node_id=0).take(20), cfg.interval_s)
    b = concat_intervals(TrafficGen(cfg, node_id=1).take(20), cfg.interval_s)
    assert a.offsets_s.shape != b.offsets_s.shape or not np.array_equal(
        a.offsets_s, b.offsets_s)


def test_traffic_mean_rate_counts_burst_duty():
    cfg = bursty_traffic(4.0, mult=3.0, on_mean=16.0, off_mean=48.0)
    assert cfg.mean_rate_rps == pytest.approx(4.0 * 1.5)
    rows = TrafficGen(cfg, node_id=0).take(4000)
    emp = sum(len(r.offsets_s) for r in rows) / (4000 * cfg.interval_s)
    assert emp == pytest.approx(cfg.mean_rate_rps, rel=0.1)


# ---------------------------------------------------------------------------
# ServingBackend: EnergyBackend contract + determinism
# ---------------------------------------------------------------------------


def _drive(be, schedule):
    """Apply a (T, N) arm schedule, returning stacked counters."""
    outs = []
    for arms in schedule:
        be.apply_arms(np.asarray(arms, np.int32))
        be.advance()
        outs.append(be.read_counters())
    return outs


def test_serving_backend_counters_monotone_and_deterministic():
    traf = bursty_diurnal_traffic(seed=2)
    rng = np.random.default_rng(0)
    sched = rng.integers(0, K, size=(30, 2))
    a = _drive(ServingBackend(traf, MODEL, n_nodes=2), sched)
    b = _drive(ServingBackend(traf, MODEL, n_nodes=2), sched)
    for ca, cb in zip(a, b):
        for f in ("energy_j", "core_active_s", "uncore_active_s",
                  "timestamp_s", "progress", "switches"):
            np.testing.assert_array_equal(getattr(ca, f), getattr(cb, f))
    for prev, cur in zip(a, a[1:]):
        assert np.all(cur.energy_j >= prev.energy_j)
        assert np.all(cur.progress >= prev.progress)
        assert np.all(cur.timestamp_s > prev.timestamp_s)


def test_serving_backend_local_slice_matches_full():
    traf = poisson_traffic(10.0, seed=5)
    sched = np.random.default_rng(1).integers(0, K, size=(20, 4))
    full = _drive(ServingBackend(traf, MODEL, n_nodes=4), sched)[-1]
    lo_be = ServingBackend(traf, MODEL, n_nodes=4).local_slice(0, 2)
    hi_be = ServingBackend(traf, MODEL, n_nodes=4).local_slice(2, 4)
    lo = _drive(lo_be, sched[:, :2])[-1]
    hi = _drive(hi_be, sched[:, 2:])[-1]
    for f in ("energy_j", "core_active_s", "uncore_active_s", "progress"):
        np.testing.assert_allclose(
            np.concatenate([getattr(lo, f), getattr(hi, f)]),
            getattr(full, f), rtol=0, atol=0)


def test_serving_backend_phase_split_lanes():
    traf = bursty_diurnal_traffic(seed=4)
    be = ServingBackend(traf, MODEL, n_nodes=2, phase_split=True)
    assert be.n_nodes == 4 and be.n_serve_nodes == 2
    # prefill lanes fixed at f_max, decode lanes at the lowest arm:
    # decode stays cheap (bandwidth-bound) and progress stays ~1
    sched = np.tile(np.array([K - 1, 0, K - 1, 0]), (60, 1))
    c = _drive(be, sched)[-1]
    e = c.energy_j
    assert e.shape == (4,)
    # decode-lane slowdown vs f_max is small: R = core/uncore ~ 1
    r_dec = c.core_active_s[1::2] / np.maximum(c.uncore_active_s[1::2], 1e-9)
    assert np.all(r_dec < 1.1)
    # prefill lanes at f_max have R == 1 by construction
    r_pre = c.core_active_s[0::2] / np.maximum(c.uncore_active_s[0::2], 1e-9)
    np.testing.assert_allclose(r_pre, 1.0, rtol=1e-6)
    # split lanes must require even-aligned slices
    with pytest.raises(ValueError):
        be.local_slice(1, 3)


def test_serving_trace_roundtrip_replays_arm_for_arm(tmp_path):
    """Live controller run -> record_trace on a fresh backend with the
    SAME arm schedule -> save/load npz -> TraceReplayBackend replay
    selects the same arms (observation-determined policy)."""
    traf = bursty_diurnal_traffic(seed=6)
    pol = energy_ucb()
    live = EnergyController(pol, ServingBackend(traf, MODEL, n_nodes=2),
                            use_kernel=False)
    arms = []
    for _ in range(40):
        live.step()
        arms.append(np.asarray(live.last_arms))
    arms = np.stack(arms)

    trace = record_trace(ServingBackend(traf, MODEL, n_nodes=2), arms)
    path = str(tmp_path / "serve_trace.npz")
    trace.save(path)
    replay = TraceReplayBackend.load(path)
    ctl = EnergyController(pol, replay, use_kernel=False)
    replayed = []
    for _ in range(40):
        ctl.step()
        replayed.append(np.asarray(ctl.last_arms))
    np.testing.assert_array_equal(np.stack(replayed), arms)


def test_serving_backend_fused_vs_vmapped_parity():
    """The fused-vs-reference bit-parity contract extends to the
    serving backend: interpret-mode fused fleet_step and the vmapped
    path pick identical arms on a phase-split fleet."""
    traf = bursty_diurnal_traffic(seed=8)
    pol = phase_policy(2, prefill=make_policy_params(qos_delta=0.01),
                       decode=make_policy_params(qos_delta=None))
    assert kernel_compatible(pol)

    def arms_with(use_kernel, interpret):
        be = ServingBackend(traf, MODEL, n_nodes=2, phase_split=True)
        ctl = EnergyController(pol, be, use_kernel=use_kernel,
                               interpret=interpret)
        out = []
        for _ in range(25):
            ctl.step()
            out.append(np.asarray(ctl.last_arms))
        return np.stack(out)

    np.testing.assert_array_equal(arms_with(False, False),
                                  arms_with(True, True))


# ---------------------------------------------------------------------------
# phase-lane helper
# ---------------------------------------------------------------------------


def test_interleave_policy_params_layout():
    pre = make_policy_params(qos_delta=0.01, alpha=0.2)
    dec = make_policy_params(qos_delta=None, alpha=0.05)
    p = interleave_policy_params(pre, dec, 3)
    np.testing.assert_allclose(p.qos_delta,
                               [0.01, -1.0, 0.01, -1.0, 0.01, -1.0])
    np.testing.assert_allclose(p.alpha, [0.2, 0.05] * 3)
    assert p.prior_mu.shape == (6, K)
    pol = phase_policy(3, prefill=pre, decode=dec)
    sl = slice_policy_lanes(pol, 2, 6, 6)
    np.testing.assert_allclose(sl.params.qos_delta, [0.01, -1.0, 0.01, -1.0])


# ---------------------------------------------------------------------------
# headline claims, small scale (the full-size run lives in
# benchmarks/serve_energy.py)
# ---------------------------------------------------------------------------


def test_serving_headline_claims_small():
    traf = bursty_diurnal_traffic()
    t_run, warm = 240, 80

    def run(policy, phase_split):
        be = ServingBackend(traf, MODEL, n_nodes=1, phase_split=phase_split)
        ctl = EnergyController(policy, be, use_kernel=False,
                               record_history=False)
        ctl.run(t_run)
        e = float(be.read_counters().energy_j.sum())
        rep = be.slo_report(warmup_s=warm * traf.interval_s)
        return e / max(be.served_tokens, 1), rep["violation_rate"]

    jpt_fmax, viol_fmax = run(static_policy(K - 1), False)
    jpt_low, viol_low = run(static_policy(0), False)
    jpt_ucb, _ = run(energy_ucb(), False)
    jpt_pq, viol_pq = run(
        phase_policy(1, prefill=make_policy_params(qos_delta=0.01),
                     decode=make_policy_params(qos_delta=None)), True)

    # static endpoints frame the trade: f_max compliant, lowest is not
    assert viol_fmax <= 0.05 < viol_low
    # unconstrained EnergyUCB saves energy vs the f_max baseline
    assert jpt_ucb < jpt_fmax
    # the phase-conditioned QoS config saves energy AND stays compliant
    assert jpt_pq < jpt_fmax and viol_pq <= 0.05
