"""Fleet-scale control plane: 63,720 controllers (10,620 Aurora nodes x
6 GPUs) advanced in lockstep, plus the coordinated gang mode for
synchronous data-parallel training.

  PYTHONPATH=src python examples/fleet_control.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy_ucb, get_app, make_env_params, static_energy_kj
from repro.core.fleet import Fleet, run_fleet_episode
from repro.kernels import ops


def main():
    n = 63_720
    fleet = Fleet(energy_ucb(), n)
    states = fleet.init(jax.random.key(0))
    arms = fleet.select(states, jax.random.key(1))  # warm up jit
    t0 = time.perf_counter()
    for i in range(10):
        arms = fleet.select(states, jax.random.key(i))
    jax.block_until_ready(arms)
    dt = (time.perf_counter() - t0) / 10
    print(f"fleet of {n} controllers: select {dt*1e3:.2f} ms/step "
          f"({dt/n*1e9:.0f} ns/controller, vmap)")

    arms_k = ops.fleet_select(
        states["mu"], states["n"], states["prev"],
        jnp.maximum(states["t"], 2.0),
        interpret=not ops.pallas_available(),
    )
    agree = float(jnp.mean((arms_k == fleet.select(states, jax.random.key(3))).astype(jnp.float32)))
    print(f"fused Pallas fleet kernel agrees with policy select: {agree:.3f}")

    # coordinated vs independent on a memory-bound app (8-node gang demo)
    p = make_env_params(get_app("miniswp"))
    nn, steps = 8, 12_000  # enough for miniswp to complete (~8.3k steps)
    ind = run_fleet_episode(energy_ucb(), p, jax.random.key(0), nn, steps, coordinated=False)
    coo = run_fleet_episode(energy_ucb(), p, jax.random.key(0), nn, steps, coordinated=True)
    e_def = static_energy_kj(p, 8) * nn
    print(f"\n{nn}-node gang on miniswp (energy vs all-nodes-f_max {e_def:.0f} kJ):")
    for name, out in (("independent", ind), ("coordinated", coo)):
        print(f"  {name:12s} energy={float(out['energy_kj']):8.1f} kJ  "
              f"gang_time={float(out['gang_time_s']):6.1f}s  "
              f"switches={int(out['switches'])}")
    print("coordinated mode: one arm for the gang -> no straggler coupling, "
          "1/N reward variance")


if __name__ == "__main__":
    main()
