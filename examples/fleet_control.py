"""Fleet-scale control plane: 63,720 controllers (10,620 Aurora nodes x
6 GPUs) advanced in lockstep through the fused select+update fleet
step, plus the coordinated gang mode for synchronous data-parallel
training — and the multi-process deployment shape, where H controller
processes each own a backend stripe (repro.parallel.distributed).

  PYTHONPATH=src python examples/fleet_control.py

The multi-process control plane also has its own CLI launcher
(repro.launch.fleet_serve): run one process per host with
``--num-hosts H --host-id h --coordinator host:port`` (plus ``--app``,
``--nodes``, ``--qos``, ``--window-discount``/``--warmup`` for the
nonstationary variants, ``--drift``/``--drift-every`` for cycling
workload phases, ``--trace`` for recorded-counter replay,
``--report-every`` for periodic fleet aggregates, and
``--checkpoint-dir``/``--checkpoint-every`` for periodic stripe
checkpoints — a SIGKILLed host relaunched with the same command line
resumes bit-exact and rejoins mid-run, see the kill-and-resume demo
below), or ``--spawn`` to fork all H hosts locally in one command:

  PYTHONPATH=src python -m repro.launch.fleet_serve --spawn \\
      --num-hosts 2 --nodes 64 --intervals 100 --report-every 25

``--workload serve`` swaps the calibrated simulator for the
request-driven serving workload (repro.workload): every node runs the
continuous-batching serve loop against its own seeded bursty-diurnal
traffic stream, QoS becomes a p99-latency SLO against the f_max
reference, and ``--phase-split`` gives each node separate prefill and
decode controller lanes (compute-bound prefill keeps the ``--qos``
slowdown budget; bandwidth-bound decode downclocks unconstrained —
the per-phase sweet spots). Same fused fleet step, same striping:

  PYTHONPATH=src python -m repro.launch.fleet_serve --spawn \\
      --num-hosts 2 --nodes 8 --intervals 200 --workload serve \\
      --phase-split --qos 0.01 --report-every 50

``--uncore-ladder 0.6,0.8,1.0`` factorizes the arms into a
(core x uncore) product ladder on either workload — same fused launch
over the flat index, per-dimension switching penalties via
``--lam-unc`` (omitted: one shared penalty, the scalar-compatible
sentinel). The demo below runs a mixed scalar/factored-penalty fleet
in one launch and an end-to-end factored controller with per-dimension
switch counts.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy_ucb, get_app, make_env_params, static_energy_kj
from repro.core.fleet import Fleet, run_fleet_episode
from repro.core.simulator import Obs
from repro.kernels import ops


def main():
    n = 63_720
    fleet = Fleet(energy_ucb(), n)
    states = fleet.init(jax.random.key(0))
    arms = fleet.select(states, jax.random.key(1))  # warm up jit
    kobs_keys = jax.random.split(jax.random.key(7), 3)
    obs = Obs(
        energy_j=jnp.full((n,), 20.0),
        uc=jax.random.uniform(kobs_keys[0], (n,), minval=0.6, maxval=1.0),
        uu=jax.random.uniform(kobs_keys[1], (n,), minval=0.2, maxval=0.5),
        progress=jnp.full((n,), 1e-4),
        reward=-jax.random.uniform(kobs_keys[2], (n,), minval=0.6, maxval=1.4),
        switched=jnp.zeros((n,), bool),
        active=jnp.ones((n,), bool),
    )
    states, arms = fleet.step(states, arms, obs, jax.random.key(10))  # warm up
    t0 = time.perf_counter()
    for i in range(10):
        states, arms = fleet.step(states, arms, obs, jax.random.key(11 + i))
    jax.block_until_ready(arms)
    dt = (time.perf_counter() - t0) / 10
    print(f"fleet of {n} controllers: fused update+select {dt*1e3:.2f} ms/interval "
          f"({dt/n*1e9:.0f} ns/controller, "
          f"{'pallas' if fleet.use_kernel else 'vmap fallback'})")

    # the fused Pallas kernel agrees with the per-controller policy path
    nk = 2048
    kern = Fleet(energy_ucb(), nk, use_kernel=True,
                 interpret=not ops.pallas_available())
    ref = Fleet(energy_ucb(), nk, use_kernel=False)
    ks = kern.init(jax.random.key(2))
    ka = kern.select(ks, jax.random.key(3))
    kobs = jax.tree.map(lambda x: x[:nk], obs)
    s1, a1 = kern.step(ks, ka, kobs)
    s2, a2 = ref.step(ks, ka, kobs, jax.random.key(4))
    agree = float(jnp.mean((a1 == a2).astype(jnp.float32)))
    print(f"fused Pallas fleet step agrees with vmapped policy: {agree:.3f}")

    # hyperparams-as-data: one fleet sweeps alpha per controller in the
    # SAME kernel launch — no per-config retrace. Desynchronize the
    # controllers first (every arm sampled with per-node noise) so the
    # alpha lanes actually disagree.
    for i in range(12):
        noisy = kobs._replace(
            reward=-jax.random.uniform(jax.random.key(100 + i), (nk,),
                                       minval=0.6, maxval=1.4),
            progress=jax.random.uniform(jax.random.key(200 + i), (nk,),
                                        minval=5e-5, maxval=2e-4))
        s1, a1 = kern.step(s1, a1, noisy)
    alphas = jnp.linspace(0.05, 0.3, nk)
    out = ops.fleet_step(
        s1["mu"], s1["n"], s1["phat"], s1["pn"], s1["prev"], s1["t"],
        a1, kobs.reward, kobs.progress, kobs.active.astype(jnp.float32),
        alphas, 0.02, interpret=not ops.pallas_available(),
    )
    print(f"per-controller alpha sweep ({nk} configs, one launch): "
          f"{len(np.unique(np.asarray(out[-1])))} distinct arms selected")

    # QoS budgets are lanes too: a mixed fleet (half unconstrained via
    # the -1 sentinel, half delta=0.02) dispatches in the same launch
    qos = jnp.where(jnp.arange(nk) % 2 == 0, -1.0, 0.02)
    f_max_arm = s1["mu"].shape[1] - 1
    out_q = ops.fleet_step(
        s1["mu"], s1["n"], s1["phat"], s1["pn"], s1["prev"], s1["t"],
        a1, kobs.reward, kobs.progress, kobs.active.astype(jnp.float32),
        alphas, 0.02, qos, f_max_arm, interpret=not ops.pallas_available(),
    )
    moved = int(jnp.sum(out_q[-1] != out[-1]))
    print(f"mixed QoS lanes (sentinel-off x delta=0.02, one launch): "
          f"budget re-routed {moved} controllers")

    # ... and so are the nonstationary variants: sliding-window
    # discounts (gamma < 1) and round-robin warm-up (optimistic < 0.5)
    # ride per-controller lanes in the SAME launch, so a mixed
    # stationary / sliding-window / warm-up fleet never leaves the
    # fused path (they used to silently fall back to vmap)
    gamma = jnp.where(jnp.arange(nk) % 2 == 0, 0.97, 1.0)
    optimistic = jnp.where(jnp.arange(nk) % 3 == 0, 0.0, 1.0)
    out_ns = ops.fleet_step(
        s1["mu"], s1["n"], s1["phat"], s1["pn"], s1["prev"], s1["t"],
        a1, kobs.reward, kobs.progress, kobs.active.astype(jnp.float32),
        alphas, 0.02, qos, f_max_arm, gamma, optimistic,
        interpret=not ops.pallas_available(),
    )
    moved_ns = int(jnp.sum(out_ns[-1] != out_q[-1]))
    print(f"mixed nonstationary lanes (half SW gamma=0.97, third warm-up, "
          f"one launch): re-routed {moved_ns} controllers")

    # factored ladders are one more lane plus one shape static: under
    # k_unc=3 the SAME (N, 9) state reads as a 3-core x 3-uncore
    # product ladder (flat arm = core*3 + unc). A mixed fleet — half
    # pricing switches scalar-style via the shared-penalty sentinel
    # (lam_unc < 0), half with a split per-dimension core/uncore cost —
    # still dispatches ONE fused launch, and the flat arms decompose
    # into per-dimension switch counts.
    lam_unc = jnp.where(jnp.arange(nk) % 2 == 0, -1.0, 0.04)
    out_f = ops.fleet_step(
        s1["mu"], s1["n"], s1["phat"], s1["pn"], s1["prev"], s1["t"],
        a1, kobs.reward, kobs.progress, kobs.active.astype(jnp.float32),
        alphas, 0.02, qos, f_max_arm, gamma, optimistic, None, lam_unc,
        k_unc=3, interpret=not ops.pallas_available(),
    )
    held, nxt = np.asarray(out_f[4]), np.asarray(out_f[-1])
    print("mixed scalar/factored penalty lanes on a 3x3 product ladder "
          "(one launch):")
    for name, m in (("shared-penalty half (scalar pricing)",
                     np.arange(nk) % 2 == 0),
                    ("split-penalty half (lam_unc=0.04)",
                     np.arange(nk) % 2 == 1)):
        cm = int(np.sum(nxt[m] // 3 != held[m] // 3))
        um = int(np.sum(nxt[m] % 3 != held[m] % 3))
        print(f"  {name}: {cm} core moves, {um} uncore moves")

    # ...and end to end on the calibrated factored environment: the
    # uncore axis stretches the bandwidth term and carries its own
    # power share, so the controller lands core AND uncore sweet spots
    # (CLI: fleet_serve --uncore-ladder 0.6,0.8,1.0 [--lam-unc 0.01])
    from repro.core import factored_energy_ucb
    from repro.core.policies import ActionSpace
    from repro.core.simulator import make_factored_env_params
    from repro.energy import EnergyController, SimBackend

    pfac = make_factored_env_params(get_app("tealeaf"))
    space = ActionSpace(9, 3)
    ctlf = EnergyController(
        factored_energy_ucb(space, uncore_penalty=0.01),
        SimBackend(pfac, n=64, seed=0),
        interpret=not ops.pallas_available())
    arms_hist = []
    for _ in range(150):
        ctlf.step()
        arms_hist.append(np.asarray(ctlf.last_arms))
    ah = np.stack(arms_hist)
    core_sw = int(np.sum(ah[1:] // space.k_unc != ah[:-1] // space.k_unc))
    unc_sw = int(np.sum(ah[1:] % space.k_unc != ah[:-1] % space.k_unc))
    sf = ctlf.summary()
    print(f"factored 9x3 fleet on tealeaf (N=64, 150 intervals, fused): "
          f"saved {sf['saved_energy_pct']:.1f}% vs (f_max, max-uncore); "
          f"{core_sw} core / {unc_sw} uncore switches")

    # drifting workloads end to end: the simulator cycles phases
    # (miniswp: memory-bound, low f best -> lbm: compute-bound, high f
    # best) every 150 intervals, and the sliding-window fleet
    # re-converges after each boundary where the stationary fleet is
    # stuck on stale estimates (CLI: fleet_serve --drift lbm
    # --drift-every 150 --window-discount 0.99)
    from repro.core.simulator import expected_rewards
    from repro.energy import EnergyController, SimBackend

    pa, pb = make_env_params(get_app("miniswp")), make_env_params(get_app("lbm"))
    mu_b = np.asarray(expected_rewards(pb))

    def drift_tail(policy):
        ctl = EnergyController(
            policy, SimBackend(pa, n=8, seed=0, drift_params=[pb],
                               drift_every=150),
            interpret=not ops.pallas_available())
        for _ in range(300):
            ctl.step()
        arms = np.stack([np.asarray(h["arm"]) for h in ctl.history])
        return float(np.mean(mu_b[arms[-60:]]))

    q_sw = drift_tail(energy_ucb(window_discount=0.97))
    q_st = drift_tail(energy_ucb())
    print(f"\ndrifting workload (miniswp -> lbm, fused all the way): tail "
          f"reward SW {q_sw:.3f} vs stationary {q_st:.3f} (best -0.998)")

    # the streaming control plane: one EnergyBackend surface from the
    # simulator to the fleet — the controller reads counters, derives
    # per-interval Obs (real switched bits included), and dispatches the
    # fused fleet step per decision interval
    from repro.energy import EnergyController, SimBackend

    ns = 4096
    ctl = EnergyController(energy_ucb(), SimBackend(make_env_params(get_app("tealeaf")), n=ns),
                           interpret=not ops.pallas_available(),
                           record_history=False)
    for _ in range(3):
        ctl.step()  # warm up traces
    t0 = time.perf_counter()
    for _ in range(10):
        ctl.step()
    jax.block_until_ready(ctl.states["mu"])
    dt = (time.perf_counter() - t0) / 10
    s = ctl.summary()
    print(f"\nstreaming EnergyController over SimBackend (N={ns}, "
          f"{'fused kernel' if ctl.use_kernel else 'vmapped'}): "
          f"{dt*1e3:.2f} ms/interval; saved {s['saved_energy_pct']:.1f}% "
          f"vs f_max, {s['switches']} switches")

    # the multi-process deployment shape: H controller processes, each
    # owning its own EnergyBackend stripe and N/H controllers, zero
    # per-interval collectives — fleet aggregates rendezvous over the
    # stdlib-socket coordinator (see module docstring for the per-host
    # CLI; --spawn forks both hosts locally)
    import subprocess
    import sys

    nd, td = 16, 40
    print(f"\n2-process distributed control plane (N={nd}, {td} intervals):")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.fleet_serve", "--spawn",
         "--num-hosts", "2", "--nodes", str(nd), "--intervals", str(td),
         "--app", "tealeaf", "--report-every", str(td // 2)],
        capture_output=True, text=True, timeout=600,
    )
    print("\n".join("  " + l for l in r.stdout.strip().splitlines()))
    if r.returncode != 0:
        print(r.stderr[-1500:])

    # fault tolerance end to end — the crash-restart runbook. Host 1 is
    # SIGKILLed right after its first stripe checkpoint and relaunched
    # with the SAME command line: it is admitted mid-run (skipping the
    # start barrier), restores its stripe's checkpoint, and replays
    # forward bit-exact while the survivor's periodic aggregates degrade
    # (hosts=1) instead of stalling. The final strict gather waits for
    # the resurrected host, so the run still ends fleet-complete.
    import os
    import secrets
    import shutil
    import signal
    import socket
    import tempfile

    from repro.train import checkpoint as ckpt_mod

    with socket.socket() as sock:
        sock.bind(("127.0.0.1", 0))
        port = sock.getsockname()[1]
    root = tempfile.mkdtemp(prefix="fleet_demo_ckpt_")
    env = dict(os.environ)
    env["FLEET_AUTHKEY"] = secrets.token_hex(16)
    nd2, td2 = 8, 60
    cmd = lambda h: [
        sys.executable, "-m", "repro.launch.fleet_serve",
        "--nodes", str(nd2), "--intervals", str(td2), "--app", "tealeaf",
        "--num-hosts", "2", "--host-id", str(h),
        "--coordinator", f"127.0.0.1:{port}", "--pace", "0.1",
        "--checkpoint-dir", root, "--checkpoint-every", "10",
        "--report-every", "30",
    ]
    print(f"\ncrash-restart runbook (N={nd2}, {td2} intervals, "
          "SIGKILL host 1 at its first checkpoint):")
    procs = {h: subprocess.Popen(cmd(h), stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True,
                                 env=env) for h in (0, 1)}
    vdir = ckpt_mod.stripe_dir(root, nd2 // 2, nd2)  # host 1's stripe
    while not ckpt_mod.list_steps(vdir):
        time.sleep(0.05)
    os.kill(procs[1].pid, signal.SIGKILL)
    procs[1].wait()
    print("  host 1 SIGKILLed; relaunching the same command line...")
    revived = subprocess.Popen(cmd(1), stdout=subprocess.PIPE,
                               stderr=subprocess.STDOUT, text=True, env=env)
    out0, _ = procs[0].communicate(timeout=300)
    out1, _ = revived.communicate(timeout=300)
    for line in out1.splitlines():
        if "resumed stripe" in line:
            print("  " + line)
    for line in out0.splitlines():
        if "hosts" in line:
            print("  " + line)
    print(f"  exit codes: survivor {procs[0].returncode}, "
          f"resurrected {revived.returncode}")
    shutil.rmtree(root, ignore_errors=True)

    # coordinated vs independent on a memory-bound app (8-node gang demo)
    p = make_env_params(get_app("miniswp"))
    nn, steps = 8, 12_000  # enough for miniswp to complete (~8.3k steps)
    ind = run_fleet_episode(energy_ucb(), p, jax.random.key(0), nn, steps, coordinated=False)
    coo = run_fleet_episode(energy_ucb(), p, jax.random.key(0), nn, steps, coordinated=True)
    e_def = static_energy_kj(p, 8) * nn
    print(f"\n{nn}-node gang on miniswp (energy vs all-nodes-f_max {e_def:.0f} kJ):")
    for name, out in (("independent", ind), ("coordinated", coo)):
        print(f"  {name:12s} energy={float(out['energy_kj']):8.1f} kJ  "
              f"gang_time={float(out['gang_time_s']):6.1f}s  "
              f"switches={int(out['switches'])}")
    print("coordinated mode: one arm for the gang -> no straggler coupling, "
          "1/N reward variance")


if __name__ == "__main__":
    main()
