"""Quickstart: EnergyUCB on a simulated Aurora node running pot3d.

Runs the paper's core loop end-to-end in ~10 s on CPU: a calibrated
DVFS environment (static energies reproduce Table 1 exactly), the
SA-UCB controller, and the headline metrics.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (
    FREQS_GHZ,
    TABLE1_KJ,
    energy_ucb,
    get_app,
    make_env_params,
    run_repeats,
    static_energy_kj,
)

APP = "pot3d"


def main():
    app = get_app(APP)
    params = make_env_params(app)
    print(f"app={APP}: T(f_max)={app.t_ref_s:.1f}s  compute-bound frac c={app.c:.2f}")
    print("static energies (kJ), 0.8 -> 1.6 GHz:")
    print("  ", " ".join(f"{static_energy_kj(params, i):7.1f}" for i in range(9)))

    out = run_repeats(energy_ucb(), params, jax.random.key(0), n_repeats=10)
    e = out["energy_kj"].mean()
    default = TABLE1_KJ[APP][-1]
    best = TABLE1_KJ[APP].min()
    best_arm = int(np.argmin(TABLE1_KJ[APP]))
    print(f"\nEnergyUCB (10 repeats): {e:.2f} ± {out['energy_kj'].std():.2f} kJ")
    print(f"  default 1.6 GHz      : {default:.2f} kJ  -> saved {default - e:.2f} kJ")
    print(f"  best static ({FREQS_GHZ[best_arm]:.1f} GHz): {best:.2f} kJ "
          f"-> energy regret {e - best:.2f} kJ ({100*(e-best)/best:.2f}%)")
    print(f"  switches: {out['switches'].mean():.0f}  "
          f"completed: {bool(out['completed'].all())}")

    qos = run_repeats(energy_ucb(qos_delta=0.05), params, jax.random.key(0), 10)
    slow = 100 * (qos["time_s"].mean() / app.t_ref_s - 1)
    print(f"\nQoS-constrained (delta=5%): {qos['energy_kj'].mean():.2f} kJ, "
          f"slowdown {slow:.2f}% (budget 5%)")


if __name__ == "__main__":
    main()
