"""Energy-aware serving, two layers deep.

Part 1 — the real jitted engine: batched prefill + greedy decode for a
reduced starcoder2 under a QoS-constrained EnergyUCB controller (each
prefill/decode call is one decision interval), reading the upgraded
``ServeEngine.stats`` telemetry (decode tokens, per-wave wall time,
queue depth).

Part 2 — the workload path (``repro.workload``): a bursty diurnal
request trace drives the roofline-parameterized ``ServingBackend``
with phase-conditioned control — compute-bound prefill keeps a tight
p99 slowdown budget while bandwidth-bound decode downclocks freely
(``phase_policy``) — and reports joules-per-served-token against the
f_max baseline plus the p99-latency SLO violation rate. This is the
small-scale version of ``benchmarks/serve_energy.py``.

  PYTHONPATH=src python examples/serve_energy_aware.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.policies import energy_ucb, make_policy_params, phase_policy
from repro.energy import EnergyController, StepEnergyModel, make_backend
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.workload import ServingBackend, bursty_diurnal_traffic


def engine_demo():
    cfg = get_reduced("starcoder2-15b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    # decision interval = one engine step; memory/collective-bound decode
    model = StepEnergyModel(t_compute_s=2e-4 * 64, t_memory_s=5e-3 * 64,
                            t_collective_s=2e-3 * 64, steps_total=400)
    controller = EnergyController(energy_ucb(qos_delta=0.10),
                                  make_backend(model))
    engine = ServeEngine(bundle, params, n_slots=4, max_len=96,
                         controller=controller)

    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).astype(np.int32),
                max_new=int(rng.integers(8, 24)))
        for i in range(12)
    ]
    done = engine.generate(reqs)
    st = engine.stats
    print(f"served {len(done)} requests: {st['decode_tokens']} decode tokens "
          f"over {st['decode_steps']} steps, "
          f"{st['wave_time_s']:.2f} s of wave time "
          f"(last wave {st['last_wave_s']:.2f} s)")
    s = controller.summary()
    print(f"  energy {s['energy_j']:.1f} J vs f_max {s['baseline_energy_j']:.1f} J "
          f"=> saved {s['saved_energy_pct']:.1f}%  "
          f"slowdown {s['slowdown_pct']:.2f}%  switches {s['switches']}")


def workload_demo(t_intervals: int = 300):
    traf = bursty_diurnal_traffic()
    be = ServingBackend(traf, "qwen2.5-3b", n_nodes=1, phase_split=True)
    pol = phase_policy(1, prefill=make_policy_params(qos_delta=0.01),
                       decode=make_policy_params(qos_delta=None))
    ctl = EnergyController(pol, be, use_kernel=False)
    ctl.run(t_intervals)
    c = be.read_counters()
    energy = float(c.energy_j.sum())
    rep = be.slo_report(warmup_s=60 * traf.interval_s)
    base = float(np.sum(be.baseline_interval())) * t_intervals
    print(f"served {rep['completed']} requests / {be.served_tokens} tokens "
          f"over {t_intervals} intervals")
    print(f"  {energy / max(be.served_tokens, 1):.3f} J/token "
          f"({energy:.0f} J vs ~{base:.0f} J at f_max)")
    print(f"  p99 {rep['p99_s']:.3f} s vs SLO {rep['slo_s']:.3f} s "
          f"=> violation rate {rep['violation_rate']:.3f}")


def main():
    print("== engine demo: real jitted prefill/decode under EnergyUCB ==")
    engine_demo()
    print("\n== workload demo: bursty diurnal traffic, phase-split lanes ==")
    workload_demo()


if __name__ == "__main__":
    main()
