"""Batched serving with a QoS-constrained EnergyUCB controller.

Serving (decode) is memory-bound on the roofline, so downclocking saves
real energy at bounded latency cost — the framework analogue of the
paper's memory-bound HPC apps. The engine runs real jitted prefill/
decode steps for a reduced starcoder2; the per-step energy model uses
the decode_32k cell's dry-run roofline terms.

  PYTHONPATH=src python examples/serve_energy_aware.py
"""
import json
import os

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.policies import energy_ucb
from repro.energy import EnergyController, StepEnergyModel, make_backend
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def cell_terms():
    path = "results/dryrun/starcoder2-15b__decode_32k__pod.json"
    if os.path.exists(path):
        from benchmarks.roofline_table import cell_row

        r = cell_row("results/dryrun", "starcoder2-15b", "decode_32k")
        if r:
            return r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]
    return 2e-4, 5e-3, 2e-3  # fallback: memory/collective-bound decode


def main():
    cfg = get_reduced("starcoder2-15b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))

    tc, tm, tcoll = cell_terms()
    # decision interval = 64 decode steps (~one token micro-batch wave)
    model = StepEnergyModel(t_compute_s=64 * tc, t_memory_s=64 * tm,
                            t_collective_s=64 * tcoll, steps_total=400)
    controller = EnergyController(energy_ucb(qos_delta=0.10),
                                  make_backend(model))
    engine = ServeEngine(bundle, params, n_slots=4, max_len=96,
                         controller=controller)

    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).astype(np.int32),
                max_new=int(rng.integers(8, 24)))
        for i in range(12)
    ]
    done = engine.generate(reqs)
    print(f"served {len(done)} requests, "
          f"{sum(len(r.out) for r in done)} tokens, stats={engine.stats}")
    s = controller.summary()
    print("\nenergy telemetry (QoS delta=10%):")
    print(f"  energy: {s['energy_j']:.1f} J vs f_max baseline {s['baseline_energy_j']:.1f} J "
          f"=> saved {s['saved_energy_pct']:.1f}%")
    print(f"  slowdown: {s['slowdown_pct']:.2f}%  switches: {s['switches']}")
    arms = [h["freq_ghz"] for h in controller.history]
    print(f"  frequency trajectory: start {arms[:5]} ... settled at {arms[-1]:.1f} GHz")


if __name__ == "__main__":
    main()
