"""End-to-end driver: train a ~100M-parameter qwen3-family model for a
few hundred steps with the EnergyUCB controller in the loop, checkpoint
+ restart, and report both learning and energy telemetry.

The training step really runs (CPU); the node's DVFS behavior is the
calibrated simulation driven by the cell's roofline terms, exactly as
the runtime would consume GEOPM telemetry on hardware.

  PYTHONPATH=src python examples/train_energy_aware.py [--steps 200]
"""
import argparse
import dataclasses
import shutil

import jax

from repro.configs import get_arch
from repro.configs.base import ArchConfig, LayoutConfig, ShapeConfig
from repro.core.policies import energy_ucb
from repro.energy import EnergyController, StepEnergyModel, make_backend
from repro.models import build_model
from repro.train.trainer import Trainer, TrainerConfig

# ~100M params: a narrow qwen3-style decoder
CFG_100M = ArchConfig(
    name="qwen3-100m",
    family="dense",
    num_layers=8,
    d_model=640,
    num_heads=10,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=50304,
    qk_norm=True,
    tie_embeddings=True,
    layout=LayoutConfig(microbatch=0, param_dtype="float32", remat="none",
                        seq_parallel=False),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()
    # NOTE: ~15-20 s/step on a 1-core CPU container; the default 200
    # steps is a ~1 h run. On any accelerator this is minutes.
    shutil.rmtree(args.ckpt, ignore_errors=True)

    bundle = build_model(CFG_100M)
    n = sum(
        int(x.size) for x in jax.tree.leaves(jax.eval_shape(bundle.init, jax.random.key(0)))
    )
    print(f"model: {CFG_100M.name} ({n/1e6:.1f}M params)")

    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch, kind="train")
    # cell energy model: a mildly memory-bound training step
    model = StepEnergyModel(t_compute_s=0.22, t_memory_s=0.30, t_collective_s=0.12,
                            n_chips=8, steps_total=args.steps)
    # the streaming control plane: EnergyUCB over the GEOPM-shaped backend
    controller = EnergyController(energy_ucb(), make_backend(model))
    trainer = Trainer(
        bundle, shape,
        tcfg=TrainerConfig(total_steps=args.steps, ckpt_every=50,
                           ckpt_dir=args.ckpt, log_every=25),
        controller=controller,
    )
    res = trainer.run()
    print("\nstep   loss     grad_norm")
    for m in res["metrics"]:
        print(f"{m['step']:5d}  {m['loss']:7.4f}  {m['grad_norm']:8.3f}")
    e = res["energy"]
    print("\nenergy telemetry (simulated node):")
    for k in ("steps", "energy_j", "baseline_energy_j", "saved_energy_pct",
              "slowdown_pct", "switches"):
        v = e[k]
        print(f"  {k:20s} {v:.2f}" if isinstance(v, float) else f"  {k:20s} {v}")
    print(f"  stragglers flagged   {len(res['stragglers'])}")

    # restart from checkpoint: loss trajectory continues deterministically
    trainer2 = Trainer(
        bundle, shape,
        tcfg=TrainerConfig(total_steps=args.steps + 20, ckpt_every=50,
                           ckpt_dir=args.ckpt, log_every=10),
    )
    start = trainer2.init_or_restore()
    print(f"\nrestarted from checkpoint at step {start}; continuing to {args.steps+20}")
    res2 = trainer2.run()
    print(f"final loss {res2['metrics'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
